# Build entry points referenced by the docs and runtime error messages.
#
#   make artifacts   AOT-lower the L2/L1 JAX+Pallas programs to HLO text
#                    + manifest.json under artifacts/ (requires JAX).
#   make build       Release build of the Rust crate (default features).
#   make test        Rust test suite, default features (offline, no JAX).
#   make test-pjrt   Artifacts + Rust tests with the `pjrt` feature.
#   make test-python Kernel/model tests for the artifact pipeline.

# The artifacts location is a contract, not a knob: the Rust tests,
# benches and examples resolve <repo-root>/artifacts (anchored via
# CARGO_MANIFEST_DIR), and `repro` defaults to ./artifacts from the
# repo root.
CONFIGS ?= mnist_small,fashion_small

.PHONY: artifacts build test test-pjrt test-python

artifacts:
	cd python && python3 -m compile.aot \
		--out-dir ../artifacts --configs $(CONFIGS)

build:
	cargo build --release

test:
	cargo test -q

test-pjrt: artifacts
	cargo test -q --features pjrt

test-python:
	cd python && python3 -m pytest tests -q
