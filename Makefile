# Build entry points referenced by the docs and runtime error messages.
#
#   make artifacts   AOT-lower the L2/L1 JAX+Pallas programs to HLO text
#                    + manifest.json under artifacts/ (requires JAX).
#   make build       Release build of the Rust crate (default features).
#   make test        Rust test suite, default features (offline, no JAX).
#   make test-pjrt   Artifacts + Rust tests with the `pjrt` feature.
#   make test-python Kernel/model tests for the artifact pipeline.
#   make grid-smoke  Tiny end-to-end pass over the docs/EXPERIMENTS.md
#                    commands: a parallel scenario x gamma grid, a
#                    capacity-class grid, a sweep, the Fig.-2 timeline
#                    and the beta table.
#   make bench       Full pinned-seed perf suite checked against the
#                    committed BENCH_baseline.json (docs/BENCHMARKS.md);
#                    mirrors the CI perf-smoke gate.
#   make bench-baseline  Run the full suite and rewrite BENCH_baseline.json
#                    in place (commit the result with a rationale).

# The artifacts location is a contract, not a knob: the Rust tests,
# benches and examples resolve <repo-root>/artifacts (anchored via
# CARGO_MANIFEST_DIR), and `repro` defaults to ./artifacts from the
# repo root.
CONFIGS ?= mnist_small,fashion_small

.PHONY: artifacts build test test-pjrt test-python grid-smoke bench bench-baseline

artifacts:
	cd python && python3 -m compile.aot \
		--out-dir ../artifacts --configs $(CONFIGS)

build:
	cargo build --release

test:
	cargo test -q

test-pjrt: artifacts
	cargo test -q --features pjrt

test-python:
	cd python && python3 -m pytest tests -q

# Exercises the cookbook's command lines (docs/EXPERIMENTS.md) on a
# deliberately tiny config so CI can afford it: an 8-job grid across all
# four scenarios, a gamma sweep, the analytic timeline and beta tables.
# Output accumulates in a mktemp scratch dir removed by an EXIT trap, so
# a failing run leaves nothing behind; on success it is promoted to
# results/grid-smoke/ for inspection.
grid-smoke: build
	@tmp=$$(mktemp -d -t grid-smoke.XXXXXX); \
	trap 'rm -rf "$$tmp"' EXIT; \
	set -e; \
	./target/release/repro grid --learner linear --jobs 4 \
	    --set clients=4 --set samples_per_client=20 --set test_samples=50 \
	    --set local_steps=2 --set max_slots=2 \
	    --axis gamma=0.1,0.4 \
	    --axis scenario=static,dropout:0.2,churn:0.4,drift:2 \
	    --out "$$tmp"; \
	./target/release/repro grid --learner linear --jobs 2 \
	    --set clients=4 --set samples_per_client=20 --set test_samples=50 \
	    --set local_steps=2 --set max_slots=2 \
	    --axis "capacity=full;classes:1.0x0.5,0.25x0.5" \
	    --out "$$tmp/capacity"; \
	./target/release/repro sweep --param gamma --values 0.1,0.4 --jobs 2 \
	    --learner linear --set clients=4 --set samples_per_client=20 \
	    --set test_samples=50 --set local_steps=2 --set max_slots=2 \
	    --out "$$tmp"; \
	./target/release/repro timeline --clients 8 --out "$$tmp"; \
	./target/release/repro inspect betas --clients 8 > "$$tmp/betas.csv"; \
	mkdir -p results; \
	rm -rf results/grid-smoke; \
	mv "$$tmp" results/grid-smoke; \
	trap - EXIT; \
	echo "grid-smoke: OK (see results/grid-smoke/)"

bench: build
	./target/release/repro bench --format json \
	    --out results/bench --check BENCH_baseline.json

# Re-record the committed baseline from a full (non-quick) run on the
# current machine — replaces the hand-seeded-values workflow described
# in docs/BENCHMARKS.md. The record is produced in a scratch dir first
# so a failed run cannot leave a truncated baseline behind.
bench-baseline: build
	@tmp=$$(mktemp -d -t bench-baseline.XXXXXX); \
	trap 'rm -rf "$$tmp"' EXIT; \
	set -e; \
	./target/release/repro bench --format json --out "$$tmp" > /dev/null; \
	cp "$$tmp"/BENCH_*.json BENCH_baseline.json; \
	echo "bench-baseline: rewrote BENCH_baseline.json (full suite)"
