//! Discrete-event virtual-time simulator (Sec. II-C substrate).
//!
//! Virtual time is measured in integer `Ticks` so event ordering is exact
//! and platform-independent. Real computation (PJRT training) is executed
//! when compute events fire, but its wall-clock cost never leaks into the
//! virtual timeline — the timeline is governed purely by the paper's time
//! model (τ compute, τ^u upload, τ^d download, per-client speed factors).

pub mod capacity;
pub mod channel;
mod compute;
mod event;
pub mod partition;
pub mod scenario;
mod time_model;

pub use capacity::{CapacityClass, CapacityProfile};
pub use channel::{ChannelState, FadingChannel};
pub use compute::{ComputeModel, HeterogeneityProfile};
pub use event::EventQueue;
pub use partition::{ClientPartition, OrderedMerge};
pub use scenario::Scenario;
pub use time_model::{Ticks, TimeModel, UplinkChannel};
