//! Client compute-capability heterogeneity (Sec. II-C / Sec. IV).
//!
//! Each client m has a speed factor `a_m >= 1` (1 = fastest hardware
//! class). The paper's simulation randomizes effective speed per trunk
//! time; we model that with per-round multiplicative jitter on top of the
//! per-client base factor.

use crate::sim::time_model::{Ticks, TimeModel};
use crate::util::rng::Rng;

/// How client speed factors are drawn.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum HeterogeneityProfile {
    /// All clients identical (the paper's homogeneous analysis case).
    Homogeneous,
    /// Factors uniform in [1, max_factor].
    Uniform {
        /// Upper bound of the uniform draw (slowest possible client).
        max_factor: f64,
    },
    /// Log-normal factors: 1 + LogNormal(0, sigma) - exp(-sigma^2/2)-ish
    /// tail; a realistic long-tail straggler population.
    Lognormal {
        /// σ of the underlying normal (tail heaviness).
        sigma: f64,
    },
    /// The paper's two extreme scenarios: a fraction of very fast clients
    /// (factor 1) and a fraction of very slow ones (factor `slow_factor`,
    /// e.g. 10x), the rest at `mid_factor`.
    Extreme {
        /// Fraction of clients at factor 1 (the fast tier).
        fast_frac: f64,
        /// Fraction of clients at `slow_factor` (the straggler tier).
        slow_frac: f64,
        /// Factor of the middle tier.
        mid_factor: f64,
        /// Factor of the straggler tier.
        slow_factor: f64,
    },
}

impl HeterogeneityProfile {
    /// Parse a CLI/JSON spelling: a profile name (`homo`, `uniform`,
    /// `lognormal`, `extreme`) optionally followed by `:`-separated
    /// numeric parameters (`uniform:6`, `lognormal:0.75`,
    /// `extreme:0.1,0.1,3,10`). A bare name uses default parameters.
    pub fn parse(s: &str) -> Option<HeterogeneityProfile> {
        let (name, args) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let nums: Vec<f64> = match args {
            None => Vec::new(),
            Some(a) => {
                let parsed: Option<Vec<f64>> =
                    a.split(',').map(|p| p.trim().parse::<f64>().ok()).collect();
                parsed?
            }
        };
        match (name.to_ascii_lowercase().as_str(), nums.as_slice()) {
            ("homogeneous" | "homo", []) => Some(HeterogeneityProfile::Homogeneous),
            ("uniform", []) => Some(HeterogeneityProfile::Uniform { max_factor: 4.0 }),
            // Speed factors are >= 1 by construction (1 = fastest class),
            // so out-of-range parameters are parse errors, not silent
            // clamps — consistent with every other config field.
            ("uniform", &[max_factor]) if max_factor >= 1.0 => {
                Some(HeterogeneityProfile::Uniform { max_factor })
            }
            ("lognormal", []) => Some(HeterogeneityProfile::Lognormal { sigma: 0.5 }),
            ("lognormal", &[sigma]) if sigma > 0.0 => {
                Some(HeterogeneityProfile::Lognormal { sigma })
            }
            ("extreme", []) => Some(HeterogeneityProfile::Extreme {
                fast_frac: 0.1,
                slow_frac: 0.1,
                mid_factor: 3.0,
                slow_factor: 10.0,
            }),
            ("extreme", &[fast_frac, slow_frac, mid_factor, slow_factor])
                if (0.0..=1.0).contains(&fast_frac)
                    && (0.0..=1.0).contains(&slow_frac)
                    && fast_frac + slow_frac <= 1.0
                    && mid_factor >= 1.0
                    && slow_factor >= 1.0 =>
            {
                Some(HeterogeneityProfile::Extreme {
                    fast_frac,
                    slow_frac,
                    mid_factor,
                    slow_factor,
                })
            }
            _ => None,
        }
    }

    /// Canonical parameterized spelling, accepted back by
    /// [`HeterogeneityProfile::parse`] (JSON provenance roundtrip).
    pub fn spec(&self) -> String {
        match self {
            HeterogeneityProfile::Homogeneous => "homo".into(),
            HeterogeneityProfile::Uniform { max_factor } => format!("uniform:{max_factor}"),
            HeterogeneityProfile::Lognormal { sigma } => format!("lognormal:{sigma}"),
            HeterogeneityProfile::Extreme {
                fast_frac,
                slow_frac,
                mid_factor,
                slow_factor,
            } => format!("extreme:{fast_frac},{slow_frac},{mid_factor},{slow_factor}"),
        }
    }
}

/// Per-client speed factors + per-round jitter.
#[derive(Debug, Clone)]
pub struct ComputeModel {
    factors: Vec<f64>,
    /// Multiplicative jitter half-width (0.1 = ±10% per round draw).
    jitter: f64,
}

impl ComputeModel {
    /// Draw per-client base factors from `profile` (deterministically in
    /// `rng`'s seed path) with the given per-round jitter half-width.
    pub fn new(profile: HeterogeneityProfile, clients: usize, jitter: f64, rng: &Rng) -> Self {
        let mut r = rng.fork(0x5eed_c0de);
        let factors: Vec<f64> = (0..clients)
            .map(|i| match profile {
                HeterogeneityProfile::Homogeneous => 1.0,
                HeterogeneityProfile::Uniform { max_factor } => {
                    r.range_f64(1.0, max_factor.max(1.0))
                }
                // Always >= 1: unit-speed floor plus a long-tailed surplus.
                HeterogeneityProfile::Lognormal { sigma } => 1.0 + r.lognormal(0.0, sigma),
                HeterogeneityProfile::Extreme {
                    fast_frac,
                    slow_frac,
                    mid_factor,
                    slow_factor,
                } => {
                    let u = i as f64 / clients.max(1) as f64;
                    if u < fast_frac {
                        1.0
                    } else if u >= 1.0 - slow_frac {
                        slow_factor
                    } else {
                        mid_factor
                    }
                }
            })
            .collect();
        ComputeModel { factors, jitter }
    }

    /// Number of clients in the model.
    pub fn clients(&self) -> usize {
        self.factors.len()
    }

    /// Base speed factor of client m.
    pub fn factor(&self, m: usize) -> f64 {
        self.factors[m]
    }

    /// The largest (slowest) base factor — the straggler bound.
    pub fn slowest_factor(&self) -> f64 {
        self.factors.iter().cloned().fold(1.0, f64::max)
    }

    /// The smallest (fastest) base factor.
    pub fn fastest_factor(&self) -> f64 {
        self.factors.iter().cloned().fold(f64::MAX, f64::min)
    }

    /// Clients sorted fastest-first (the baseline-AFL schedule).
    pub fn fastest_first(&self) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..self.factors.len()).collect();
        idx.sort_by(|&a, &b| {
            self.factors[a]
                .partial_cmp(&self.factors[b])
                .unwrap()
                .then(a.cmp(&b))
        });
        idx
    }

    /// Draw the compute duration for `local_steps` steps on client m.
    pub fn duration(
        &self,
        tm: &TimeModel,
        m: usize,
        local_steps: usize,
        rng: &mut Rng,
    ) -> Ticks {
        self.duration_scaled(tm, m, local_steps, rng, 1.0)
    }

    /// Like [`ComputeModel::duration`] with an extra multiplicative
    /// `scale` on the effective speed factor — the seam scenarios (e.g.
    /// `drift`) use for time-varying compute. Applied *before* rounding,
    /// so `scale == 1.0` is bit-identical to the unscaled draw.
    pub fn duration_scaled(
        &self,
        tm: &TimeModel,
        m: usize,
        local_steps: usize,
        rng: &mut Rng,
        scale: f64,
    ) -> Ticks {
        let jit = if self.jitter > 0.0 {
            1.0 + self.jitter * (2.0 * rng.f64() - 1.0)
        } else {
            1.0
        };
        tm.compute_time(local_steps, self.factors[m] * jit * scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(99)
    }

    #[test]
    fn homogeneous_all_ones() {
        let cm = ComputeModel::new(HeterogeneityProfile::Homogeneous, 10, 0.0, &rng());
        assert!((0..10).all(|m| cm.factor(m) == 1.0));
        assert_eq!(cm.slowest_factor(), 1.0);
    }

    #[test]
    fn uniform_within_bounds() {
        let cm = ComputeModel::new(
            HeterogeneityProfile::Uniform { max_factor: 4.0 },
            100,
            0.0,
            &rng(),
        );
        for m in 0..100 {
            assert!((1.0..=4.0).contains(&cm.factor(m)));
        }
        assert!(cm.slowest_factor() > cm.fastest_factor());
    }

    #[test]
    fn extreme_has_three_tiers() {
        let cm = ComputeModel::new(
            HeterogeneityProfile::Extreme {
                fast_frac: 0.1,
                slow_frac: 0.1,
                mid_factor: 3.0,
                slow_factor: 10.0,
            },
            20,
            0.0,
            &rng(),
        );
        assert_eq!(cm.factor(0), 1.0);
        assert_eq!(cm.factor(10), 3.0);
        assert_eq!(cm.factor(19), 10.0);
    }

    #[test]
    fn fastest_first_sorted() {
        let cm = ComputeModel::new(
            HeterogeneityProfile::Uniform { max_factor: 5.0 },
            30,
            0.0,
            &rng(),
        );
        let order = cm.fastest_first();
        for w in order.windows(2) {
            assert!(cm.factor(w[0]) <= cm.factor(w[1]));
        }
    }

    #[test]
    fn duration_deterministic_without_jitter() {
        let tm = TimeModel::default();
        let cm = ComputeModel::new(HeterogeneityProfile::Homogeneous, 4, 0.0, &rng());
        let mut r1 = rng();
        let mut r2 = rng();
        assert_eq!(cm.duration(&tm, 0, 16, &mut r1), cm.duration(&tm, 0, 16, &mut r2));
        assert_eq!(cm.duration(&tm, 0, 16, &mut r1), 160);
    }

    #[test]
    fn duration_scaled_is_exact_at_unit_scale() {
        let tm = TimeModel::default();
        let cm = ComputeModel::new(
            HeterogeneityProfile::Uniform { max_factor: 4.0 },
            4,
            0.2,
            &rng(),
        );
        let mut r1 = rng();
        let mut r2 = rng();
        for m in 0..4 {
            assert_eq!(
                cm.duration(&tm, m, 16, &mut r1),
                cm.duration_scaled(&tm, m, 16, &mut r2, 1.0)
            );
        }
        let mut r = rng();
        let cm = ComputeModel::new(HeterogeneityProfile::Homogeneous, 1, 0.0, &rng());
        assert_eq!(cm.duration_scaled(&tm, 0, 16, &mut r, 2.0), 320);
    }

    #[test]
    fn jitter_bounded() {
        let tm = TimeModel::default();
        let cm = ComputeModel::new(HeterogeneityProfile::Homogeneous, 1, 0.2, &rng());
        let mut r = rng();
        for _ in 0..200 {
            let d = cm.duration(&tm, 0, 16, &mut r) as f64;
            assert!((160.0 * 0.8 - 1.0..=160.0 * 1.2 + 1.0).contains(&d), "{d}");
        }
    }

    #[test]
    fn lognormal_factors_at_least_one() {
        let cm = ComputeModel::new(
            HeterogeneityProfile::Lognormal { sigma: 0.8 },
            200,
            0.0,
            &rng(),
        );
        for m in 0..200 {
            assert!(cm.factor(m) >= 1.0, "{}", cm.factor(m));
        }
    }

    #[test]
    fn parse_profiles() {
        assert_eq!(
            HeterogeneityProfile::parse("homo"),
            Some(HeterogeneityProfile::Homogeneous)
        );
        assert!(HeterogeneityProfile::parse("uniform").is_some());
        assert!(HeterogeneityProfile::parse("nope").is_none());
    }

    #[test]
    fn parse_accepts_parameterized_spellings() {
        assert_eq!(
            HeterogeneityProfile::parse("uniform:6"),
            Some(HeterogeneityProfile::Uniform { max_factor: 6.0 })
        );
        assert_eq!(
            HeterogeneityProfile::parse("extreme:0.2,0.2,3,10"),
            Some(HeterogeneityProfile::Extreme {
                fast_frac: 0.2,
                slow_frac: 0.2,
                mid_factor: 3.0,
                slow_factor: 10.0,
            })
        );
        assert!(HeterogeneityProfile::parse("uniform:x").is_none());
        assert!(HeterogeneityProfile::parse("extreme:1,2").is_none());
        assert!(HeterogeneityProfile::parse("homo:1").is_none());
        // Out-of-range parameters are rejected, not clamped.
        assert!(HeterogeneityProfile::parse("uniform:0.5").is_none());
        assert!(HeterogeneityProfile::parse("lognormal:-1").is_none());
        assert!(HeterogeneityProfile::parse("extreme:0.6,0.6,3,10").is_none());
        assert!(HeterogeneityProfile::parse("extreme:0.1,0.1,3,-10").is_none());
        assert!(HeterogeneityProfile::parse("extreme:0.1,0.1,0.5,10").is_none());
    }

    #[test]
    fn spec_roundtrips_every_profile() {
        for p in [
            HeterogeneityProfile::Homogeneous,
            HeterogeneityProfile::Uniform { max_factor: 4.0 },
            HeterogeneityProfile::Lognormal { sigma: 0.5 },
            HeterogeneityProfile::Extreme {
                fast_frac: 0.1,
                slow_frac: 0.3,
                mid_factor: 2.5,
                slow_factor: 8.0,
            },
        ] {
            assert_eq!(HeterogeneityProfile::parse(&p.spec()), Some(p), "{}", p.spec());
        }
    }
}
