//! Client partitioning and deterministic merge — the two structural
//! primitives of the sharded coordinator (`coordinator::shard`), shared
//! with the TCP deployment leader (`net::leader`) so the simulator and
//! the deployment keep one aggregation discipline.
//!
//! * [`ClientPartition`] splits a client population into K contiguous,
//!   disjoint shards (sizes differing by at most one). The sharded
//!   simulator routes each client's local-training work to the worker
//!   owning its shard; the TCP leader routes each worker's *connection*
//!   to the ingest shard owning its id (`shard_of`). In both, which
//!   shard a client lands in can affect only *which thread* does the
//!   arithmetic or frame-decoding, never the result.
//! * [`OrderedMerge`] is the ordered fan-in: items arriving in
//!   nondeterministic order are staged and released in ascending
//!   `(key, client)` order. It packages, for consumers without a
//!   virtual clock, the same `(time, insertion seq)` discipline the
//!   sharded simulator gets from [`crate::sim::EventQueue`]: the
//!   deployment leader stages each drained burst of concurrent TCP
//!   uploads under `(start iteration, worker id)`, so socket races
//!   within a burst cannot reorder aggregation (burst membership
//!   itself remains wall-clock-dependent — full determinism needs the
//!   simulator's virtual time, or the leader's `lockstep` mode, which
//!   pins burst membership to fault-schedule-determined rounds). Ties
//!   on the full key are broken by insertion sequence, exactly like
//!   the event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// A disjoint, contiguous K-way split of clients `0..clients`.
///
/// The shard count is clamped to `[1, clients]` (an empty shard would be
/// a worker with no possible work). Shard sizes differ by at most one,
/// with the remainder spread over the lowest-numbered shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClientPartition {
    clients: usize,
    shards: usize,
}

impl ClientPartition {
    /// A partition of `clients` clients into (at most) `shards` shards.
    /// `shards` is clamped to `[1, max(clients, 1)]`.
    pub fn new(clients: usize, shards: usize) -> ClientPartition {
        ClientPartition {
            clients,
            shards: shards.clamp(1, clients.max(1)),
        }
    }

    /// The effective shard count after clamping.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// The client population size.
    pub fn clients(&self) -> usize {
        self.clients
    }

    /// The shard owning `client`.
    pub fn shard_of(&self, client: usize) -> usize {
        debug_assert!(client < self.clients, "client {client} out of range");
        let base = self.clients / self.shards;
        let rem = self.clients % self.shards;
        // The first `rem` shards own `base + 1` clients each.
        let wide = rem * (base + 1);
        if client < wide {
            client / (base + 1)
        } else {
            rem + (client - wide) / base
        }
    }

    /// The contiguous client range of shard `k`.
    pub fn range(&self, k: usize) -> std::ops::Range<usize> {
        assert!(k < self.shards, "shard {k} out of range ({})", self.shards);
        let base = self.clients / self.shards;
        let rem = self.clients % self.shards;
        let start = k * base + k.min(rem);
        let len = base + usize::from(k < rem);
        start..start + len
    }
}

/// Wrapper keeping the heap ordering independent of the payload (the
/// same idiom as the event queue's `EventBox`).
#[derive(Debug)]
struct Item<T>(T);

impl<T> PartialEq for Item<T> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<T> Eq for Item<T> {}
impl<T> PartialOrd for Item<T> {
    fn partial_cmp(&self, _: &Self) -> Option<std::cmp::Ordering> {
        Some(std::cmp::Ordering::Equal)
    }
}
impl<T> Ord for Item<T> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

/// Ordered fan-in stage: items pushed in any order pop in ascending
/// `(key, client, insertion sequence)` order.
///
/// Consumption order of a staged set is a pure function of the items
/// themselves, whatever order threads or sockets delivered them in.
/// The deployment leader stages each drained burst of concurrent
/// uploads here (key = start iteration); the sharded simulator's
/// aggregation stage gets the equivalent ordering from its event
/// queue's `(virtual time, insertion seq)` key, which is why the two
/// paths share this module's docs rather than this type's heap.
#[derive(Debug)]
pub struct OrderedMerge<T> {
    heap: BinaryHeap<Reverse<(u64, usize, u64, Item<T>)>>,
    seq: u64,
}

impl<T> Default for OrderedMerge<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> OrderedMerge<T> {
    /// An empty merge stage.
    pub fn new() -> OrderedMerge<T> {
        OrderedMerge {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Stage `item` under `(key, client)`.
    pub fn push(&mut self, key: u64, client: usize, item: T) {
        self.heap.push(Reverse((key, client, self.seq, Item(item))));
        self.seq += 1;
    }

    /// Release the staged item with the smallest `(key, client)`.
    pub fn pop(&mut self) -> Option<(u64, usize, T)> {
        let Reverse((key, client, _, Item(item))) = self.heap.pop()?;
        Some((key, client, item))
    }

    /// The `(key, client)` that [`OrderedMerge::pop`] would release next.
    pub fn peek_key(&self) -> Option<(u64, usize)> {
        self.heap.peek().map(|Reverse((k, c, _, _))| (*k, *c))
    }

    /// Number of staged items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether nothing is staged.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_population_disjointly() {
        for (clients, shards) in [(10, 3), (7, 7), (12, 4), (5, 1), (1, 8), (100, 16)] {
            let p = ClientPartition::new(clients, shards);
            assert!(p.shards() >= 1 && p.shards() <= clients.max(1));
            let mut seen = vec![false; clients];
            for k in 0..p.shards() {
                for c in p.range(k) {
                    assert!(!seen[c], "client {c} in two shards ({clients}x{shards})");
                    seen[c] = true;
                    assert_eq!(p.shard_of(c), k, "shard_of({c}) ({clients}x{shards})");
                }
            }
            assert!(seen.iter().all(|&s| s), "uncovered client ({clients}x{shards})");
        }
    }

    #[test]
    fn partition_sizes_differ_by_at_most_one() {
        let p = ClientPartition::new(10, 3);
        let sizes: Vec<usize> = (0..3).map(|k| p.range(k).len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert_eq!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap(), 1);
    }

    #[test]
    fn partition_clamps_degenerate_shard_counts() {
        assert_eq!(ClientPartition::new(4, 0).shards(), 1);
        assert_eq!(ClientPartition::new(4, 99).shards(), 4);
        assert_eq!(ClientPartition::new(0, 3).shards(), 1);
    }

    #[test]
    fn merge_releases_in_key_then_client_order() {
        let mut m = OrderedMerge::new();
        m.push(20, 1, "c");
        m.push(10, 5, "b");
        m.push(10, 2, "a");
        assert_eq!(m.len(), 3);
        assert_eq!(m.peek_key(), Some((10, 2)));
        assert_eq!(m.pop(), Some((10, 2, "a")));
        assert_eq!(m.pop(), Some((10, 5, "b")));
        assert_eq!(m.pop(), Some((20, 1, "c")));
        assert_eq!(m.pop(), None);
        assert!(m.is_empty());
    }

    #[test]
    fn merge_breaks_full_ties_by_insertion() {
        let mut m = OrderedMerge::new();
        m.push(7, 0, 1);
        m.push(7, 0, 2);
        m.push(7, 0, 3);
        assert_eq!(m.pop().unwrap().2, 1);
        assert_eq!(m.pop().unwrap().2, 2);
        assert_eq!(m.pop().unwrap().2, 3);
    }

    #[test]
    fn merge_order_is_independent_of_arrival_order() {
        let entries = [(3u64, 1usize), (1, 9), (2, 0), (1, 1), (3, 0)];
        let mut a = OrderedMerge::new();
        let mut b = OrderedMerge::new();
        for &(k, c) in &entries {
            a.push(k, c, (k, c));
        }
        for &(k, c) in entries.iter().rev() {
            b.push(k, c, (k, c));
        }
        let drain = |mut m: OrderedMerge<(u64, usize)>| {
            let mut out = Vec::new();
            while let Some(e) = m.pop() {
                out.push(e);
            }
            out
        };
        assert_eq!(drain(a), drain(b));
    }
}
