//! Scenario library: pluggable world models for the event-driven engine.
//!
//! The paper evaluates CSMAAFL in a *static* world — client speed
//! factors are drawn once and every upload arrives. Related work shows
//! the interesting regimes are dynamic: Hu et al. (arXiv:2107.11415)
//! schedule under intermittent client availability, and Gao et al.
//! (arXiv:2401.13366) show resource-constrained async FL develops
//! systematic bias when slow clients drop out. A [`Scenario`] injects
//! exactly those dynamics into the event loop without touching the
//! aggregation or scheduling policies.
//!
//! Like aggregation policies, scenarios are a registry spelling —
//! `scenario=<name[:params]>` on any config or `--set` — parsed by
//! [`parse`]:
//!
//! | Spelling                  | World                                        |
//! |---------------------------|----------------------------------------------|
//! | `static`                  | today's fixed world (the pinned default)     |
//! | `dropout:p`               | each upload lost in transit w.p. `p`         |
//! | `churn:rate[,cycle]`      | clients leave/rejoin (offline `rate` of the  |
//! |                           | time, mean on+off cycle `cycle` slots);      |
//! |                           | a rejoining client uploads the stale model   |
//! |                           | it was holding when it left                  |
//! | `drift:period[,factor]`   | periodic slow-down: every other `period`-slot|
//! |                           | epoch, compute runs `factor`× slower         |
//!
//! The event loop consults the scenario at three points: when drawing a
//! compute duration ([`Scenario::compute_scale`]), when a client asks
//! for the channel ([`Scenario::offline_until`]), and when an upload
//! completes ([`Scenario::upload_lost`]). `static` answers all three
//! with the identity, so the pinned default is bit-identical to the
//! pre-scenario engine. Stochastic scenarios draw from their own forked
//! RNG streams (seeded in [`Scenario::bind`]), never from the engine's,
//! so adding a scenario cannot perturb jitter or loss draws elsewhere.

use anyhow::{bail, ensure, Result};

use crate::sim::time_model::Ticks;
use crate::util::rng::Rng;
use crate::util::spec::parse_spec;

/// A world model the event-driven AFL engine consults while simulating.
///
/// All hooks default to the static world (no scaling, no loss, always
/// online), so implementations override only the dynamics they model.
/// Hooks may mutate internal state; the engine calls them in
/// deterministic event order, and stochastic implementations must draw
/// only from RNG streams derived in [`Scenario::bind`].
pub trait Scenario: Send {
    /// Canonical label (names series and log lines).
    fn label(&self) -> String;

    /// Called once before the run with the population size, the ticks
    /// per relative time slot, and the run seed. Implementations derive
    /// their RNG streams and per-client state here.
    fn bind(&mut self, _clients: usize, _slot_ticks: Ticks, _seed: u64) {}

    /// Multiplier on the client's effective speed factor for the
    /// compute draw starting at `now` (> 1 = slower). Applied before
    /// rounding, so `1.0` is exactly the unscaled duration.
    fn compute_scale(&mut self, _client: usize, _now: Ticks) -> f64 {
        1.0
    }

    /// Whether the upload completing at `now` is lost in transit.
    fn upload_lost(&mut self, _client: usize, _now: Ticks) -> bool {
        false
    }

    /// If the client is offline at `now`, the (strictly later) tick at
    /// which it rejoins; `None` when it is online.
    fn offline_until(&mut self, _client: usize, _now: Ticks) -> Option<Ticks> {
        None
    }
}

/// One canonical registry spelling per built-in scenario (tests iterate
/// these; docs list them).
pub const SCENARIO_SPECS: [&str; 4] = ["static", "dropout:0.1", "churn:0.3", "drift:8"];

/// Instantiate a scenario from its registry spelling `name[:p1[,p2]]`.
///
/// ```
/// use csmaafl::sim::scenario;
/// let s = scenario::parse("dropout:0.3").unwrap();
/// assert_eq!(s.label(), "dropout p=0.3");
/// assert!(scenario::parse("bogus").is_err());
/// assert_eq!(scenario::resolve(None).unwrap().label(), "static");
/// ```
pub fn parse(spec: &str) -> Result<Box<dyn Scenario>> {
    let (name, f) = parse_spec(spec)?;
    match name.to_ascii_lowercase().as_str() {
        "static" => {
            ensure!(f.is_empty(), "scenario {name:?} takes no parameters");
            Ok(Box::new(StaticWorld))
        }
        "dropout" => {
            ensure!(f.len() == 1, "dropout takes exactly one parameter (p)");
            Ok(Box::new(Dropout::new(f[0])?))
        }
        "churn" => {
            ensure!(
                !f.is_empty() && f.len() <= 2,
                "churn takes one or two parameters (rate[,cycle_slots])"
            );
            let cycle = f.get(1).copied().unwrap_or(4.0);
            Ok(Box::new(Churn::new(f[0], cycle)?))
        }
        "drift" => {
            ensure!(
                !f.is_empty() && f.len() <= 2,
                "drift takes one or two parameters (period_slots[,factor])"
            );
            let factor = f.get(1).copied().unwrap_or(2.0);
            Ok(Box::new(Drift::new(f[0], factor)?))
        }
        other => bail!(
            "unknown scenario {other:?} \
             (static | dropout:p | churn:rate[,cycle] | drift:period[,factor])"
        ),
    }
}

/// Resolve a config's optional spelling: `None` means the pinned
/// `static` default.
pub fn resolve(spec: Option<&str>) -> Result<Box<dyn Scenario>> {
    match spec {
        None => Ok(Box::new(StaticWorld)),
        Some(s) => parse(s),
    }
}

/// The paper's fixed world: no departures, no transit loss, constant
/// compute factors. Every hook is the identity, so runs under this
/// scenario are bit-identical to the pre-scenario engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticWorld;

impl Scenario for StaticWorld {
    fn label(&self) -> String {
        "static".into()
    }
}

/// Uploads are lost in transit with probability `p` (Bernoulli per
/// upload, own RNG stream). Lost uploads feed the engine's existing
/// lost-upload statistics: the server re-downloads the current global
/// so the client rejoins, its local work wasted.
#[derive(Debug, Clone)]
pub struct Dropout {
    p: f64,
    rng: Rng,
}

impl Dropout {
    /// A transit-loss world with loss probability `p ∈ (0, 1)`.
    pub fn new(p: f64) -> Result<Dropout> {
        ensure!(
            p > 0.0 && p < 1.0,
            "dropout probability must be in (0,1), got {p}"
        );
        Ok(Dropout { p, rng: Rng::new(0) })
    }
}

impl Scenario for Dropout {
    fn label(&self) -> String {
        format!("dropout p={}", self.p)
    }

    fn bind(&mut self, _clients: usize, _slot_ticks: Ticks, seed: u64) {
        self.rng = Rng::new(seed).fork(0xd709);
    }

    fn upload_lost(&mut self, _client: usize, _now: Ticks) -> bool {
        self.rng.f64() < self.p
    }
}

/// Per-client availability state of the churn world.
#[derive(Debug, Clone)]
struct ClientChurn {
    online: bool,
    /// Tick at which the current on/off period ends.
    until: Ticks,
    rng: Rng,
}

/// Clients alternately leave and rejoin: each client is offline a
/// long-run fraction `rate` of the time, in alternating on/off windows
/// whose mean combined length is `cycle_slots` relative time slots
/// (window lengths jitter uniformly in ±50% of their mean). A client
/// that finishes local compute while offline holds its local model and
/// re-contends for the channel only when it rejoins — by which point
/// the model version it trained from is stale, so churn stresses
/// exactly the staleness handling of the aggregation policies.
#[derive(Debug, Clone)]
pub struct Churn {
    rate: f64,
    cycle_slots: f64,
    on_mean: f64,
    off_mean: f64,
    state: Vec<ClientChurn>,
}

impl Churn {
    /// A churn world: offline fraction `rate ∈ (0, 1)`, mean on+off
    /// cycle `cycle_slots > 0` relative slots.
    pub fn new(rate: f64, cycle_slots: f64) -> Result<Churn> {
        ensure!(
            rate > 0.0 && rate < 1.0,
            "churn rate must be in (0,1), got {rate}"
        );
        ensure!(
            cycle_slots > 0.0,
            "churn cycle must be > 0 slots, got {cycle_slots}"
        );
        Ok(Churn {
            rate,
            cycle_slots,
            on_mean: 0.0,
            off_mean: 0.0,
            state: Vec::new(),
        })
    }

    fn draw(mean: f64, rng: &mut Rng) -> Ticks {
        ((mean * (0.5 + rng.f64())).round() as Ticks).max(1)
    }
}

impl Scenario for Churn {
    fn label(&self) -> String {
        format!("churn r={} c={}", self.rate, self.cycle_slots)
    }

    fn bind(&mut self, clients: usize, slot_ticks: Ticks, seed: u64) {
        let cycle_ticks = self.cycle_slots * slot_ticks as f64;
        self.on_mean = (1.0 - self.rate) * cycle_ticks;
        self.off_mean = self.rate * cycle_ticks;
        let root = Rng::new(seed).fork(0xc4a2);
        self.state = (0..clients)
            .map(|c| {
                let mut rng = root.fork(c as u64);
                let until = Self::draw(self.on_mean, &mut rng);
                ClientChurn {
                    online: true,
                    until,
                    rng,
                }
            })
            .collect();
    }

    fn offline_until(&mut self, client: usize, now: Ticks) -> Option<Ticks> {
        let (on_mean, off_mean) = (self.on_mean, self.off_mean);
        let s = &mut self.state[client];
        while s.until <= now {
            s.online = !s.online;
            let mean = if s.online { on_mean } else { off_mean };
            s.until += Self::draw(mean, &mut s.rng);
        }
        if s.online {
            None
        } else {
            Some(s.until)
        }
    }
}

/// Periodic compute slow-down: virtual time is divided into epochs of
/// `period_slots` relative slots; during every other epoch all clients'
/// compute runs `factor`× slower (a coarse model of diurnal load or
/// shared-cluster contention — time-varying compute factors).
#[derive(Debug, Clone)]
pub struct Drift {
    period_slots: f64,
    factor: f64,
    period_ticks: f64,
}

impl Drift {
    /// A drift world: epoch length `period_slots > 0`, slow-epoch
    /// factor `factor >= 1`.
    pub fn new(period_slots: f64, factor: f64) -> Result<Drift> {
        ensure!(
            period_slots > 0.0,
            "drift period must be > 0 slots, got {period_slots}"
        );
        ensure!(factor >= 1.0, "drift factor must be >= 1, got {factor}");
        Ok(Drift {
            period_slots,
            factor,
            period_ticks: 0.0,
        })
    }
}

impl Scenario for Drift {
    fn label(&self) -> String {
        format!("drift p={} x={}", self.period_slots, self.factor)
    }

    fn bind(&mut self, _clients: usize, slot_ticks: Ticks, _seed: u64) {
        self.period_ticks = self.period_slots * slot_ticks as f64;
    }

    fn compute_scale(&mut self, _client: usize, now: Ticks) -> f64 {
        if self.period_ticks <= 0.0 {
            return 1.0;
        }
        if ((now as f64 / self.period_ticks).floor() as u64) % 2 == 1 {
            self.factor
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parses_every_canonical_spelling() {
        for spec in SCENARIO_SPECS {
            let s = parse(spec).unwrap();
            assert!(!s.label().is_empty(), "{spec}");
        }
    }

    #[test]
    fn registry_rejects_unknown_and_malformed() {
        assert!(parse("bogus").is_err());
        assert!(parse("static:1").is_err());
        assert!(parse("dropout").is_err());
        assert!(parse("dropout:x").is_err());
        assert!(parse("dropout:0").is_err());
        assert!(parse("dropout:1.5").is_err());
        assert!(parse("churn:0.2,1,1").is_err());
        assert!(parse("churn:-0.1").is_err());
        assert!(parse("drift:0").is_err());
        assert!(parse("drift:4,0.5").is_err());
    }

    #[test]
    fn static_world_is_the_identity() {
        let mut s = StaticWorld;
        s.bind(8, 1000, 42);
        assert_eq!(s.compute_scale(0, 500), 1.0);
        assert!(!s.upload_lost(0, 500));
        assert_eq!(s.offline_until(0, 500), None);
    }

    #[test]
    fn dropout_rate_is_roughly_p() {
        let mut d = Dropout::new(0.25).unwrap();
        d.bind(4, 1000, 7);
        let lost = (0..10_000u64)
            .filter(|&i| d.upload_lost((i % 4) as usize, i))
            .count();
        assert!((2000..3000).contains(&lost), "{lost}");
    }

    #[test]
    fn dropout_streams_are_seed_deterministic() {
        let mut a = Dropout::new(0.5).unwrap();
        let mut b = Dropout::new(0.5).unwrap();
        a.bind(2, 1000, 9);
        b.bind(2, 1000, 9);
        for t in 0..100 {
            assert_eq!(a.upload_lost(0, t), b.upload_lost(0, t));
        }
    }

    #[test]
    fn churn_alternates_and_rejoins_strictly_later() {
        let mut c = Churn::new(0.5, 2.0).unwrap();
        c.bind(3, 1000, 11);
        let mut saw_offline = false;
        for t in (0..40_000u64).step_by(97) {
            if let Some(rejoin) = c.offline_until(1, t) {
                saw_offline = true;
                assert!(rejoin > t, "rejoin {rejoin} must be after now {t}");
                // At the rejoin tick the client is online again.
                assert_eq!(c.offline_until(1, rejoin), None);
            }
        }
        assert!(saw_offline, "client never went offline over 40k ticks");
    }

    #[test]
    fn churn_offline_fraction_tracks_rate() {
        let mut c = Churn::new(0.7, 1.0).unwrap();
        c.bind(1, 1000, 5);
        let samples = 50_000u64;
        let off = (0..samples)
            .filter(|&t| c.offline_until(0, t).is_some())
            .count() as f64;
        let frac = off / samples as f64;
        assert!((0.55..0.85).contains(&frac), "offline fraction {frac}");
    }

    #[test]
    fn drift_is_a_square_wave_over_epochs() {
        let mut d = Drift::new(2.0, 3.0).unwrap();
        d.bind(4, 100, 0); // epoch = 200 ticks
        assert_eq!(d.compute_scale(0, 0), 1.0);
        assert_eq!(d.compute_scale(0, 199), 1.0);
        assert_eq!(d.compute_scale(0, 200), 3.0);
        assert_eq!(d.compute_scale(0, 399), 3.0);
        assert_eq!(d.compute_scale(0, 400), 1.0);
    }
}
