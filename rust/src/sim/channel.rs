//! Fading-channel registry: per-client time-varying link quality.
//!
//! The wireless async-FL related work (arXiv 2107.11415, 2212.07356)
//! schedules against a *channel*: a per-client link whose quality varies
//! over time, decides how long an upload occupies the uplink, and makes
//! transmission failures correlate with link state instead of being
//! i.i.d. coin flips. This module models that as a **block-fading Markov
//! chain over a small gain ladder**: virtual time is cut into coherence
//! blocks of `block_ticks`; within a block the channel gain is constant;
//! at each block boundary the ladder level takes one birth–death step
//!
//! ```text
//! P(level → level−1) = p_move/2,   P(level → level+1) = p_move/2,
//! P(level → level)   = 1 − p_move          (saturating at the rails)
//! ```
//!
//! over the gain ladder `[0.25, 0.5, 1.0, 2.0]`. A client's effective
//! upload time is `τ^u / gain` (deep fade → 4× slower upload), and an
//! upload finishing in block `b` is lost with the level's loss
//! probability `[0.4, 0.1, 0.02, 0.0]` — failures cluster in fades,
//! which is exactly the correlation the i.i.d. `upload_loss` knob and
//! the `dropout` scenario cannot express.
//!
//! Like scenarios and capacity profiles, the channel is a registry
//! spelling — `channel=<name[:params]>` on any config or `--set`:
//!
//! | Spelling                  | Channel                                      |
//! |---------------------------|----------------------------------------------|
//! | `ideal`                   | gain 1.0 always, no losses (pinned default)  |
//! | `markov[:p_move,block]`   | block-fading ladder walk: move probability   |
//! |                           | `p_move ∈ (0,1]` per block boundary, blocks  |
//! |                           | of `block` ticks (defaults `0.5`, `500`)     |
//!
//! **Determinism.** The fading process is a *pure function of
//! (seed, client, block index)*: the channel stream is forked from the
//! root run RNG (fork label `0xfad1e5`, like `dropout`'s loss stream),
//! each client forks its own sub-stream (like `churn`), and each block's
//! move/loss draws come from a per-`(client, block)` fork — never from a
//! sequential stream whose value depends on query history. Queries at
//! any time, in any order, from any engine or shard therefore agree
//! (`tests/properties.rs` pins this), and the trivial `ideal` channel
//! makes **no** draws and **no** forks at all, so it cannot perturb any
//! other stream derived from the root — `channel=ideal` is byte-identical
//! to the pre-channel engines (`tests/sharded.rs` pins this).

use anyhow::{bail, ensure, Result};

use crate::sim::Ticks;
use crate::util::rng::Rng;

/// The gain ladder, worst fade first. Gains multiply the uplink rate:
/// effective upload time is `τ^u / gain`.
pub const GAIN_LADDER: [f64; 4] = [0.25, 0.5, 1.0, 2.0];

/// Per-level transmission-loss probability (aligned with [`GAIN_LADDER`]).
pub const LOSS_PROB: [f64; 4] = [0.4, 0.1, 0.02, 0.0];

/// Ladder index every client starts in (gain 1.0).
const START_LEVEL: u8 = 2;

/// One canonical registry spelling per built-in channel shape (tests
/// iterate these; docs list them).
pub const CHANNEL_SPECS: [&str; 2] = ["ideal", "markov:0.5,500"];

/// RNG fork label of the channel stream (off the root run RNG).
const FADE_FORK: u64 = 0xfad1e5;

/// Markov block-fading parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
struct MarkovParams {
    /// Probability of taking a ladder step at each block boundary.
    p_move: f64,
    /// Coherence-block length in virtual ticks.
    block_ticks: Ticks,
}

/// A parsed channel model (the registry entry). Bind it to a population
/// with [`FadingChannel::bind`] to get a queryable [`ChannelState`].
#[derive(Debug, Clone, PartialEq)]
pub struct FadingChannel {
    /// `None` = the trivial `ideal` channel.
    markov: Option<MarkovParams>,
}

impl FadingChannel {
    /// The pinned default: gain 1.0 for everyone, forever, no losses.
    pub fn ideal() -> FadingChannel {
        FadingChannel { markov: None }
    }

    /// Whether this is the trivial `ideal` channel. Trivial channels
    /// take the engines' existing code path untouched (no draws, no
    /// forks, no new report fields), which is what makes them
    /// byte-identical to the pre-channel records.
    pub fn is_trivial(&self) -> bool {
        self.markov.is_none()
    }

    /// Canonical registry spelling (round-trips through [`parse`]).
    pub fn spec(&self) -> String {
        match self.markov {
            None => "ideal".into(),
            Some(p) => format!("markov:{},{}", p.p_move, p.block_ticks),
        }
    }

    /// Bind the model to a population. `root` is the run's root RNG;
    /// the trivial channel never forks it.
    pub fn bind(&self, clients: usize, root: &Rng) -> ChannelState {
        match self.markov {
            None => ChannelState {
                params: None,
                rng: None,
                cache: Vec::new(),
            },
            Some(params) => ChannelState {
                params: Some(params),
                rng: Some(root.fork(FADE_FORK)),
                cache: vec![(0, START_LEVEL); clients],
            },
        }
    }
}

/// The bound per-client fading process: answers "what is client `c`'s
/// channel at time `t`" queries. Holds a per-client `(block, level)`
/// cache so monotone queries advance the ladder walk incrementally, but
/// every answer is the same pure function of (seed, client, block) —
/// an out-of-order query just re-walks from block 0.
#[derive(Debug, Clone)]
pub struct ChannelState {
    params: Option<MarkovParams>,
    /// The channel fork of the root RNG (`None` when ideal).
    rng: Option<Rng>,
    /// Per-client cached walk position: (block index, ladder level).
    cache: Vec<(u64, u8)>,
}

impl ChannelState {
    /// Whether this is the bound trivial channel.
    pub fn is_trivial(&self) -> bool {
        self.params.is_none()
    }

    /// The coherence-block index `now` falls in (0 for the ideal
    /// channel, which has a single infinite block).
    pub fn block_of(&self, now: Ticks) -> u64 {
        match self.params {
            None => 0,
            Some(p) => now / p.block_ticks,
        }
    }

    /// The per-(client, block) draw pair: (move u, loss u). Pure in
    /// (seed, client, block) by construction — a fresh fork per query.
    fn block_draws(&self, client: usize, block: u64) -> (f64, f64) {
        let rng = self.rng.as_ref().expect("draws only on non-trivial channels");
        let mut r = rng.fork(client as u64).fork(block);
        let mv = r.f64();
        let loss = r.f64();
        (mv, loss)
    }

    /// One birth–death step of the ladder walk.
    fn step(level: u8, u: f64, p_move: f64) -> u8 {
        if u < p_move * 0.5 {
            level.saturating_sub(1)
        } else if u < p_move {
            (level + 1).min(GAIN_LADDER.len() as u8 - 1)
        } else {
            level
        }
    }

    /// Ladder level of `client` in block `block`: advance the cached
    /// walk forward, or re-walk from block 0 on an out-of-order query
    /// (same answer either way — the walk is pure in (seed, client,
    /// block)).
    fn level_at(&mut self, client: usize, block: u64) -> u8 {
        let p = self.params.expect("level queries only on non-trivial channels");
        let (mut at, mut level) = self.cache[client];
        if block < at {
            at = 0;
            level = START_LEVEL;
        }
        while at < block {
            at += 1;
            let (mv, _) = self.block_draws(client, at);
            level = Self::step(level, mv, p.p_move);
        }
        self.cache[client] = (at, level);
        level
    }

    /// Channel gain of `client` at time `now` (1.0 on the ideal channel).
    pub fn gain(&mut self, client: usize, now: Ticks) -> f64 {
        if self.params.is_none() {
            return 1.0;
        }
        let block = self.block_of(now);
        GAIN_LADDER[self.level_at(client, block) as usize]
    }

    /// Whether an upload by `client` finishing at `now` is lost to the
    /// channel. Block-faded: the decision is a pure function of
    /// (seed, client, block), so failures cluster within a fade instead
    /// of flipping an independent coin per upload. Never true (and never
    /// draws) on the ideal channel.
    pub fn upload_lost(&mut self, client: usize, now: Ticks) -> bool {
        if self.params.is_none() {
            return false;
        }
        let block = self.block_of(now);
        let level = self.level_at(client, block);
        let (_, loss_u) = self.block_draws(client, block);
        loss_u < LOSS_PROB[level as usize]
    }

    /// Channel-scaled upload duration: `τ / gain`, rounded, floored at
    /// one tick. Exactly `tau` on the ideal channel (gain 1.0).
    pub fn scaled_tau(&mut self, client: usize, now: Ticks, tau: Ticks) -> Ticks {
        if self.params.is_none() {
            // Exactly `tau`, not `max(1)`: the ideal channel must leave
            // every engine's timeline untouched, degenerate τ included.
            return tau;
        }
        let g = self.gain(client, now);
        ((tau as f64 / g).round() as Ticks).max(1)
    }
}

/// Instantiate a channel model from its registry spelling.
///
/// ```
/// use csmaafl::sim::channel;
/// assert!(channel::parse("ideal").unwrap().is_trivial());
/// let c = channel::parse("markov:0.3,200").unwrap();
/// assert!(!c.is_trivial());
/// assert_eq!(c.spec(), "markov:0.3,200");
/// assert!(channel::parse("bogus").is_err());
/// assert!(channel::resolve(None).unwrap().is_trivial());
/// ```
pub fn parse(spec: &str) -> Result<FadingChannel> {
    let (name, params) = match spec.split_once(':') {
        Some((n, p)) => (n.trim(), Some(p.trim())),
        None => (spec.trim(), None),
    };
    match name.to_ascii_lowercase().as_str() {
        "ideal" => {
            ensure!(params.is_none(), "channel \"ideal\" takes no parameters");
            Ok(FadingChannel::ideal())
        }
        "markov" => {
            let (p_move, block_ticks) = match params {
                None => (0.5, 500),
                Some("") => bail!("markov takes p_move[,block_ticks] (e.g. markov:0.5,500)"),
                Some(p) => {
                    let mut it = p.split(',').map(str::trim);
                    let pm: f64 = match it.next() {
                        Some(s) if !s.is_empty() => s.parse().map_err(|_| {
                            anyhow::anyhow!("bad channel move probability {s:?} in {spec:?}")
                        })?,
                        _ => bail!("markov takes p_move[,block_ticks]"),
                    };
                    let bt: Ticks = match it.next() {
                        None => 500,
                        Some(s) => s.parse().map_err(|_| {
                            anyhow::anyhow!("bad channel block length {s:?} in {spec:?}")
                        })?,
                    };
                    ensure!(it.next().is_none(), "markov takes at most two parameters");
                    (pm, bt)
                }
            };
            ensure!(
                p_move.is_finite() && p_move > 0.0 && p_move <= 1.0,
                "channel move probability must be in (0,1], got {p_move}"
            );
            ensure!(block_ticks >= 1, "channel block length must be >= 1 tick");
            Ok(FadingChannel {
                markov: Some(MarkovParams { p_move, block_ticks }),
            })
        }
        other => bail!("unknown channel model {other:?} (ideal | markov[:p_move,block_ticks])"),
    }
}

/// Ladder index of an exact [`GAIN_LADDER`] gain value (the ladder holds
/// exact powers of two, so `f64` equality is well-defined). `None` for
/// anything off-ladder — e.g. the ideal channel's constant 1.0 is level
/// 2, but telemetry callers should skip the lookup entirely when the
/// channel is trivial.
pub fn level_of_gain(gain: f64) -> Option<u8> {
    GAIN_LADDER.iter().position(|&g| g == gain).map(|i| i as u8)
}

/// Resolve a config's optional spelling: `None` means the pinned `ideal`
/// default.
pub fn resolve(spec: Option<&str>) -> Result<FadingChannel> {
    match spec {
        None => Ok(FadingChannel::ideal()),
        Some(s) => parse(s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parses_every_canonical_spelling() {
        for spec in CHANNEL_SPECS {
            let c = parse(spec).unwrap();
            // Canonical spellings round-trip through spec() → parse().
            assert_eq!(parse(&c.spec()).unwrap(), c, "{spec}");
        }
        // The bare spelling resolves to the canonical defaults.
        assert_eq!(parse("markov").unwrap().spec(), "markov:0.5,500");
        assert_eq!(parse("markov:0.5").unwrap().spec(), "markov:0.5,500");
    }

    #[test]
    fn registry_rejects_unknown_and_malformed() {
        assert!(parse("bogus").is_err());
        assert!(parse("ideal:1").is_err());
        assert!(parse("markov:").is_err());
        assert!(parse("markov:x").is_err());
        assert!(parse("markov:0").is_err());
        assert!(parse("markov:1.5").is_err());
        assert!(parse("markov:-0.5").is_err());
        assert!(parse("markov:0.5,0").is_err());
        assert!(parse("markov:0.5,x").is_err());
        assert!(parse("markov:0.5,500,9").is_err());
    }

    #[test]
    fn ideal_is_trivial_makes_no_state_and_never_loses() {
        let root = Rng::new(42);
        let c = resolve(None).unwrap();
        assert!(c.is_trivial());
        let mut s = c.bind(1_000_000, &root);
        // No per-client allocation for the trivial channel.
        assert!(s.is_trivial());
        for now in [0, 123, 99_999] {
            assert_eq!(s.gain(17, now), 1.0);
            assert!(!s.upload_lost(17, now));
            assert_eq!(s.scaled_tau(17, now, 100), 100);
        }
    }

    #[test]
    fn fading_is_a_pure_function_of_seed_client_and_block() {
        let c = parse("markov:0.5,100").unwrap();
        let root = Rng::new(7);
        // Forward walk vs out-of-order queries on a fresh instance.
        let mut fwd = c.bind(8, &root);
        let mut ooo = c.bind(8, &root);
        let times: Vec<Ticks> = (0..40).map(|i| i * 97).collect();
        let forward: Vec<f64> = times.iter().map(|&t| fwd.gain(3, t)).collect();
        let backward: Vec<f64> = times.iter().rev().map(|&t| ooo.gain(3, t)).collect();
        let reversed: Vec<f64> = backward.into_iter().rev().collect();
        assert_eq!(forward, reversed, "query order changed the fading process");
        // Loss decisions are equally pure.
        let mut a = c.bind(8, &root);
        let mut b = c.bind(8, &root);
        for &t in times.iter().rev() {
            assert_eq!(a.upload_lost(5, t), b.upload_lost(5, t));
        }
        // And distinct seeds give distinct processes.
        let mut other = c.bind(8, &Rng::new(8));
        let diverged = times.iter().any(|&t| other.gain(3, t) != fwd.gain(3, t));
        assert!(diverged, "seed did not influence the walk");
    }

    #[test]
    fn gain_is_constant_within_a_block_and_walks_the_ladder() {
        let c = parse("markov:1.0,100").unwrap();
        let mut s = c.bind(4, &Rng::new(3));
        // Within one coherence block the gain cannot change.
        let g0 = s.gain(1, 0);
        assert_eq!(g0, s.gain(1, 50));
        assert_eq!(g0, s.gain(1, 99));
        assert_eq!(g0, 1.0, "walk starts at the gain-1.0 rung");
        // With p_move=1 every boundary steps one rung: consecutive
        // blocks differ by exactly one ladder position.
        let mut prev = 2usize;
        for b in 1..50u64 {
            let g = s.gain(1, b * 100);
            let idx = GAIN_LADDER.iter().position(|&x| x == g).unwrap();
            assert!(
                idx.abs_diff(prev) <= 1,
                "block {b}: jumped {prev} -> {idx}"
            );
            prev = idx;
        }
    }

    #[test]
    fn losses_correlate_with_fades() {
        let c = parse("markov:0.5,100").unwrap();
        let mut s = c.bind(64, &Rng::new(11));
        let (mut faded_losses, mut top_losses) = (0u64, 0u64);
        let (mut faded, mut top) = (0u64, 0u64);
        for client in 0..64 {
            for b in 0..200u64 {
                let now = b * 100;
                let g = s.gain(client, now);
                let lost = s.upload_lost(client, now);
                if g < 1.0 {
                    faded += 1;
                    faded_losses += lost as u64;
                } else if g == 2.0 {
                    top += 1;
                    top_losses += lost as u64;
                }
            }
        }
        assert!(faded > 0 && top > 0, "walk never visited both ends");
        assert_eq!(top_losses, 0, "the top rung has loss probability 0");
        assert!(
            faded_losses > 0,
            "fades never lost an upload across {faded} faded blocks"
        );
    }

    #[test]
    fn level_of_gain_inverts_the_ladder() {
        for (i, &g) in GAIN_LADDER.iter().enumerate() {
            assert_eq!(level_of_gain(g), Some(i as u8));
        }
        assert_eq!(level_of_gain(3.0), None);
    }

    #[test]
    fn scaled_tau_divides_by_gain_and_floors() {
        let c = parse("markov:0.5,100").unwrap();
        let mut s = c.bind(4, &Rng::new(5));
        let g = s.gain(2, 1234);
        let tau = s.scaled_tau(2, 1234, 100);
        assert_eq!(tau, ((100.0 / g).round() as Ticks).max(1));
    }
}
