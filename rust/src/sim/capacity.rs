//! Capacity-class registry: heterogeneous-capacity submodel profiles.
//!
//! The paper's premise is that small-capacity clients delay aggregation,
//! but in the baseline engines capacity shows up only as *time* — every
//! client still trains the full model. The HeteroFL lineage (and
//! resource-constrained async FL, arXiv:2401.13366) instead gives each
//! client a rate-scaled *submodel*: a capacity class with rate `r`
//! trains and uploads only the leading `ceil(r·n)` elements of every
//! tensor (see [`crate::model::SubmodelMap`]), so capacity scales both
//! the `train_passes` cost and the upload size, and the server
//! aggregates overlapping slices.
//!
//! Like scenarios and aggregation policies, capacity is a registry
//! spelling — `capacity=<name[:params]>` on any config or `--set` —
//! parsed by [`parse`]:
//!
//! | Spelling                   | Population                                  |
//! |----------------------------|---------------------------------------------|
//! | `full`                     | every client at rate 1.0 (pinned default)   |
//! | `uniform:r`                | every client at rate `r ∈ (0, 1]`           |
//! | `classes:r1xf1,r2xf2,...`  | mixed classes: fraction `f_k` of clients at |
//! |                            | rate `r_k` (fractions normalized to sum 1)  |
//!
//! Class membership is assigned deterministically from the root run RNG
//! (fork label `0xca9ac1`, one draw per client in client order) exactly
//! like the `dropout` scenario draws its loss stream — so the
//! assignment never perturbs jitter, partition, or scenario draws, and
//! single-class profiles (`full`, any `uniform:r`) make **no** draws at
//! all. `full` and `uniform:1.0` keep every engine bit-identical to the
//! pre-submodel code path (`tests/sharded.rs` pins this).

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

/// One capacity class: a submodel rate and the population fraction
/// assigned to it.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityClass {
    /// Submodel rate in (0, 1]; 1.0 is the full model.
    pub rate: f64,
    /// Fraction of the population in this class (normalized, sums to 1
    /// across the profile).
    pub fraction: f64,
    /// Canonical label for metrics columns and log lines (`r1`, `r0.5`).
    pub label: String,
}

/// A capacity profile: the capacity classes of a population and how
/// clients are split among them, in spelling order.
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityProfile {
    classes: Vec<CapacityClass>,
}

/// One canonical registry spelling per built-in profile shape (tests
/// iterate these; docs list them).
pub const CAPACITY_SPECS: [&str; 3] =
    ["full", "uniform:0.5", "classes:1.0x0.5,0.5x0.3,0.25x0.2"];

/// RNG fork label of the class-assignment stream.
const ASSIGN_FORK: u64 = 0xca9ac1;

impl CapacityProfile {
    /// The pinned default: every client at rate 1.0.
    pub fn full() -> CapacityProfile {
        CapacityProfile {
            classes: vec![CapacityClass {
                rate: 1.0,
                fraction: 1.0,
                label: "r1".into(),
            }],
        }
    }

    fn uniform(rate: f64) -> Result<CapacityProfile> {
        ensure!(
            rate.is_finite() && rate > 0.0 && rate <= 1.0,
            "capacity rate must be in (0,1], got {rate}"
        );
        Ok(CapacityProfile {
            classes: vec![CapacityClass {
                rate,
                fraction: 1.0,
                label: format!("r{rate}"),
            }],
        })
    }

    fn mixed(pairs: Vec<(f64, f64)>) -> Result<CapacityProfile> {
        ensure!(!pairs.is_empty(), "classes takes at least one rxf pair");
        ensure!(
            pairs.len() <= 16,
            "classes takes at most 16 rxf pairs, got {}",
            pairs.len()
        );
        let total: f64 = pairs.iter().map(|(_, f)| f).sum();
        let mut classes = Vec::with_capacity(pairs.len());
        for (i, &(rate, fraction)) in pairs.iter().enumerate() {
            ensure!(
                rate.is_finite() && rate > 0.0 && rate <= 1.0,
                "capacity rate must be in (0,1], got {rate}"
            );
            ensure!(
                fraction.is_finite() && fraction > 0.0,
                "class fraction must be > 0, got {fraction}"
            );
            ensure!(
                pairs[..i].iter().all(|&(r, _)| r != rate),
                "duplicate capacity rate {rate}"
            );
            classes.push(CapacityClass {
                rate,
                fraction: fraction / total,
                label: format!("r{rate}"),
            });
        }
        Ok(CapacityProfile { classes })
    }

    /// The capacity classes, in spelling order.
    pub fn classes(&self) -> &[CapacityClass] {
        &self.classes
    }

    /// Whether this is the identity profile: a single class at rate 1.0.
    /// Trivial profiles take the engines' existing full-model path
    /// untouched, which is what makes them bit-identical to the
    /// pre-submodel code.
    pub fn is_trivial(&self) -> bool {
        self.classes.len() == 1 && self.classes[0].rate == 1.0
    }

    /// Canonical registry spelling (round-trips through [`parse`]).
    pub fn spec(&self) -> String {
        if self.is_trivial() {
            "full".into()
        } else if self.classes.len() == 1 {
            format!("uniform:{}", self.classes[0].rate)
        } else {
            let pairs: Vec<String> = self
                .classes
                .iter()
                .map(|c| format!("{}x{}", c.rate, c.fraction))
                .collect();
            format!("classes:{}", pairs.join(","))
        }
    }

    /// Assign every client a class index, deterministically from the
    /// root run RNG: one `f64` draw per client in client order against
    /// the cumulative class fractions. Single-class profiles make no
    /// draws (the fork is never advanced), so `full`/`uniform` cannot
    /// perturb any other stream derived from `root`.
    pub fn assign(&self, clients: usize, root: &Rng) -> Vec<u8> {
        if self.classes.len() == 1 {
            return vec![0; clients];
        }
        let mut rng = root.fork(ASSIGN_FORK);
        (0..clients)
            .map(|_| {
                let u = rng.f64();
                let mut cum = 0.0;
                for (k, c) in self.classes.iter().enumerate() {
                    cum += c.fraction;
                    if u < cum {
                        return k as u8;
                    }
                }
                (self.classes.len() - 1) as u8
            })
            .collect()
    }
}

/// Instantiate a capacity profile from its registry spelling.
///
/// ```
/// use csmaafl::sim::capacity;
/// let p = capacity::parse("classes:1.0x0.5,0.25x0.5").unwrap();
/// assert_eq!(p.classes().len(), 2);
/// assert!(!p.is_trivial());
/// assert!(capacity::parse("bogus").is_err());
/// assert!(capacity::resolve(None).unwrap().is_trivial());
/// ```
pub fn parse(spec: &str) -> Result<CapacityProfile> {
    let (name, params) = match spec.split_once(':') {
        Some((n, p)) => (n.trim(), Some(p.trim())),
        None => (spec.trim(), None),
    };
    match name.to_ascii_lowercase().as_str() {
        "full" => {
            ensure!(params.is_none(), "capacity profile \"full\" takes no parameters");
            Ok(CapacityProfile::full())
        }
        "uniform" => {
            let p = match params {
                Some(p) if !p.is_empty() => p,
                _ => bail!("uniform takes exactly one parameter (rate)"),
            };
            let rate: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("bad capacity rate {p:?} in {spec:?}"))?;
            CapacityProfile::uniform(rate)
        }
        "classes" => {
            let p = match params {
                Some(p) if !p.is_empty() => p,
                _ => bail!("classes takes rxf pairs (e.g. classes:1.0x0.5,0.25x0.5)"),
            };
            let mut pairs = Vec::new();
            for part in p.split(',') {
                let part = part.trim();
                let (r, f) = match part.split_once('x') {
                    Some(rf) => rf,
                    None => bail!("bad class pair {part:?} in {spec:?} (expected RATExFRACTION)"),
                };
                let rate: f64 = r.trim().parse().map_err(|_| {
                    anyhow::anyhow!("bad capacity rate {r:?} in {spec:?}")
                })?;
                let fraction: f64 = f.trim().parse().map_err(|_| {
                    anyhow::anyhow!("bad class fraction {f:?} in {spec:?}")
                })?;
                pairs.push((rate, fraction));
            }
            CapacityProfile::mixed(pairs)
        }
        other => bail!(
            "unknown capacity profile {other:?} \
             (full | uniform:rate | classes:r1xf1,r2xf2,...)"
        ),
    }
}

/// Resolve a config's optional spelling: `None` means the pinned `full`
/// default.
pub fn resolve(spec: Option<&str>) -> Result<CapacityProfile> {
    match spec {
        None => Ok(CapacityProfile::full()),
        Some(s) => parse(s),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parses_every_canonical_spelling() {
        for spec in CAPACITY_SPECS {
            let p = parse(spec).unwrap();
            assert!(!p.classes().is_empty(), "{spec}");
            // Canonical spellings round-trip through spec() → parse().
            assert_eq!(parse(&p.spec()).unwrap(), p, "{spec}");
        }
    }

    #[test]
    fn registry_rejects_unknown_and_malformed() {
        assert!(parse("bogus").is_err());
        assert!(parse("full:1").is_err());
        assert!(parse("uniform").is_err());
        assert!(parse("uniform:").is_err());
        assert!(parse("uniform:x").is_err());
        assert!(parse("uniform:0").is_err());
        assert!(parse("uniform:1.5").is_err());
        assert!(parse("uniform:-0.5").is_err());
        assert!(parse("classes").is_err());
        assert!(parse("classes:").is_err());
        assert!(parse("classes:1.0").is_err());
        assert!(parse("classes:1.0x").is_err());
        assert!(parse("classes:1.0x0.5,1.0x0.5").is_err());
        assert!(parse("classes:0x0.5").is_err());
        assert!(parse("classes:0.5x0").is_err());
        assert!(parse("classes:0.5x-1").is_err());
    }

    #[test]
    fn full_and_uniform_one_are_trivial() {
        assert!(parse("full").unwrap().is_trivial());
        assert!(parse("uniform:1.0").unwrap().is_trivial());
        assert!(!parse("uniform:0.5").unwrap().is_trivial());
        assert!(resolve(None).unwrap().is_trivial());
    }

    #[test]
    fn fractions_normalize_to_one() {
        let p = parse("classes:1.0x2,0.5x1,0.25x1").unwrap();
        let sum: f64 = p.classes().iter().map(|c| c.fraction).sum();
        assert!((sum - 1.0).abs() < 1e-12, "{sum}");
        assert!((p.classes()[0].fraction - 0.5).abs() < 1e-12);
    }

    #[test]
    fn labels_come_from_rates() {
        let p = parse("classes:1.0x0.5,0.5x0.3,0.25x0.2").unwrap();
        let labels: Vec<&str> = p.classes().iter().map(|c| c.label.as_str()).collect();
        assert_eq!(labels, vec!["r1", "r0.5", "r0.25"]);
    }

    #[test]
    fn single_class_assignment_makes_no_draws() {
        let root = Rng::new(42);
        let a = parse("full").unwrap().assign(100, &root);
        let b = parse("uniform:0.5").unwrap().assign(100, &root);
        assert!(a.iter().all(|&c| c == 0));
        assert!(b.iter().all(|&c| c == 0));
    }

    #[test]
    fn assignment_is_deterministic_in_the_root_seed() {
        let p = parse("classes:1.0x0.5,0.25x0.5").unwrap();
        let a = p.assign(1000, &Rng::new(7));
        let b = p.assign(1000, &Rng::new(7));
        let c = p.assign(1000, &Rng::new(8));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn assignment_fractions_track_the_profile() {
        let p = parse("classes:1.0x0.5,0.5x0.3,0.25x0.2").unwrap();
        let assign = p.assign(10_000, &Rng::new(3));
        let mut counts = [0usize; 3];
        for &c in &assign {
            counts[c as usize] += 1;
        }
        assert!((4500..5500).contains(&counts[0]), "{counts:?}");
        assert!((2500..3500).contains(&counts[1]), "{counts:?}");
        assert!((1500..2500).contains(&counts[2]), "{counts:?}");
    }
}
