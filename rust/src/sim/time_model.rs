//! The paper's Sec. II-C time model.
//!
//! All durations are integer ticks (1 tick = 1 ms of modelled time by
//! convention; only ratios matter). The model exposes the paper's three
//! primitives — download `τ^d`, per-local-step compute `τ`, TDMA upload
//! `τ^u` — plus the analytic round/sweep formulas used by the Fig. 2
//! comparison and verified against the simulator in tests.

/// Virtual time unit: integer ticks (1 tick ≈ 1 ms of modelled time by
/// convention; only ratios matter).
pub type Ticks = u64;

/// Communication + computation time parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TimeModel {
    /// Time to send the global model to a client (`τ^d`).
    pub tau_down: Ticks,
    /// Compute time of ONE local SGD step on the *fastest* hardware class.
    /// A full local round of `E` steps on client m costs
    /// `E * tau_step * a_m` (a_m from the heterogeneity profile).
    pub tau_step: Ticks,
    /// TDMA upload slot length (`τ^u`).
    pub tau_up: Ticks,
}

impl Default for TimeModel {
    fn default() -> Self {
        // Communication-heavier-than-one-step regime, as in the paper's
        // discussion (uploads dominate unless a client is very slow).
        TimeModel {
            tau_down: 50,
            tau_step: 10,
            tau_up: 100,
        }
    }
}

impl TimeModel {
    /// `τ` for a client: E local steps at speed factor a. (Scenario
    /// compute scaling — `sim::scenario` — multiplies into the factor
    /// before this rounding, never after.)
    pub fn compute_time(&self, local_steps: usize, factor: f64) -> Ticks {
        let t = (local_steps as f64) * (self.tau_step as f64) * factor;
        t.round().max(1.0) as Ticks
    }

    /// SFL homogeneous round: `τ^d + τ + M·τ^u` (Sec. II-C).
    pub fn sfl_round_homogeneous(&self, m: usize, local_steps: usize) -> Ticks {
        self.tau_down + self.compute_time(local_steps, 1.0) + m as Ticks * self.tau_up
    }

    /// SFL heterogeneous round: `τ^d + a·τ + M·τ^u` with `a` the slowest
    /// client's factor.
    pub fn sfl_round_heterogeneous(
        &self,
        m: usize,
        local_steps: usize,
        slowest_factor: f64,
    ) -> Ticks {
        self.tau_down
            + self.compute_time(local_steps, slowest_factor)
            + m as Ticks * self.tau_up
    }

    /// AFL homogeneous full sweep: `M·τ^u + M·τ^d + τ` (Sec. II-C).
    pub fn afl_sweep_homogeneous(&self, m: usize, local_steps: usize) -> Ticks {
        m as Ticks * self.tau_up
            + m as Ticks * self.tau_down
            + self.compute_time(local_steps, 1.0)
    }

    /// AFL steady-state inter-aggregation gap: `τ^u + τ^d`.
    pub fn afl_update_interval(&self) -> Ticks {
        self.tau_up + self.tau_down
    }
}

/// The single TDMA uplink: one model upload at a time.
#[derive(Debug, Clone, Default)]
pub struct UplinkChannel {
    busy_until: Ticks,
}

impl UplinkChannel {
    /// An idle channel.
    pub fn new() -> Self {
        UplinkChannel { busy_until: 0 }
    }

    /// Whether the channel is idle at virtual time `now`.
    pub fn is_free(&self, now: Ticks) -> bool {
        now >= self.busy_until
    }

    /// The virtual time the current reservation ends (0 when never used).
    pub fn busy_until(&self) -> Ticks {
        self.busy_until
    }

    /// Reserve the channel from `now` for `dur` ticks; returns completion
    /// time. Panics if the channel is busy — callers must check first.
    pub fn reserve(&mut self, now: Ticks, dur: Ticks) -> Ticks {
        assert!(self.is_free(now), "uplink busy until {}", self.busy_until);
        self.busy_until = now + dur;
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TM: TimeModel = TimeModel {
        tau_down: 50,
        tau_step: 10,
        tau_up: 100,
    };

    #[test]
    fn sfl_round_formula() {
        // τ^d + E·τ_step + M·τ^u = 50 + 16*10 + 20*100 = 2210
        assert_eq!(TM.sfl_round_homogeneous(20, 16), 2210);
    }

    #[test]
    fn sfl_heterogeneous_uses_slowest() {
        // 50 + 4*16*10 + 20*100 = 2690
        assert_eq!(TM.sfl_round_heterogeneous(20, 16, 4.0), 2690);
        assert!(TM.sfl_round_heterogeneous(20, 16, 4.0) > TM.sfl_round_homogeneous(20, 16));
    }

    #[test]
    fn afl_sweep_formula() {
        // M·τ^u + M·τ^d + τ = 2000 + 1000 + 160 = 3160
        assert_eq!(TM.afl_sweep_homogeneous(20, 16), 3160);
        // The paper's observation: AFL sweep costs (M-1)·τ^d more than SFL.
        assert_eq!(
            TM.afl_sweep_homogeneous(20, 16) - TM.sfl_round_homogeneous(20, 16),
            19 * TM.tau_down
        );
    }

    #[test]
    fn afl_updates_more_frequently() {
        assert!(TM.afl_update_interval() < TM.sfl_round_homogeneous(20, 16));
        assert_eq!(TM.afl_update_interval(), 150);
    }

    #[test]
    fn compute_time_scales_and_floors() {
        assert_eq!(TM.compute_time(16, 1.0), 160);
        assert_eq!(TM.compute_time(16, 2.5), 400);
        assert_eq!(TM.compute_time(0, 1.0), 1, "floored at one tick");
    }

    #[test]
    fn channel_reservation() {
        let mut ch = UplinkChannel::new();
        assert!(ch.is_free(0));
        let done = ch.reserve(10, 100);
        assert_eq!(done, 110);
        assert!(!ch.is_free(50));
        assert!(ch.is_free(110));
    }

    #[test]
    #[should_panic]
    fn channel_rejects_double_booking() {
        let mut ch = UplinkChannel::new();
        ch.reserve(0, 100);
        ch.reserve(50, 100);
    }
}
