//! Deterministic discrete-event queue.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::time_model::Ticks;

/// A time-ordered event queue. Ties are broken by insertion sequence so
/// simulation runs are exactly reproducible.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(Ticks, u64, EventBox<E>)>>,
    now: Ticks,
    seq: u64,
}

/// Wrapper to keep the heap ordering independent of the payload.
#[derive(Debug)]
struct EventBox<E>(E);

impl<E> PartialEq for EventBox<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EventBox<E> {}
impl<E> PartialOrd for EventBox<E> {
    fn partial_cmp(&self, _: &Self) -> Option<std::cmp::Ordering> {
        Some(std::cmp::Ordering::Equal)
    }
}
impl<E> Ord for EventBox<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    /// An empty queue at virtual time 0.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> Ticks {
        self.now
    }

    /// Number of scheduled, not-yet-popped events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events remain.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `at` (>= now).
    pub fn schedule_at(&mut self, at: Ticks, event: E) {
        debug_assert!(at >= self.now, "scheduling into the past");
        self.heap.push(Reverse((at, self.seq, EventBox(event))));
        self.seq += 1;
    }

    /// Schedule `event` `delay` ticks from now.
    pub fn schedule_in(&mut self, delay: Ticks, event: E) {
        self.schedule_at(self.now + delay, event);
    }

    /// Pop the earliest event, advancing virtual time to its timestamp.
    pub fn pop(&mut self) -> Option<(Ticks, E)> {
        let Reverse((at, _, EventBox(e))) = self.heap.pop()?;
        self.now = at;
        Some((at, e))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<Ticks> {
        self.heap.peek().map(|Reverse((at, _, _))| *at)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(30, "c");
        q.schedule_at(10, "a");
        q.schedule_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.now(), 10);
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule_at(5, 1);
        q.schedule_at(5, 2);
        q.schedule_at(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(10, "x");
        q.pop();
        q.schedule_in(5, "y");
        assert_eq!(q.pop(), Some((15, "y")));
    }

    #[test]
    fn peek_does_not_advance() {
        let mut q = EventQueue::new();
        q.schedule_at(7, ());
        assert_eq!(q.peek_time(), Some(7));
        assert_eq!(q.now(), 0);
    }
}
