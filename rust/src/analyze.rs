//! Post-hoc analysis of stored figure/run records (`results/*.json`)
//! and ordered trace files (`--trace` JSONL): the paper-facing
//! comparison tables — early-stage acceleration, time-to-target-
//! accuracy, final gaps, fairness — plus the `repro trace` summarizer
//! that reconstructs staleness timelines and fairness tables from a
//! recorded event stream.

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::metrics::{ClassMetrics, EvalPoint, RunResult};
use crate::telemetry::{jain_fairness, Histogram};
use crate::util::json::{self, Json};

/// Reload a RunResult from its JSON record (inverse of `to_json`).
pub fn run_from_json(j: &Json) -> Result<RunResult> {
    let label = j
        .get("label")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("run record: missing label"))?
        .to_string();
    let mut run = RunResult::empty(&label);
    run.aggregations = j.get("aggregations").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.mean_staleness = j.get("mean_staleness").and_then(Json::as_f64).unwrap_or(0.0);
    run.fairness = j.get("fairness").and_then(Json::as_f64).unwrap_or(1.0);
    run.lost_uploads = j.get("lost_uploads").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.mean_train_loss = j.get("mean_train_loss").and_then(Json::as_f64).unwrap_or(0.0);
    run.total_ticks = j.get("total_ticks").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.wallclock_secs = j.get("wallclock_secs").and_then(Json::as_f64).unwrap_or(0.0);
    // Present only on traced records (the key is omitted otherwise).
    run.telemetry = j.get("telemetry").cloned();
    run.uploads_per_client = j
        .get("uploads_per_client")
        .and_then(Json::as_array)
        .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as u64).collect())
        .unwrap_or_default();
    run.lost_per_client = j
        .get("lost_per_client")
        .and_then(Json::as_array)
        .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as u64).collect())
        .unwrap_or_default();
    // Present only on heterogeneous-capacity records (the key is
    // omitted entirely under the trivial profile).
    if let Some(cells) = j.get("classes").and_then(Json::as_array) {
        for c in cells {
            run.classes.push(ClassMetrics {
                label: c
                    .get("label")
                    .and_then(Json::as_str)
                    .unwrap_or("?")
                    .to_string(),
                rate: c.get("rate").and_then(Json::as_f64).unwrap_or(0.0),
                clients: c.get("clients").and_then(Json::as_i64).unwrap_or(0) as usize,
                uploads: c.get("uploads").and_then(Json::as_i64).unwrap_or(0) as u64,
                lost_uploads: c.get("lost_uploads").and_then(Json::as_i64).unwrap_or(0) as u64,
                mean_train_loss: c
                    .get("mean_train_loss")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
                accuracy: c.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
                loss: c.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
    }
    for p in j
        .get("points")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("run record: missing points"))?
    {
        run.points.push(EvalPoint {
            slot: p.get("slot").and_then(Json::as_f64).unwrap_or(0.0),
            ticks: p.get("ticks").and_then(Json::as_i64).unwrap_or(0) as u64,
            iteration: p.get("iteration").and_then(Json::as_i64).unwrap_or(0) as u64,
            accuracy: p.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
            loss: p.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    Ok(run)
}

/// Load every run from a figure record (`results/figN.json`).
pub fn load_figure_record(path: &str) -> Result<(String, Vec<RunResult>)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    let title = j
        .get("title")
        .and_then(Json::as_str)
        .unwrap_or("(untitled)")
        .to_string();
    let runs = j
        .get("runs")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("{path}: missing runs"))?
        .iter()
        .map(run_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok((title, runs))
}

/// Mean accuracy over a slot window.
pub fn window_accuracy(r: &RunResult, lo: f64, hi: f64) -> f64 {
    let pts: Vec<f64> = r
        .points
        .iter()
        .filter(|p| p.slot >= lo && p.slot <= hi)
        .map(|p| p.accuracy)
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    pts.iter().sum::<f64>() / pts.len() as f64
}

/// The per-figure comparison table the paper's prose walks through.
pub fn figure_table(title: &str, runs: &[RunResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fed = runs.iter().find(|r| r.label == "fedavg");
    let fed_final = fed.map_or(0.0, |r| r.final_accuracy());
    let target = 0.8 * fed_final;
    out.push_str(&format!(
        "{:<18} {:>10} {:>10} {:>10} {:>16} {:>12}\n",
        "series", "early(1-5)", "final", "best", "slots-to-80%fed", "staleness"
    ));
    for r in runs {
        let tta = r
            .slots_to_accuracy(target)
            .map(|s| format!("{s:.0}"))
            .unwrap_or_else(|| "never".into());
        out.push_str(&format!(
            "{:<18} {:>10.4} {:>10.4} {:>10.4} {:>16} {:>12.2}\n",
            r.label,
            window_accuracy(r, 1.0, 5.0),
            r.final_accuracy(),
            r.best_accuracy(),
            tta,
            r.mean_staleness,
        ));
    }
    // Per-capacity-class bias breakdown (heterogeneous-capacity runs
    // only): how each class participated and how well the final global
    // serves its own data.
    for r in runs.iter().filter(|r| !r.classes.is_empty()) {
        out.push_str(&format!(
            "{:<18} {:>6} {:>8} {:>8} {:>6} {:>10} {:>10}\n",
            format!("  {} classes", r.label),
            "rate",
            "clients",
            "uploads",
            "lost",
            "class-acc",
            "class-loss"
        ));
        for c in &r.classes {
            out.push_str(&format!(
                "{:<18} {:>6} {:>8} {:>8} {:>6} {:>10.4} {:>10.4}\n",
                format!("  {}", c.label),
                c.rate,
                c.clients,
                c.uploads,
                c.lost_uploads,
                c.accuracy,
                c.loss
            ));
        }
    }
    if let Some(fed) = fed {
        let best_early = runs
            .iter()
            .filter(|r| r.label != "fedavg")
            .map(|r| window_accuracy(r, 1.0, 5.0))
            .fold(0.0, f64::max);
        out.push_str(&format!(
            "early-stage: best csmaafl {:.4} vs fedavg {:.4} ({})\n",
            best_early,
            window_accuracy(fed, 1.0, 5.0),
            if best_early > window_accuracy(fed, 1.0, 5.0) {
                "CSMAAFL accelerates — matches the paper"
            } else {
                "no acceleration in this run"
            }
        ));
    }
    out
}

/// Aggregated view of one ordered trace file (`--trace` JSONL): the
/// `repro trace` subcommand's data model. Built by [`summarize_trace`],
/// which doubles as the `--check` validator — every line must parse and
/// carry its event kind's exact field set, or the summarizer errors
/// with the offending 1-based line number.
pub struct TraceSummary {
    /// Total trace lines (= events).
    pub events: u64,
    /// Per-kind event counts, keyed by the wire `ev` tag.
    pub kind_counts: BTreeMap<String, u64>,
    /// Staleness histogram across `apply` events.
    pub staleness: Histogram,
    /// Queue-depth histogram across `grant` events.
    pub queue_depth: Histogram,
    /// Grant count per client (grown to the largest id seen).
    pub grants_per_client: Vec<u64>,
    /// Grant count per fading gain level (`sim::channel::GAIN_LADDER`).
    pub grants_per_level: [u64; 4],
    /// Grants issued under the ideal channel (`level: -1`).
    pub grants_ideal: u64,
    /// Lost uploads by cause: `[scenario, channel, disconnect]`.
    pub lost_by_cause: [u64; 3],
    /// Final arena high-water mark (0 when the engine has no arena).
    pub arena_high: u64,
    /// `(t, staleness)` per apply, in trace order — the timeline's
    /// raw material.
    applies: Vec<(u64, u64)>,
    /// Largest timestamp seen across all events.
    pub t_max: u64,
}

impl TraceSummary {
    /// Jain fairness index over the per-client grant counts.
    pub fn grant_fairness(&self) -> f64 {
        jain_fairness(&self.grants_per_client)
    }

    /// Mean staleness over `buckets` equal time windows:
    /// `(window_end_t, mean_staleness, applies_in_window)` per bucket.
    pub fn timeline(&self, buckets: usize) -> Vec<(u64, f64, u64)> {
        let buckets = buckets.max(1);
        let width = (self.t_max / buckets as u64).max(1);
        let mut sums = vec![0u64; buckets];
        let mut counts = vec![0u64; buckets];
        for &(t, s) in &self.applies {
            let b = ((t / width) as usize).min(buckets - 1);
            sums[b] += s;
            counts[b] += 1;
        }
        (0..buckets)
            .map(|b| {
                let mean = if counts[b] == 0 {
                    0.0
                } else {
                    sums[b] as f64 / counts[b] as f64
                };
                ((b as u64 + 1) * width, mean, counts[b])
            })
            .collect()
    }
}

/// Parse and aggregate an ordered trace file (the JSONL written by
/// `--trace`). Strict by design: any unparseable line, unknown event
/// kind, missing field, or out-of-range value is an error naming the
/// offending line — `repro trace --check` is exactly this call.
pub fn summarize_trace(text: &str) -> Result<TraceSummary> {
    let mut s = TraceSummary {
        events: 0,
        kind_counts: BTreeMap::new(),
        staleness: Histogram::new(),
        queue_depth: Histogram::new(),
        grants_per_client: Vec::new(),
        grants_per_level: [0; 4],
        grants_ideal: 0,
        lost_by_cause: [0; 3],
        arena_high: 0,
        applies: Vec::new(),
        t_max: 0,
    };
    for (idx, line) in text.lines().enumerate() {
        let no = idx + 1;
        let j = json::parse(line).map_err(|e| anyhow!("trace line {no}: {e}"))?;
        let kind = j
            .get("ev")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("trace line {no}: missing ev tag"))?
            .to_string();
        let geti = |key: &str| -> Result<i64> {
            j.get(key)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("trace line {no}: {kind} event missing {key}"))
        };
        let getf = |key: &str| -> Result<f64> {
            j.get(key)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace line {no}: {kind} event missing {key}"))
        };
        match kind.as_str() {
            "class" => {
                geti("client")?;
                geti("class")?;
            }
            "channel" => {
                let t = geti("t")? as u64;
                geti("client")?;
                geti("level")?;
                s.t_max = s.t_max.max(t);
            }
            "grant" => {
                let t = geti("t")? as u64;
                let client = geti("client")? as usize;
                let queue = geti("queue")? as u64;
                let level = geti("level")?;
                if client >= s.grants_per_client.len() {
                    s.grants_per_client.resize(client + 1, 0);
                }
                s.grants_per_client[client] += 1;
                s.queue_depth.record(queue);
                match level {
                    -1 => s.grants_ideal += 1,
                    0..=3 => s.grants_per_level[level as usize] += 1,
                    _ => return Err(anyhow!("trace line {no}: grant level {level} out of range")),
                }
                s.t_max = s.t_max.max(t);
            }
            "apply" => {
                let t = geti("t")? as u64;
                geti("client")?;
                geti("iter")?;
                let stale = geti("stale")? as u64;
                getf("beta")?;
                getf("weight")?;
                s.staleness.record(stale);
                s.applies.push((t, stale));
                s.t_max = s.t_max.max(t);
            }
            "lost" => {
                let t = geti("t")? as u64;
                geti("client")?;
                let cause = j
                    .get("cause")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("trace line {no}: lost event missing cause"))?;
                let slot = match cause {
                    "scenario" => 0,
                    "channel" => 1,
                    "disconnect" => 2,
                    other => {
                        return Err(anyhow!("trace line {no}: unknown loss cause {other:?}"))
                    }
                };
                s.lost_by_cause[slot] += 1;
                s.t_max = s.t_max.max(t);
            }
            "arena" => {
                let t = geti("t")? as u64;
                let high = geti("high")? as u64;
                s.arena_high = s.arena_high.max(high);
                s.t_max = s.t_max.max(t);
            }
            other => return Err(anyhow!("trace line {no}: unknown event kind {other:?}")),
        }
        *s.kind_counts.entry(kind).or_insert(0) += 1;
        s.events += 1;
    }
    Ok(s)
}

/// Render the `repro trace` report: event counts, upload outcomes,
/// fairness, staleness aggregates and the bucketed staleness timeline.
pub fn trace_table(s: &TraceSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!("== trace: {} events ==\n", s.events));
    for (k, n) in &s.kind_counts {
        out.push_str(&format!("  {k:<8} {n:>10}\n"));
    }
    let applied = s.staleness.count();
    let lost: u64 = s.lost_by_cause.iter().sum();
    out.push_str(&format!(
        "uploads: {} applied, {} lost (scenario {}, channel {}, disconnect {})\n",
        applied, lost, s.lost_by_cause[0], s.lost_by_cause[1], s.lost_by_cause[2]
    ));
    out.push_str(&format!(
        "staleness: mean {:.2}, max {}\n",
        s.staleness.mean(),
        s.staleness.max()
    ));
    out.push_str(&format!(
        "queue depth at grant: mean {:.2}, max {}\n",
        s.queue_depth.mean(),
        s.queue_depth.max()
    ));
    let grants: u64 = s.grants_per_client.iter().sum();
    out.push_str(&format!(
        "grants: {} across {} clients, jain {:.4}\n",
        grants,
        s.grants_per_client.len(),
        s.grant_fairness()
    ));
    if s.grants_per_level.iter().any(|&n| n > 0) {
        out.push_str(&format!("grants per gain level: {:?}\n", s.grants_per_level));
    }
    if s.arena_high > 0 {
        out.push_str(&format!("arena high-water: {}\n", s.arena_high));
    }
    if !s.applies.is_empty() {
        out.push_str("staleness timeline (t<=, mean, applies):\n");
        for (t, mean, n) in s.timeline(10) {
            out.push_str(&format!("  {t:>12} {mean:>8.2} {n:>8}\n"));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_run(label: &str, accs: &[f64]) -> RunResult {
        let mut r = RunResult::empty(label);
        r.points = accs
            .iter()
            .enumerate()
            .map(|(i, &a)| EvalPoint {
                slot: i as f64,
                ticks: 100 * i as u64,
                iteration: i as u64,
                accuracy: a,
                loss: 1.0,
            })
            .collect();
        r
    }

    #[test]
    fn json_record_roundtrip() {
        let mut r = fake_run("x", &[0.1, 0.5, 0.9]);
        r.lost_uploads = 3;
        r.lost_per_client = vec![1, 2];
        let back = run_from_json(&r.to_json()).unwrap();
        assert_eq!(back.label, "x");
        assert_eq!(back.points.len(), 3);
        assert_eq!(back.points[2].accuracy, 0.9);
        assert_eq!(back.lost_uploads, 3);
        assert_eq!(back.lost_per_client, vec![1, 2]);
    }

    #[test]
    fn class_cells_roundtrip_and_render() {
        let mut r = fake_run("csmaafl", &[0.1, 0.6]);
        r.classes.push(ClassMetrics {
            label: "r0.25".into(),
            rate: 0.25,
            clients: 5,
            uploads: 40,
            lost_uploads: 2,
            mean_train_loss: 0.9,
            accuracy: 0.44,
            loss: 1.6,
        });
        let back = run_from_json(&r.to_json()).unwrap();
        assert_eq!(back.classes.len(), 1);
        assert_eq!(back.classes[0].label, "r0.25");
        assert_eq!(back.classes[0].clients, 5);
        assert_eq!(back.classes[0].accuracy, 0.44);
        let table = figure_table("t", std::slice::from_ref(&back));
        assert!(table.contains("r0.25"), "{table}");
        assert!(table.contains("0.4400"), "{table}");
        // Trivial-profile runs render no class block.
        let plain = figure_table("t", &[fake_run("fedavg", &[0.1])]);
        assert!(!plain.contains("classes"), "{plain}");
    }

    #[test]
    fn trace_summary_aggregates_and_renders() {
        let text = concat!(
            "{\"ev\":\"class\",\"client\":0,\"class\":1}\n",
            "{\"ev\":\"grant\",\"t\":5,\"client\":0,\"queue\":2,\"level\":-1}\n",
            "{\"ev\":\"apply\",\"t\":9,\"client\":0,\"iter\":1,\"stale\":0,",
            "\"beta\":0.8,\"weight\":0.2}\n",
            "{\"ev\":\"lost\",\"t\":12,\"client\":1,\"cause\":\"channel\"}\n",
            "{\"ev\":\"arena\",\"t\":3,\"high\":2}\n",
        );
        let s = summarize_trace(text).unwrap();
        assert_eq!(s.events, 5);
        assert_eq!(s.kind_counts.get("grant"), Some(&1));
        assert_eq!(s.staleness.count(), 1);
        assert_eq!(s.lost_by_cause, [0, 1, 0]);
        assert_eq!(s.grants_per_client, vec![1]);
        assert_eq!(s.grants_ideal, 1);
        assert_eq!(s.arena_high, 2);
        assert_eq!(s.t_max, 12);
        assert_eq!(s.grant_fairness(), 1.0);
        let table = trace_table(&s);
        assert!(table.contains("jain"), "{table}");
        assert!(table.contains("arena high-water: 2"), "{table}");
        assert!(table.contains("staleness timeline"), "{table}");
        // Ten timeline windows cover every apply exactly once.
        let covered: u64 = s.timeline(10).iter().map(|&(_, _, n)| n).sum();
        assert_eq!(covered, 1);
    }

    #[test]
    fn trace_summary_rejects_malformed_lines() {
        assert!(summarize_trace("not json\n").is_err());
        let err = summarize_trace("{\"ev\":\"mystery\"}\n").unwrap_err();
        assert!(err.to_string().contains("line 1"), "{err}");
        let err = summarize_trace("{\"ev\":\"grant\",\"t\":1,\"client\":0,\"queue\":0}\n")
            .unwrap_err();
        assert!(err.to_string().contains("missing level"), "{err}");
        let err = summarize_trace("{\"ev\":\"lost\",\"t\":1,\"client\":0,\"cause\":\"x\"}\n")
            .unwrap_err();
        assert!(err.to_string().contains("unknown loss cause"), "{err}");
    }

    #[test]
    fn telemetry_key_roundtrips_through_run_records() {
        let mut r = fake_run("x", &[0.1]);
        assert!(run_from_json(&r.to_json()).unwrap().telemetry.is_none());
        let mut reg = Json::object();
        reg.set("uploads_applied", Json::Int(5));
        r.telemetry = Some(reg);
        let back = run_from_json(&r.to_json()).unwrap();
        let t = back.telemetry.expect("telemetry survived the roundtrip");
        assert_eq!(t.get("uploads_applied").unwrap().as_i64(), Some(5));
    }

    #[test]
    fn window_and_table() {
        let fed = fake_run("fedavg", &[0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.8]);
        let csma = fake_run("csmaafl g=0.2", &[0.0, 0.4, 0.5, 0.6, 0.6, 0.6, 0.7]);
        assert!((window_accuracy(&csma, 1.0, 5.0) - 0.54).abs() < 1e-9);
        let table = figure_table("t", &[fed, csma]);
        assert!(table.contains("CSMAAFL accelerates"));
        assert!(table.contains("never") || table.contains("6"));
    }
}
