//! Post-hoc analysis of stored figure/run records (`results/*.json`):
//! the paper-facing comparison tables — early-stage acceleration,
//! time-to-target-accuracy, final gaps, fairness.

use anyhow::{anyhow, Context, Result};

use crate::metrics::{ClassMetrics, EvalPoint, RunResult};
use crate::util::json::{self, Json};

/// Reload a RunResult from its JSON record (inverse of `to_json`).
pub fn run_from_json(j: &Json) -> Result<RunResult> {
    let label = j
        .get("label")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("run record: missing label"))?
        .to_string();
    let mut run = RunResult::empty(&label);
    run.aggregations = j.get("aggregations").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.mean_staleness = j.get("mean_staleness").and_then(Json::as_f64).unwrap_or(0.0);
    run.fairness = j.get("fairness").and_then(Json::as_f64).unwrap_or(1.0);
    run.lost_uploads = j.get("lost_uploads").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.mean_train_loss = j.get("mean_train_loss").and_then(Json::as_f64).unwrap_or(0.0);
    run.total_ticks = j.get("total_ticks").and_then(Json::as_i64).unwrap_or(0) as u64;
    run.wallclock_secs = j.get("wallclock_secs").and_then(Json::as_f64).unwrap_or(0.0);
    run.uploads_per_client = j
        .get("uploads_per_client")
        .and_then(Json::as_array)
        .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as u64).collect())
        .unwrap_or_default();
    run.lost_per_client = j
        .get("lost_per_client")
        .and_then(Json::as_array)
        .map(|a| a.iter().filter_map(Json::as_i64).map(|v| v as u64).collect())
        .unwrap_or_default();
    // Present only on heterogeneous-capacity records (the key is
    // omitted entirely under the trivial profile).
    if let Some(cells) = j.get("classes").and_then(Json::as_array) {
        for c in cells {
            run.classes.push(ClassMetrics {
                label: c
                    .get("label")
                    .and_then(Json::as_str)
                    .unwrap_or("?")
                    .to_string(),
                rate: c.get("rate").and_then(Json::as_f64).unwrap_or(0.0),
                clients: c.get("clients").and_then(Json::as_i64).unwrap_or(0) as usize,
                uploads: c.get("uploads").and_then(Json::as_i64).unwrap_or(0) as u64,
                lost_uploads: c.get("lost_uploads").and_then(Json::as_i64).unwrap_or(0) as u64,
                mean_train_loss: c
                    .get("mean_train_loss")
                    .and_then(Json::as_f64)
                    .unwrap_or(0.0),
                accuracy: c.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
                loss: c.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
    }
    for p in j
        .get("points")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("run record: missing points"))?
    {
        run.points.push(EvalPoint {
            slot: p.get("slot").and_then(Json::as_f64).unwrap_or(0.0),
            ticks: p.get("ticks").and_then(Json::as_i64).unwrap_or(0) as u64,
            iteration: p.get("iteration").and_then(Json::as_i64).unwrap_or(0) as u64,
            accuracy: p.get("accuracy").and_then(Json::as_f64).unwrap_or(0.0),
            loss: p.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
        });
    }
    Ok(run)
}

/// Load every run from a figure record (`results/figN.json`).
pub fn load_figure_record(path: &str) -> Result<(String, Vec<RunResult>)> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let j = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
    let title = j
        .get("title")
        .and_then(Json::as_str)
        .unwrap_or("(untitled)")
        .to_string();
    let runs = j
        .get("runs")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("{path}: missing runs"))?
        .iter()
        .map(run_from_json)
        .collect::<Result<Vec<_>>>()?;
    Ok((title, runs))
}

/// Mean accuracy over a slot window.
pub fn window_accuracy(r: &RunResult, lo: f64, hi: f64) -> f64 {
    let pts: Vec<f64> = r
        .points
        .iter()
        .filter(|p| p.slot >= lo && p.slot <= hi)
        .map(|p| p.accuracy)
        .collect();
    if pts.is_empty() {
        return 0.0;
    }
    pts.iter().sum::<f64>() / pts.len() as f64
}

/// The per-figure comparison table the paper's prose walks through.
pub fn figure_table(title: &str, runs: &[RunResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let fed = runs.iter().find(|r| r.label == "fedavg");
    let fed_final = fed.map_or(0.0, |r| r.final_accuracy());
    let target = 0.8 * fed_final;
    out.push_str(&format!(
        "{:<18} {:>10} {:>10} {:>10} {:>16} {:>12}\n",
        "series", "early(1-5)", "final", "best", "slots-to-80%fed", "staleness"
    ));
    for r in runs {
        let tta = r
            .slots_to_accuracy(target)
            .map(|s| format!("{s:.0}"))
            .unwrap_or_else(|| "never".into());
        out.push_str(&format!(
            "{:<18} {:>10.4} {:>10.4} {:>10.4} {:>16} {:>12.2}\n",
            r.label,
            window_accuracy(r, 1.0, 5.0),
            r.final_accuracy(),
            r.best_accuracy(),
            tta,
            r.mean_staleness,
        ));
    }
    // Per-capacity-class bias breakdown (heterogeneous-capacity runs
    // only): how each class participated and how well the final global
    // serves its own data.
    for r in runs.iter().filter(|r| !r.classes.is_empty()) {
        out.push_str(&format!(
            "{:<18} {:>6} {:>8} {:>8} {:>6} {:>10} {:>10}\n",
            format!("  {} classes", r.label),
            "rate",
            "clients",
            "uploads",
            "lost",
            "class-acc",
            "class-loss"
        ));
        for c in &r.classes {
            out.push_str(&format!(
                "{:<18} {:>6} {:>8} {:>8} {:>6} {:>10.4} {:>10.4}\n",
                format!("  {}", c.label),
                c.rate,
                c.clients,
                c.uploads,
                c.lost_uploads,
                c.accuracy,
                c.loss
            ));
        }
    }
    if let Some(fed) = fed {
        let best_early = runs
            .iter()
            .filter(|r| r.label != "fedavg")
            .map(|r| window_accuracy(r, 1.0, 5.0))
            .fold(0.0, f64::max);
        out.push_str(&format!(
            "early-stage: best csmaafl {:.4} vs fedavg {:.4} ({})\n",
            best_early,
            window_accuracy(fed, 1.0, 5.0),
            if best_early > window_accuracy(fed, 1.0, 5.0) {
                "CSMAAFL accelerates — matches the paper"
            } else {
                "no acceleration in this run"
            }
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_run(label: &str, accs: &[f64]) -> RunResult {
        let mut r = RunResult::empty(label);
        r.points = accs
            .iter()
            .enumerate()
            .map(|(i, &a)| EvalPoint {
                slot: i as f64,
                ticks: 100 * i as u64,
                iteration: i as u64,
                accuracy: a,
                loss: 1.0,
            })
            .collect();
        r
    }

    #[test]
    fn json_record_roundtrip() {
        let mut r = fake_run("x", &[0.1, 0.5, 0.9]);
        r.lost_uploads = 3;
        r.lost_per_client = vec![1, 2];
        let back = run_from_json(&r.to_json()).unwrap();
        assert_eq!(back.label, "x");
        assert_eq!(back.points.len(), 3);
        assert_eq!(back.points[2].accuracy, 0.9);
        assert_eq!(back.lost_uploads, 3);
        assert_eq!(back.lost_per_client, vec![1, 2]);
    }

    #[test]
    fn class_cells_roundtrip_and_render() {
        let mut r = fake_run("csmaafl", &[0.1, 0.6]);
        r.classes.push(ClassMetrics {
            label: "r0.25".into(),
            rate: 0.25,
            clients: 5,
            uploads: 40,
            lost_uploads: 2,
            mean_train_loss: 0.9,
            accuracy: 0.44,
            loss: 1.6,
        });
        let back = run_from_json(&r.to_json()).unwrap();
        assert_eq!(back.classes.len(), 1);
        assert_eq!(back.classes[0].label, "r0.25");
        assert_eq!(back.classes[0].clients, 5);
        assert_eq!(back.classes[0].accuracy, 0.44);
        let table = figure_table("t", std::slice::from_ref(&back));
        assert!(table.contains("r0.25"), "{table}");
        assert!(table.contains("0.4400"), "{table}");
        // Trivial-profile runs render no class block.
        let plain = figure_table("t", &[fake_run("fedavg", &[0.1])]);
        assert!(!plain.contains("classes"), "{plain}");
    }

    #[test]
    fn window_and_table() {
        let fed = fake_run("fedavg", &[0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.8]);
        let csma = fake_run("csmaafl g=0.2", &[0.0, 0.4, 0.5, 0.6, 0.6, 0.6, 0.7]);
        assert!((window_accuracy(&csma, 1.0, 5.0) - 0.54).abs() < 1e-9);
        let table = figure_table("t", &[fed, csma]);
        assert!(table.contains("CSMAAFL accelerates"));
        assert!(table.contains("never") || table.contains("6"));
    }
}
