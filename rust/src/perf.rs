//! The pinned-seed performance suite behind `repro bench`: the repo's
//! perf trajectory as machine-readable `BENCH_<date>.json` records.
//!
//! Ten suites cover the hot paths this crate optimizes:
//!
//! | Suite         | Cases                              | What it measures |
//! |---------------|------------------------------------|------------------|
//! | `aggregation` | `lerp_<n>`, `arena_cycle_<n>`      | eq.-(3) flat kernel throughput; arena alloc/copy/free recycling |
//! | `kernels`     | `lerp_scalar_<n>`, `lerp_<n>`, `axpy_scalar_<n>`, `axpy_<n>`, `lerp_par4_<n>`, `l2_<n>` | every flat-kernel variant (`model::params`) head-to-head: the retained scalar references, the shipping dispatcher (chunked, or SSE2 under `--features simd`), the 4-thread parallel lerp, and the deliberately-scalar l2 chain |
//! | `scheduler`   | `<policy>_<m>`                     | request+grant drain of the heap/cursor fast paths |
//! | `event_loop`  | `sim_<m>_clients`                  | full coordinator event loop (`coordinator::scale`), ns per event |
//! | `end_to_end`  | `grid_2x_gamma`                    | tiny learner-driven grid through the `PlanRunner` |
//! | `sharded`     | `sim_<m>_shards1`, `sim_<m>_multi`, `speedup_multi_vs_1` | the sharded coordinator (`coordinator::shard`) at heavy synthetic training: ns per event single- vs multi-shard, plus their ratio (multi/single — dimensionless, < 1 means speedup) |
//! | `submodel`    | `extract_<n>`, `merge_<n>`, `merge_lerp_<n>` | heterogeneous-capacity slice kernels (`model::submodel`): rate-0.5 extract/merge over a flat buffer, plus the slice-wise eq.-(3) merge into a `ParamSet` |
//! | `net`         | `encode_<n>`, `decode_<n>`, `reader_chunked_<n>` | wire-protocol hot paths (`net::wire`): frame encode, shape-validated decode, and the leader's incremental `FrameReader` fed in socket-sized chunks |
//! | `channel`     | `gain_walk_<m>`, `delta_encode_<n>`, `delta_apply_<n>`, `sim_channel_aware_<m>` | the fading-channel subsystem (`sim::channel`): the per-grant gain refresh over a whole population, the XOR-bitpattern delta codec behind `DeltaUpdate` frames, and a full channel-aware event loop under `markov:0.5,500` — ns per event, so fading must not regress the hot loop |
//! | `telemetry`   | `noop_sink`, `event_encode`, `histogram_record` | the observability layer (`telemetry`): the disabled-handle cost every engine decision pays when `--trace` is off (must stay branch-cheap and allocation-free), the JSONL encode of the densest event, and one log2-bucket histogram update |
//!
//! The record schema (`csmaafl-bench-v1`) is
//! `suites → <suite> → <case> → {iters, ns_per_iter, clients}` plus
//! top-level `schema`, `date` and `quick` fields; `sharded` cases carry
//! an extra `shards` field (consumers must ignore unknown per-case
//! keys). Case *names and inputs* are pinned and deterministic; the
//! measured `ns_per_iter` values are, of course, machine-dependent —
//! except `speedup_multi_vs_1`, whose "ns_per_iter" holds the
//! multi/single wall-clock ratio so the regression gate bounds the
//! parallel path losing its advantage. [`check`] compares a fresh run
//! against a stored baseline and reports every case slower than
//! `factor ×` its baseline — the CI `perf-smoke` gate
//! (see `docs/BENCHMARKS.md`).

use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::RunConfig;
use crate::coordinator::{
    run_scale_sim, run_sharded_sim, ScaleSimConfig, SchedulerPolicy, UploadScheduler,
};
use crate::experiment::{Plan, PlanRunner};
use crate::model::{lerp_flat, ParamArena, ParamLayout, ParamSet, SubmodelMap, TensorSpec};
use crate::net::wire::{self, FrameReader, Message};
use crate::session::{LearnerKind, Session};
use crate::sim::channel;
use crate::util::bench::Bencher;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Schema tag every bench record carries (bumped on layout changes).
pub const BENCH_SCHEMA: &str = "csmaafl-bench-v1";

/// The suite names, in run order (the `--suite` filter vocabulary).
pub const SUITES: [&str; 10] = [
    "aggregation",
    "kernels",
    "scheduler",
    "event_loop",
    "end_to_end",
    "sharded",
    "submodel",
    "net",
    "channel",
    "telemetry",
];

/// How to run the suite.
#[derive(Debug, Clone, Default)]
pub struct BenchConfig {
    /// Shrink measurement windows and problem sizes (the CI setting).
    pub quick: bool,
    /// Run only this suite (must be one of [`SUITES`]); `None` = all.
    pub suite: Option<String>,
    /// Shard count of the `sharded` suite's multi-shard case; `None` =
    /// min(4, available cores).
    pub shards: Option<usize>,
}

/// One measured case, pre-JSON.
struct Case {
    name: String,
    iters: u64,
    ns_per_iter: f64,
    clients: u64,
    /// Shard-worker count, for `sharded`-suite cases only.
    shards: Option<u64>,
}

fn bencher(group: &str, quick: bool) -> Bencher {
    if quick {
        Bencher::new(group).with_window(Duration::from_millis(40), 200)
    } else {
        Bencher::new(group).with_window(Duration::from_millis(250), 2000)
    }
}

fn random_flat(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.normal()).collect()
}

fn suite_aggregation(quick: bool) -> Vec<Case> {
    let mut out = Vec::new();
    let mut b = bencher("aggregation", quick);
    // 5.4k = the mnist_small CNN, 431k ≈ the paper's full CNN.
    for &n in &[5_370usize, 431_080] {
        let mut acc = random_flat(n, 1);
        let local = random_flat(n, 2);
        let r = b.bench(&format!("lerp_{n}"), || {
            lerp_flat(&mut acc, &local, 0.9);
        });
        out.push(Case {
            name: format!("lerp_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
    }
    // Steady-state arena recycling: alloc + flat copy-in + free.
    let n = 5_370usize;
    let spec = TensorSpec {
        name: "w".into(),
        shape: vec![n],
    };
    let layout = ParamLayout::new(vec![spec]);
    let src = ParamSet::from_flat(&layout, &random_flat(n, 3));
    let mut arena = ParamArena::new(layout);
    let r = b.bench(&format!("arena_cycle_{n}"), || {
        let slot = arena.alloc_from_set(&src);
        arena.free(slot);
    });
    out.push(Case {
        name: format!("arena_cycle_{n}"),
        iters: r.iters,
        ns_per_iter: r.mean_ns,
        clients: 0,
        shards: None,
    });
    out
}

/// The `kernels` suite: the flat-kernel variants of `model::params`
/// head-to-head at the two pinned model sizes. `lerp_<n>`/`axpy_<n>`
/// measure the shipping dispatcher (the chunked loops, or the SSE2
/// path under `--features simd`), `*_scalar_<n>` the retained
/// references, `lerp_par4_<n>` the 4-thread parallel lerp (thread
/// count pinned so the case name is machine-independent), and `l2_<n>`
/// the deliberately-scalar f64 distance chain. Every variant is
/// bit-identical to its reference by the `rust/tests/properties.rs`
/// harness, so this suite is pure throughput — the vectorization win
/// recorded as a ratio against the scalar rows.
fn suite_kernels(quick: bool) -> Vec<Case> {
    use crate::model::{axpy_flat, axpy_flat_scalar, l2_accumulate, lerp_flat_par, lerp_flat_scalar};
    let mut out = Vec::new();
    let mut b = bencher("kernels", quick);
    let mut push = |name: String, r: &crate::util::bench::CaseResult| {
        out.push(Case {
            name,
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
    };
    for &n in &[5_370usize, 431_080] {
        let mut acc = random_flat(n, 21);
        let other = random_flat(n, 22);
        let name = format!("lerp_scalar_{n}");
        let r = b.bench(&name, || lerp_flat_scalar(&mut acc, &other, 0.9));
        push(name, r);
        let name = format!("lerp_{n}");
        let r = b.bench(&name, || lerp_flat(&mut acc, &other, 0.9));
        push(name, r);
        let name = format!("axpy_scalar_{n}");
        let r = b.bench(&name, || axpy_flat_scalar(&mut acc, &other, 0.25));
        push(name, r);
        let name = format!("axpy_{n}");
        let r = b.bench(&name, || axpy_flat(&mut acc, &other, 0.25));
        push(name, r);
        let name = format!("lerp_par4_{n}");
        let r = b.bench(&name, || lerp_flat_par(&mut acc, &other, 0.9, 4));
        push(name, r);
        let name = format!("l2_{n}");
        let r = b.bench(&name, || {
            let mut d = 0.0f64;
            l2_accumulate(&mut d, std::hint::black_box(&acc), &other);
            std::hint::black_box(d);
        });
        push(name, r);
    }
    out
}

fn suite_scheduler(quick: bool) -> Vec<Case> {
    let mut out = Vec::new();
    let mut b = bencher("scheduler", quick);
    let mut cases: Vec<(SchedulerPolicy, usize)> = vec![
        (SchedulerPolicy::OldestModelFirst, 1_000),
        (SchedulerPolicy::OldestModelFirst, 100_000),
        (SchedulerPolicy::Fifo, 100_000),
        (SchedulerPolicy::RoundRobin, 100_000),
    ];
    if !quick {
        cases.push((SchedulerPolicy::OldestModelFirst, 1_000_000));
    }
    for (policy, m) in cases {
        let name = format!("{}_{m}", policy.name());
        let r = b.bench(&name, || {
            let mut s = UploadScheduler::new(policy, m);
            for c in 0..m {
                s.request(c, c as u64);
            }
            while s.grant().is_some() {}
        });
        out.push(Case {
            name,
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: m as u64,
            shards: None,
        });
    }
    out
}

fn suite_event_loop(quick: bool) -> Result<Vec<Case>> {
    let clients = if quick { 10_000 } else { 50_000 };
    let cfg = ScaleSimConfig {
        clients,
        iterations: clients as u64,
        params: 32,
        ..ScaleSimConfig::default()
    };
    let r = run_scale_sim(&cfg)?;
    Ok(vec![Case {
        name: format!("sim_{clients}_clients"),
        iters: r.events,
        ns_per_iter: r.wall_secs * 1e9 / r.events.max(1) as f64,
        clients: clients as u64,
        shards: None,
    }])
}

fn suite_end_to_end(quick: bool) -> Result<Vec<Case>> {
    let cfg = RunConfig {
        clients: 4,
        samples_per_client: 20,
        test_samples: 50,
        local_steps: 2,
        max_slots: if quick { 1.0 } else { 2.0 },
        ..RunConfig::default()
    };
    let session = Session::new(cfg, LearnerKind::Linear, "artifacts")?;
    let plan = Plan::new().axis("gamma", vec!["0.1".to_string(), "0.4".to_string()]);
    let t0 = Instant::now();
    let runs = PlanRunner::new(&session).jobs(2).run(&plan)?;
    let ns = t0.elapsed().as_nanos() as f64;
    ensure!(runs.len() == 2, "grid produced {} runs", runs.len());
    Ok(vec![Case {
        name: "grid_2x_gamma".into(),
        iters: runs.len() as u64,
        ns_per_iter: ns / runs.len() as f64,
        clients: 4,
        shards: None,
    }])
}

/// The `sharded` suite: the same pinned scale-sim config on 1 shard
/// worker vs `shards` workers, at `train_passes` heavy enough that the
/// parallelizable synthetic-training work dominates the sequential
/// aggregation stage. Also asserts the engines' deterministic summaries
/// agree — the bench would be meaningless if the fast path diverged.
fn suite_sharded(quick: bool, shards: usize) -> Result<Vec<Case>> {
    let clients = if quick { 5_000 } else { 20_000 };
    let cfg = ScaleSimConfig {
        clients,
        iterations: clients as u64,
        params: 64,
        train_passes: 8,
        ..ScaleSimConfig::default()
    };
    let single = run_sharded_sim(&cfg, 1)?;
    let multi = run_sharded_sim(&cfg, shards)?;
    ensure!(
        single.summary_json().to_string_compact() == multi.summary_json().to_string_compact(),
        "sharded determinism violated: 1-shard and {}-shard summaries differ",
        multi.shards
    );
    let ns = |r: &crate::coordinator::ScaleSimReport| r.wall_secs * 1e9 / r.events.max(1) as f64;
    Ok(vec![
        Case {
            name: format!("sim_{clients}_shards1"),
            iters: single.events,
            ns_per_iter: ns(&single),
            clients: clients as u64,
            shards: Some(1),
        },
        Case {
            name: format!("sim_{clients}_multi"),
            iters: multi.events,
            ns_per_iter: ns(&multi),
            clients: clients as u64,
            shards: Some(multi.shards as u64),
        },
        Case {
            // Dimensionless multi/single ratio in the ns_per_iter slot:
            // < 1 means the shards paid off; the --check gate bounds it
            // like any other case, so losing the speedup regresses CI.
            name: "speedup_multi_vs_1".into(),
            iters: 1,
            ns_per_iter: ns(&multi) / ns(&single).max(1e-9),
            clients: clients as u64,
            shards: Some(multi.shards as u64),
        },
    ])
}

/// The `submodel` suite: the heterogeneous-capacity slice kernels
/// (`model::submodel`) at the two pinned model sizes, rate 0.5 — the
/// mid-rate class of the canonical `classes:1.0x0.5,0.5x0.3,0.25x0.2`
/// profile. Two tensors so the per-tensor slice walk (not just one
/// memcpy) is what gets measured.
fn suite_submodel(quick: bool) -> Vec<Case> {
    let mut out = Vec::new();
    let mut b = bencher("submodel", quick);
    for &n in &[5_370usize, 431_080] {
        let layout = ParamLayout::new(vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![n - n / 8],
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![n / 8],
            },
        ]);
        let map = SubmodelMap::new(&layout, 0.5);
        let full = random_flat(n, 11);
        let mut sub = vec![0.0f32; map.numel()];
        let r = b.bench(&format!("extract_{n}"), || {
            map.extract_flat(std::hint::black_box(&full), &mut sub);
        });
        out.push(Case {
            name: format!("extract_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
        let mut dst = random_flat(n, 12);
        let r = b.bench(&format!("merge_{n}"), || {
            map.merge_flat(&mut dst, std::hint::black_box(&sub));
        });
        out.push(Case {
            name: format!("merge_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
        let mut global = ParamSet::from_flat(&layout, &random_flat(n, 13));
        let r = b.bench(&format!("merge_lerp_{n}"), || {
            map.merge_lerp_set(&mut global, std::hint::black_box(&sub), 0.9);
        });
        out.push(Case {
            name: format!("merge_lerp_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
    }
    out
}

/// The `net` suite: wire-protocol hot paths. Frame encode and
/// shape-validated decode at the two pinned model sizes, plus the
/// leader's incremental [`FrameReader`] fed in 4 KiB chunks — the shape
/// of work an ingest shard does per nonblocking socket sweep.
fn suite_net(quick: bool) -> Vec<Case> {
    let mut out = Vec::new();
    let mut b = bencher("net", quick);
    for &n in &[5_370usize, 431_080] {
        let layout = ParamLayout::new(vec![TensorSpec {
            name: "w".into(),
            shape: vec![n],
        }]);
        let params = ParamSet::from_flat(&layout, &random_flat(n, 7));
        let specs = params.specs();
        let msg = Message::Update {
            start_iteration: 3,
            steps: 4,
            params,
        };
        let frame = wire::encode(&msg);
        let r = b.bench(&format!("encode_{n}"), || {
            std::hint::black_box(wire::encode(std::hint::black_box(&msg)));
        });
        out.push(Case {
            name: format!("encode_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
        let body = &frame[4..];
        let r = b.bench(&format!("decode_{n}"), || {
            let m = wire::decode(std::hint::black_box(body), &specs).expect("legal frame");
            std::hint::black_box(&m);
        });
        out.push(Case {
            name: format!("decode_{n}"),
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
        if n == 5_370 {
            let r = b.bench(&format!("reader_chunked_{n}"), || {
                let mut rd = Chunked {
                    data: &frame,
                    pos: 0,
                };
                let mut fr = FrameReader::new();
                let got = fr.poll(&mut rd).expect("clean read").expect("one full frame");
                std::hint::black_box(&got);
            });
            out.push(Case {
                name: format!("reader_chunked_{n}"),
                iters: r.iters,
                ns_per_iter: r.mean_ns,
                clients: 0,
                shards: None,
            });
        }
    }
    out
}

/// The `telemetry` suite: the observability layer's per-decision costs.
/// `noop_sink` is what every instrumented engine decision pays when
/// `--trace` is off — one `is_enabled` branch per call, zero allocation
/// — so it must stay within noise of no instrumentation at all;
/// `event_encode` the JSONL encoding of the densest event
/// (`UploadApplied`, two floats) into a reused buffer; and
/// `histogram_record` one log2-bucket `Histogram` update.
fn suite_telemetry(quick: bool) -> Vec<Case> {
    use crate::telemetry::{Histogram, Telemetry, TraceEvent};
    let mut out = Vec::new();
    let mut b = bencher("telemetry", quick);

    let mut tel = Telemetry::off();
    tel.bind(64);
    let mut t = 0u64;
    let r = b.bench("noop_sink", || {
        t = t.wrapping_add(1);
        let c = (t % 64) as usize;
        tel.grant(t, c, 7, 2);
        tel.upload_applied(t, c, t, 3, 0.5, 0.25);
        std::hint::black_box(&tel);
    });
    out.push(Case {
        name: "noop_sink".into(),
        iters: r.iters,
        ns_per_iter: r.mean_ns,
        clients: 0,
        shards: None,
    });

    let ev = TraceEvent::UploadApplied {
        t: 123_456,
        client: 4_242,
        iteration: 98_765,
        staleness: 17,
        beta: 0.0625,
        weight: 0.001953125,
    };
    let mut line = String::with_capacity(160);
    let r = b.bench("event_encode", || {
        line.clear();
        std::hint::black_box(&ev).encode_into(&mut line);
        std::hint::black_box(&line);
    });
    out.push(Case {
        name: "event_encode".into(),
        iters: r.iters,
        ns_per_iter: r.mean_ns,
        clients: 0,
        shards: None,
    });

    let mut h = Histogram::new();
    let mut v = 0u64;
    let r = b.bench("histogram_record", || {
        v = v.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(1);
        h.record(v >> 48);
        std::hint::black_box(&h);
    });
    out.push(Case {
        name: "histogram_record".into(),
        iters: r.iters,
        ns_per_iter: r.mean_ns,
        clients: 0,
        shards: None,
    });
    out
}

/// Hands out a byte slice 4 KiB at a time — a stand-in for what one
/// nonblocking-socket read returns.
struct Chunked<'a> {
    data: &'a [u8],
    pos: usize,
}

impl std::io::Read for Chunked<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = buf.len().min(4096).min(self.data.len() - self.pos);
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

/// The `channel` suite: the fading-channel subsystem and the delta
/// codec behind `DeltaUpdate` frames. `gain_walk_<m>` is the per-grant
/// gain refresh the channel-aware scheduler pays — every client queried
/// at one slot (cache-hot after the first walk, like the engines'
/// monotone queries); `delta_encode_<n>`/`delta_apply_<n>` the
/// XOR-bitpattern codec at the two pinned model sizes; and
/// `sim_channel_aware_<m>` a full scale-sim event loop under
/// `markov:0.5,500` with the channel-aware scheduler, in ns per event
/// like `event_loop` — the fading path must not regress the hot loop.
fn suite_channel(quick: bool) -> Result<Vec<Case>> {
    let mut out = Vec::new();
    let mut b = bencher("channel", quick);
    let m = if quick { 10_000 } else { 100_000 };
    let fading = channel::parse("markov:0.5,500")?;
    let mut chan = fading.bind(m, &Rng::new(42));
    let name = format!("gain_walk_{m}");
    let r = b.bench(&name, || {
        let mut acc = 0.0f64;
        for c in 0..m {
            acc += chan.gain(c, 10_000);
        }
        std::hint::black_box(acc);
    });
    out.push(Case {
        name,
        iters: r.iters,
        ns_per_iter: r.mean_ns,
        clients: m as u64,
        shards: None,
    });
    for &n in &[5_370usize, 431_080] {
        let layout = ParamLayout::new(vec![TensorSpec {
            name: "w".into(),
            shape: vec![n],
        }]);
        let base = ParamSet::from_flat(&layout, &random_flat(n, 31));
        let local = ParamSet::from_flat(&layout, &random_flat(n, 32));
        let name = format!("delta_encode_{n}");
        let r = b.bench(&name, || {
            std::hint::black_box(wire::delta_params(std::hint::black_box(&local), &base));
        });
        out.push(Case {
            name,
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
        let delta = wire::delta_params(&local, &base);
        let name = format!("delta_apply_{n}");
        let r = b.bench(&name, || {
            std::hint::black_box(wire::apply_delta(std::hint::black_box(&delta), &base));
        });
        out.push(Case {
            name,
            iters: r.iters,
            ns_per_iter: r.mean_ns,
            clients: 0,
            shards: None,
        });
    }
    let clients = if quick { 2_000 } else { 20_000 };
    let cfg = ScaleSimConfig {
        clients,
        iterations: clients as u64,
        params: 32,
        scheduler: SchedulerPolicy::ChannelAware,
        channel: Some("markov:0.5,500".into()),
        ..ScaleSimConfig::default()
    };
    let sim = run_scale_sim(&cfg)?;
    out.push(Case {
        name: format!("sim_channel_aware_{clients}"),
        iters: sim.events,
        ns_per_iter: sim.wall_secs * 1e9 / sim.events.max(1) as f64,
        clients: clients as u64,
        shards: None,
    });
    Ok(out)
}

fn cases_json(cases: Vec<Case>) -> Json {
    let mut o = Json::object();
    for c in cases {
        let mut cj = Json::object();
        cj.set("iters", Json::Int(c.iters as i64))
            .set("ns_per_iter", Json::Float(c.ns_per_iter))
            .set("clients", Json::Int(c.clients as i64));
        if let Some(s) = c.shards {
            cj.set("shards", Json::Int(s as i64));
        }
        o.set(&c.name, cj);
    }
    o
}

/// Run the selected suites and return the full bench record.
pub fn run(cfg: &BenchConfig) -> Result<Json> {
    if let Some(s) = &cfg.suite {
        ensure!(
            SUITES.contains(&s.as_str()),
            "unknown suite {s:?} \
             (aggregation|kernels|scheduler|event_loop|end_to_end|sharded|submodel|net|channel\
             |telemetry)"
        );
    }
    let selected = |name: &str| match cfg.suite.as_deref() {
        Some(s) => s == name,
        None => true,
    };
    let mut suites = Json::object();
    if selected("aggregation") {
        suites.set("aggregation", cases_json(suite_aggregation(cfg.quick)));
    }
    if selected("kernels") {
        suites.set("kernels", cases_json(suite_kernels(cfg.quick)));
    }
    if selected("scheduler") {
        suites.set("scheduler", cases_json(suite_scheduler(cfg.quick)));
    }
    if selected("event_loop") {
        suites.set("event_loop", cases_json(suite_event_loop(cfg.quick)?));
    }
    if selected("end_to_end") {
        suites.set("end_to_end", cases_json(suite_end_to_end(cfg.quick)?));
    }
    if selected("sharded") {
        let shards = cfg.shards.unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().min(4))
                .unwrap_or(1)
        });
        suites.set("sharded", cases_json(suite_sharded(cfg.quick, shards)?));
    }
    if selected("submodel") {
        suites.set("submodel", cases_json(suite_submodel(cfg.quick)));
    }
    if selected("net") {
        suites.set("net", cases_json(suite_net(cfg.quick)));
    }
    if selected("channel") {
        suites.set("channel", cases_json(suite_channel(cfg.quick)?));
    }
    if selected("telemetry") {
        suites.set("telemetry", cases_json(suite_telemetry(cfg.quick)));
    }
    let mut root = Json::object();
    root.set("schema", Json::Str(BENCH_SCHEMA.into()))
        .set("date", Json::Str(utc_date_string()))
        .set("quick", Json::Bool(cfg.quick))
        .set("suites", suites);
    Ok(root)
}

/// Print a bench record as an aligned table (the `--format table` view).
pub fn print_table(record: &Json) {
    println!(
        "{:<13} {:<22} {:>10} {:>16} {:>10}",
        "suite", "case", "iters", "ns/iter", "clients"
    );
    let Some(suites) = record.get("suites").and_then(Json::as_object) else {
        return;
    };
    for (sname, cases) in suites {
        let Some(cases) = cases.as_object() else {
            continue;
        };
        for (cname, c) in cases {
            println!(
                "{:<13} {:<22} {:>10} {:>16.0} {:>10}",
                sname,
                cname,
                c.get("iters").and_then(Json::as_i64).unwrap_or(0),
                c.get("ns_per_iter").and_then(Json::as_f64).unwrap_or(0.0),
                c.get("clients").and_then(Json::as_i64).unwrap_or(0)
            );
        }
    }
}

/// Compare `current` against `baseline`. Returns the list of failures
/// (regressions beyond `factor ×` the baseline `ns_per_iter`, plus
/// baseline cases the current run should have measured but did not)
/// and the number of cases compared.
///
/// Comparison semantics:
/// - When both records declare a `quick` flag and they differ, the
///   comparison is refused: quick and full mode measure different case
///   names (problem sizes), so every mismatch would read as a
///   regression.
/// - A baseline *suite* entirely absent from the current record fails
///   under `strict_suites` (the unfiltered CI gate) and is skipped
///   otherwise (a `--suite`-filtered local check).
/// - Within a measured suite, a baseline *case* the run did not emit
///   is always a failure (a vanished or renamed case must not pass
///   silently).
/// - Cases new relative to the baseline are ignored — they enter the
///   gate when the baseline is re-recorded.
pub fn check(
    current: &Json,
    baseline: &Json,
    factor: f64,
    strict_suites: bool,
) -> Result<(Vec<String>, usize)> {
    ensure!(factor > 0.0, "--factor must be > 0, got {factor}");
    let schema = baseline.get("schema").and_then(Json::as_str);
    ensure!(
        schema == Some(BENCH_SCHEMA),
        "baseline schema {schema:?} != {BENCH_SCHEMA:?} — re-record the baseline"
    );
    let cq = current.get("quick").and_then(Json::as_bool);
    let bq = baseline.get("quick").and_then(Json::as_bool);
    if let (Some(c), Some(b)) = (cq, bq) {
        ensure!(
            c == b,
            "bench mode mismatch: baseline quick={b}, this run quick={c} — \
             quick and full mode measure different cases, compare like with like"
        );
    }
    let bsuites = baseline
        .get("suites")
        .and_then(Json::as_object)
        .ok_or_else(|| anyhow!("baseline has no suites object"))?;
    let csuites = current
        .get("suites")
        .and_then(Json::as_object)
        .ok_or_else(|| anyhow!("current record has no suites object"))?;
    let mut failures = Vec::new();
    let mut compared = 0usize;
    for (sname, bcases) in bsuites {
        // A malformed baseline must disarm the gate *loudly*, never by
        // silently comparing zero cases.
        let Some(bcases) = bcases.as_object() else {
            bail!("baseline suite {sname:?} is not an object — re-record the baseline");
        };
        let Some(ccases) = csuites.get(sname) else {
            if strict_suites {
                failures.push(format!("{sname}: suite in baseline but not measured"));
            }
            continue;
        };
        for (cname, bcase) in bcases {
            let Some(base_ns) = bcase.get("ns_per_iter").and_then(Json::as_f64) else {
                bail!(
                    "baseline case {sname}/{cname} has no numeric ns_per_iter — \
                     re-record the baseline"
                );
            };
            let cur_ns = ccases
                .get(cname)
                .and_then(|c| c.get("ns_per_iter"))
                .and_then(Json::as_f64);
            match cur_ns {
                None => failures.push(format!(
                    "{sname}/{cname}: in baseline but not measured by this run"
                )),
                Some(cur) => {
                    compared += 1;
                    if cur > factor * base_ns {
                        failures.push(format!(
                            "{sname}/{cname}: {cur:.0} ns/iter vs baseline {base_ns:.0} \
                             (> {factor}x)"
                        ));
                    }
                }
            }
        }
    }
    ensure!(
        compared > 0 || !failures.is_empty(),
        "no comparable cases between this run and the baseline \
         (--suite filter too narrow, or empty baseline) — nothing was gated"
    );
    Ok((failures, compared))
}

/// Today's UTC date as `YYYY-MM-DD` (names the `BENCH_<date>.json`
/// record; no chrono — the crate is dependency-minimal).
pub fn utc_date_string() -> String {
    let secs = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let (y, m, d) = civil_from_days((secs / 86_400) as i64);
    format!("{y:04}-{m:02}-{d:02}")
}

/// Days-since-epoch → (year, month, day), Howard Hinnant's civil
/// algorithm. The `era` division is written so truncating integer
/// division behaves like floor for negative inputs; every later
/// quantity is non-negative.
fn civil_from_days(z: i64) -> (i64, u32, u32) {
    let z = z + 719_468;
    let era = if z >= 0 { z } else { z - 146_096 } / 146_097;
    let doe = z - era * 146_097; // [0, 146096]
    let yoe = (doe - doe / 1_460 + doe / 36_524 - doe / 146_096) / 365; // [0, 399]
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100); // [0, 365]
    let mp = (5 * doy + 2) / 153; // [0, 11]
    let d = (doy - (153 * mp + 2) / 5 + 1) as u32; // [1, 31]
    let m = (if mp < 10 { mp + 3 } else { mp - 9 }) as u32;
    let y = yoe + era * 400;
    (if m <= 2 { y + 1 } else { y }, m, d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn civil_dates_match_known_values() {
        assert_eq!(civil_from_days(0), (1970, 1, 1));
        assert_eq!(civil_from_days(59), (1970, 3, 1));
        assert_eq!(civil_from_days(789), (1972, 2, 29));
        assert_eq!(civil_from_days(11_016), (2000, 2, 29));
        assert_eq!(civil_from_days(18_321), (2020, 2, 29));
        assert_eq!(civil_from_days(19_723), (2024, 1, 1));
        assert_eq!(civil_from_days(20_665), (2026, 7, 31));
    }

    #[test]
    fn date_string_shape() {
        let s = utc_date_string();
        assert_eq!(s.len(), 10, "{s}");
        assert_eq!(s.as_bytes()[4], b'-');
        assert_eq!(s.as_bytes()[7], b'-');
    }

    fn record(suite: &str, case: &str, ns: f64) -> Json {
        let mut cj = Json::object();
        cj.set("iters", Json::Int(10))
            .set("ns_per_iter", Json::Float(ns))
            .set("clients", Json::Int(0));
        let mut cases = Json::object();
        cases.set(case, cj);
        let mut suites = Json::object();
        suites.set(suite, cases);
        let mut root = Json::object();
        root.set("schema", Json::Str(BENCH_SCHEMA.into()))
            .set("date", Json::Str("2026-01-01".into()))
            .set("quick", Json::Bool(true))
            .set("suites", suites);
        root
    }

    #[test]
    fn check_passes_within_factor_and_fails_beyond() {
        let baseline = record("aggregation", "lerp_8", 1000.0);
        let same = record("aggregation", "lerp_8", 1500.0);
        let (fails, compared) = check(&same, &baseline, 2.0, true).unwrap();
        assert!(fails.is_empty(), "{fails:?}");
        assert_eq!(compared, 1);
        let slow = record("aggregation", "lerp_8", 2500.0);
        let (fails, _) = check(&slow, &baseline, 2.0, true).unwrap();
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("lerp_8"), "{fails:?}");
    }

    #[test]
    fn check_flags_missing_cases_and_ignores_new_ones() {
        let baseline = record("aggregation", "lerp_8", 1000.0);
        let other = record("aggregation", "lerp_16", 1000.0);
        let (fails, compared) = check(&other, &baseline, 2.0, true).unwrap();
        assert_eq!(compared, 0);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("not measured"), "{fails:?}");
        // The reverse direction (new case, old baseline) is clean.
        let (fails, _) = check(&baseline, &baseline, 2.0, true).unwrap();
        assert!(fails.is_empty());
    }

    #[test]
    fn check_suite_strictness_matches_the_filter_semantics() {
        // Baseline has two suites; the current run measured only one.
        let baseline = json::parse(
            r#"{"schema": "csmaafl-bench-v1", "quick": true, "suites": {
                "aggregation": {"lerp_8": {"iters": 1, "ns_per_iter": 1000.0, "clients": 0}},
                "scheduler": {"oldest_8": {"iters": 1, "ns_per_iter": 1000.0, "clients": 8}}}}"#,
        )
        .unwrap();
        let current = record("aggregation", "lerp_8", 1000.0);
        // Unfiltered (strict) run: the vanished suite fails the gate.
        let (fails, compared) = check(&current, &baseline, 2.0, true).unwrap();
        assert_eq!(compared, 1);
        assert_eq!(fails.len(), 1);
        assert!(fails[0].contains("scheduler"), "{fails:?}");
        // A --suite-filtered check skips suites it did not measure but
        // still compares the overlap.
        let (fails, compared) = check(&current, &baseline, 2.0, false).unwrap();
        assert_eq!(compared, 1);
        assert!(fails.is_empty(), "{fails:?}");
    }

    #[test]
    fn check_refuses_vacuous_and_malformed_comparisons() {
        // Zero overlap is an error, not a silent pass.
        let baseline = record("scheduler", "oldest_8", 1000.0);
        let current = record("aggregation", "lerp_8", 1000.0);
        let err = check(&current, &baseline, 2.0, false).unwrap_err().to_string();
        assert!(err.contains("no comparable cases"), "{err}");
        // A baseline case without numeric ns_per_iter is an error.
        let broken = json::parse(
            r#"{"schema": "csmaafl-bench-v1",
                "suites": {"aggregation": {"lerp_8": {"iters": 1, "ns_per_itr": 5}}}}"#,
        )
        .unwrap();
        let err = check(&current, &broken, 2.0, true).unwrap_err().to_string();
        assert!(err.contains("ns_per_iter"), "{err}");
    }

    #[test]
    fn check_refuses_quick_vs_full_comparison() {
        let mut baseline = record("aggregation", "lerp_8", 1000.0);
        let current = record("aggregation", "lerp_8", 1000.0);
        // Same mode (both quick): fine.
        assert!(check(&current, &baseline, 2.0, true).is_ok());
        // Differing declared modes: refused with an actionable error.
        if let Json::Object(o) = &mut baseline {
            o.insert("quick".into(), Json::Bool(false));
        }
        let err = check(&current, &baseline, 2.0, true).unwrap_err().to_string();
        assert!(err.contains("mode mismatch"), "{err}");
        // A baseline without a quick flag (hand-built) is accepted.
        if let Json::Object(o) = &mut baseline {
            o.remove("quick");
        }
        assert!(check(&current, &baseline, 2.0, true).is_ok());
    }

    #[test]
    fn check_rejects_schema_mismatch() {
        let baseline = json::parse(r#"{"schema": "other-v9", "suites": {}}"#).unwrap();
        let current = record("aggregation", "lerp_8", 1.0);
        assert!(check(&current, &baseline, 2.0, true).is_err());
    }

    #[test]
    fn run_rejects_unknown_suite() {
        let cfg = BenchConfig {
            quick: true,
            suite: Some("bogus".into()),
            shards: None,
        };
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn sharded_suite_emits_both_shard_counts_and_the_ratio() {
        let cases = suite_sharded(true, 2).unwrap();
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["sim_5000_shards1", "sim_5000_multi", "speedup_multi_vs_1"]);
        assert_eq!(cases[0].shards, Some(1));
        assert_eq!(cases[1].shards, Some(2));
        for c in &cases {
            assert!(c.ns_per_iter > 0.0, "{}", c.name);
        }
        // The ratio case is dimensionless and sane (not a raw timing).
        assert!(cases[2].ns_per_iter < 100.0, "{}", cases[2].ns_per_iter);
    }

    #[test]
    fn net_suite_emits_schema_shaped_cases() {
        let cases = suite_net(true);
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            ["encode_5370", "decode_5370", "reader_chunked_5370", "encode_431080",
             "decode_431080"]
        );
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn submodel_suite_emits_schema_shaped_cases() {
        let cases = suite_submodel(true);
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            ["extract_5370", "merge_5370", "merge_lerp_5370", "extract_431080",
             "merge_431080", "merge_lerp_431080"]
        );
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn kernels_suite_emits_schema_shaped_cases() {
        let cases = suite_kernels(true);
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            ["lerp_scalar_5370", "lerp_5370", "axpy_scalar_5370", "axpy_5370",
             "lerp_par4_5370", "l2_5370", "lerp_scalar_431080", "lerp_431080",
             "axpy_scalar_431080", "axpy_431080", "lerp_par4_431080", "l2_431080"]
        );
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn channel_suite_emits_schema_shaped_cases() {
        let cases = suite_channel(true).unwrap();
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            ["gain_walk_10000", "delta_encode_5370", "delta_apply_5370",
             "delta_encode_431080", "delta_apply_431080", "sim_channel_aware_2000"]
        );
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn telemetry_suite_emits_schema_shaped_cases() {
        let cases = suite_telemetry(true);
        let names: Vec<&str> = cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(names, ["noop_sink", "event_encode", "histogram_record"]);
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }

    #[test]
    fn aggregation_suite_emits_schema_shaped_cases() {
        // The cheapest real suite end-to-end: case names pinned, fields
        // present, values positive.
        let cases = suite_aggregation(true);
        assert_eq!(cases.len(), 3);
        assert!(cases.iter().any(|c| c.name == "lerp_5370"));
        assert!(cases.iter().any(|c| c.name == "arena_cycle_5370"));
        for c in &cases {
            assert!(c.iters > 0 && c.ns_per_iter > 0.0, "{}", c.name);
        }
    }
}
