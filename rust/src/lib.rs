//! # CSMAAFL — Client Scheduling and Model Aggregation in Asynchronous FL
//!
//! Production-grade reproduction of *CSMAAFL: Client Scheduling and Model
//! Aggregation in Asynchronous Federated Learning* (Ma, Wang, Sun, Hu,
//! Qian; 2023) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the asynchronous FL server: a sans-IO server
//!   state machine (`coordinator::core::ServerCore`) with pluggable
//!   aggregation and scheduling policies ([`coordinator::policy`]) —
//!   eq.-(11) staleness-aware weighting, the solved Sec.-III-B β
//!   schedule ([`coordinator::beta_solver`]), FedAsync polynomial decay
//!   and AsyncFedED-style adaptive weighting — TDMA upload-slot
//!   arbitration with staleness priority ([`coordinator::scheduler`]),
//!   a synchronous FedAvg comparator, and a discrete-event virtual-time
//!   simulator of the paper's Sec.-II-C time model ([`sim`]) with a
//!   pluggable scenario library ([`sim::scenario`]: `static` |
//!   `dropout` | `churn` | `drift`). Multi-run experiments are
//!   declarative [`experiment::Plan`]s executed in parallel by
//!   [`experiment::PlanRunner`] with byte-identical output at any
//!   `--jobs` count. The same `ServerCore` drives the TCP deployment
//!   runtime ([`net`]). The coordinator hot path scales to 10^6
//!   simulated clients (`repro sim`, [`coordinator::scale`]) over the
//!   arena-backed flat parameter store ([`model::ParamArena`]) and
//!   O(log n) slot arbitration, and shards across cores
//!   ([`coordinator::shard`], `repro sim --shards N`): disjoint client
//!   partitions ([`sim::ClientPartition`]) feed one ordered
//!   aggregation stage with bit-identical output at any shard count;
//!   [`perf`] is the pinned benchmark suite (`repro bench`) whose
//!   `BENCH_<date>.json` records CI gates on, including the measured
//!   multi-shard speedup.
//! * **L2/L1 (build time)** — `python/compile/`: the paper's CNN in JAX
//!   with Pallas kernels on the dense layers and the aggregation axpy,
//!   AOT-lowered to HLO text executed through PJRT ([`runtime`]).
//!
//! ## Build matrix
//!
//! | Build                          | Learners                | Offline |
//! |--------------------------------|-------------------------|---------|
//! | `cargo build` (default)        | `linear` (pure Rust)    | yes     |
//! | `cargo build --features pjrt`  | `linear` + `pjrt` seam  | yes     |
//!
//! The default build is pure Rust with `anyhow` as the only dependency;
//! the PJRT/XLA execution path ([`runtime::Engine`]) is replaced by an
//! API-compatible stub that fails at load time. The `pjrt` feature
//! compiles the full execution path against the typed seam in
//! `runtime::xla`; binding that seam to the native PJRT C API is the
//! remaining step to run the AOT CNN artifacts.
//!
//! ## Quickstart
//!
//! ```text
//! cargo run --release -- train --set clients=10 --learner linear
//! repro figures --fig fig3 --learner linear --out results/ --jobs 4
//! repro grid --axis gamma=0.1,0.2,0.4 --axis scenario=static,dropout:0.1
//! repro timeline --clients 20
//! ```

#![warn(missing_docs)]

pub mod analyze;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiment;
pub mod figures;
pub mod learner;
pub mod metrics;
pub mod model;
pub mod net;
pub mod perf;
pub mod runtime;
pub mod session;
pub mod sim;
pub mod telemetry;
pub mod util;

pub use config::{Algorithm, RunConfig};
pub use coordinator::{run, FlContext};
pub use experiment::{Plan, PlanRunner};
pub use metrics::RunResult;
