//! Per-run result record.

use crate::sim::Ticks;
use crate::util::json::Json;

/// One evaluation of the global model on the held-out test set.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalPoint {
    /// X axis of the paper's figures: relative time slots (1 slot = one
    /// synchronous FedAvg round under the run's time model).
    pub slot: f64,
    /// The same instant in raw virtual ticks.
    pub ticks: Ticks,
    /// Global aggregations performed up to this point.
    pub iteration: u64,
    /// Test-set accuracy of the global model in force at this instant.
    pub accuracy: f64,
    /// Mean test-set loss at this instant.
    pub loss: f64,
}

/// Per-capacity-class outcome of a heterogeneous-capacity run: how much
/// each class participated and how well the final global model serves
/// that class's own training data — the system-bias signal (slow
/// classes that upload less get modeled worse).
#[derive(Debug, Clone, PartialEq)]
pub struct ClassMetrics {
    /// Canonical class label (`r1`, `r0.5`, ...).
    pub label: String,
    /// Submodel rate of the class.
    pub rate: f64,
    /// Clients assigned to the class.
    pub clients: usize,
    /// Updates absorbed from the class.
    pub uploads: u64,
    /// Uploads from the class lost in transit.
    pub lost_uploads: u64,
    /// Mean reported local training loss across the class.
    pub mean_train_loss: f64,
    /// Final-global-model accuracy on the class members' pooled data.
    pub accuracy: f64,
    /// Final-global-model loss on the class members' pooled data.
    pub loss: f64,
}

impl ClassMetrics {
    /// JSON form (one element of the `classes` array).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("label", Json::Str(self.label.clone()))
            .set("rate", Json::Float(self.rate))
            .set("clients", Json::Int(self.clients as i64))
            .set("uploads", Json::Int(self.uploads as i64))
            .set("lost_uploads", Json::Int(self.lost_uploads as i64))
            .set("mean_train_loss", Json::Float(self.mean_train_loss))
            .set("accuracy", Json::Float(self.accuracy))
            .set("loss", Json::Float(self.loss));
        o
    }
}

/// Everything a single federated run produced.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Series label, e.g. `fedavg` or `csmaafl g=0.2`.
    pub label: String,
    /// Accuracy/loss curve at the evaluation cadence.
    pub points: Vec<EvalPoint>,
    /// Upload count per client (fairness analysis).
    pub uploads_per_client: Vec<u64>,
    /// Total global aggregations.
    pub aggregations: u64,
    /// Mean observed staleness (AFL runs; 0 for SFL).
    pub mean_staleness: f64,
    /// Jain fairness index over uploads.
    pub fairness: f64,
    /// Uploads lost in transit (failure injection; 0 = reliable channel).
    pub lost_uploads: u64,
    /// Uploads lost in transit, per client (dropout-bias accounting;
    /// empty or all-zero on reliable channels).
    pub lost_per_client: Vec<u64>,
    /// Mean client-reported local training loss across the run (0 for
    /// engines that do not report it, e.g. SFL).
    pub mean_train_loss: f64,
    /// Per-capacity-class metrics; empty under the trivial (`full` /
    /// `uniform:1.0`) capacity profile, in which case the emitted JSON
    /// is byte-identical to a pre-submodel run.
    pub classes: Vec<ClassMetrics>,
    /// Canonical channel-model spelling (`sim::channel`); `"ideal"`
    /// under the trivial model, in which case the emitted JSON is
    /// byte-identical to a pre-channel run.
    pub channel: String,
    /// Total upload payload that crossed the (simulated) uplink, in
    /// wire-format bytes — lost uploads included, since they occupied
    /// the TDMA slot all the same.
    pub bytes_on_wire: u64,
    /// Uploads lost to channel fades specifically (a subset of
    /// `lost_uploads`; 0 under the ideal channel).
    pub channel_lost: u64,
    /// Virtual completion time.
    pub total_ticks: Ticks,
    /// Real wall-clock spent (training + eval dispatches).
    pub wallclock_secs: f64,
    /// Worker threads the engine actually used (after clamping). Like
    /// wall-clock it can vary per machine (`shards=auto`), so it is
    /// never part of [`RunResult::summary_json`] — only the full
    /// record.
    pub shards: usize,
    /// Aggregate telemetry ([`crate::telemetry::Registry`] JSON) —
    /// `Some` only when the run was traced. Rides the full record
    /// only, never the deterministic summary, so untraced runs emit
    /// byte-identical records to pre-telemetry builds.
    pub telemetry: Option<Json>,
}

impl RunResult {
    /// An empty record with the given label (all counters zero).
    pub fn empty(label: &str) -> Self {
        RunResult {
            label: label.to_string(),
            points: Vec::new(),
            uploads_per_client: Vec::new(),
            aggregations: 0,
            mean_staleness: 0.0,
            fairness: 1.0,
            lost_uploads: 0,
            lost_per_client: Vec::new(),
            mean_train_loss: 0.0,
            classes: Vec::new(),
            channel: "ideal".to_string(),
            bytes_on_wire: 0,
            channel_lost: 0,
            total_ticks: 0,
            wallclock_secs: 0.0,
            shards: 1,
            telemetry: None,
        }
    }

    /// Accuracy at the last recorded point (0 when no points exist).
    pub fn final_accuracy(&self) -> f64 {
        self.points.last().map_or(0.0, |p| p.accuracy)
    }

    /// Best accuracy over the whole curve.
    pub fn best_accuracy(&self) -> f64 {
        self.points.iter().map(|p| p.accuracy).fold(0.0, f64::max)
    }

    /// First relative time slot at which accuracy reached `target`
    /// (the paper's "time to reach the same performance" comparison).
    pub fn slots_to_accuracy(&self, target: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.accuracy >= target)
            .map(|p| p.slot)
    }

    /// Deterministic scalar summary: every field is a pure function of
    /// the run's config + seed (no wall-clock, no curve), so `repro
    /// grid` matrices built from it are byte-identical regardless of
    /// `--jobs` thread count, machine, or load.
    pub fn summary_json(&self) -> Json {
        let mut o = Json::object();
        o.set("label", Json::Str(self.label.clone()))
            .set("aggregations", Json::Int(self.aggregations as i64))
            .set("final_accuracy", Json::Float(self.final_accuracy()))
            .set("best_accuracy", Json::Float(self.best_accuracy()))
            .set("mean_staleness", Json::Float(self.mean_staleness))
            .set("fairness", Json::Float(self.fairness))
            .set("lost_uploads", Json::Int(self.lost_uploads as i64))
            .set("mean_train_loss", Json::Float(self.mean_train_loss))
            .set("total_ticks", Json::Int(self.total_ticks as i64));
        // Class cells appear only under a non-trivial capacity profile,
        // so `capacity=uniform:1.0` summaries stay byte-identical to
        // the pre-submodel engine.
        if !self.classes.is_empty() {
            o.set(
                "classes",
                Json::Array(self.classes.iter().map(|c| c.to_json()).collect()),
            );
        }
        // Likewise the channel triplet appears only under a fading
        // model, so `channel=ideal` summaries stay byte-identical to
        // the pre-channel engine.
        if self.channel != "ideal" {
            o.set("channel", Json::Str(self.channel.clone()))
                .set("bytes_on_wire", Json::Int(self.bytes_on_wire as i64))
                .set("channel_lost", Json::Int(self.channel_lost as i64));
        }
        o
    }

    /// JSON summary (for `results/*.json` run records).
    pub fn to_json(&self) -> Json {
        let mut o = self.summary_json();
        o.set("wallclock_secs", Json::Float(self.wallclock_secs))
            .set("shards", Json::Int(self.shards as i64))
            .set("channel", Json::Str(self.channel.clone()))
            .set("bytes_on_wire", Json::Int(self.bytes_on_wire as i64))
            .set(
                "uploads_per_client",
                Json::Array(
                    self.uploads_per_client
                        .iter()
                        .map(|&u| Json::Int(u as i64))
                        .collect(),
                ),
            )
            .set(
                "lost_per_client",
                Json::Array(
                    self.lost_per_client
                        .iter()
                        .map(|&u| Json::Int(u as i64))
                        .collect(),
                ),
            )
            .set(
                "points",
                Json::Array(
                    self.points
                        .iter()
                        .map(|p| {
                            let mut pj = Json::object();
                            pj.set("slot", Json::Float(p.slot))
                                .set("ticks", Json::Int(p.ticks as i64))
                                .set("iteration", Json::Int(p.iteration as i64))
                                .set("accuracy", Json::Float(p.accuracy))
                                .set("loss", Json::Float(p.loss));
                            pj
                        })
                        .collect(),
                ),
            );
        // Telemetry aggregates appear only when the run was traced, so
        // untraced full records stay byte-identical to pre-telemetry
        // builds.
        if let Some(t) = &self.telemetry {
            o.set("telemetry", t.clone());
        }
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_with_points(accs: &[f64]) -> RunResult {
        let mut r = RunResult::empty("x");
        r.points = accs
            .iter()
            .enumerate()
            .map(|(i, &a)| EvalPoint {
                slot: i as f64,
                ticks: i as u64 * 100,
                iteration: i as u64,
                accuracy: a,
                loss: 1.0,
            })
            .collect();
        r
    }

    #[test]
    fn accessors() {
        let r = run_with_points(&[0.1, 0.5, 0.4, 0.8]);
        assert_eq!(r.final_accuracy(), 0.8);
        assert_eq!(r.best_accuracy(), 0.8);
        assert_eq!(r.slots_to_accuracy(0.45), Some(1.0));
        assert_eq!(r.slots_to_accuracy(0.9), None);
    }

    #[test]
    fn json_summary_parses() {
        let mut r = run_with_points(&[0.2, 0.6]);
        r.lost_uploads = 7;
        r.lost_per_client = vec![3, 4];
        let j = r.to_json();
        let parsed = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed.get("label").unwrap().as_str(), Some("x"));
        assert_eq!(parsed.get("lost_uploads").unwrap().as_i64(), Some(7));
        assert_eq!(
            parsed.get("lost_per_client").unwrap().as_array().unwrap().len(),
            2
        );
        assert_eq!(
            parsed.get("points").unwrap().as_array().unwrap().len(),
            2
        );
    }

    #[test]
    fn summary_json_is_wallclock_free() {
        let mut r = run_with_points(&[0.2, 0.6]);
        r.wallclock_secs = 123.4;
        r.shards = 8;
        let s = r.summary_json().to_string_pretty();
        assert!(!s.contains("wallclock"), "{s}");
        assert!(!s.contains("points"), "{s}");
        // Shard count is machine-dependent under `auto`, so like
        // wall-clock it must never leak into the deterministic summary.
        assert!(!s.contains("shards"), "{s}");
        assert!(s.contains("best_accuracy"), "{s}");
    }

    #[test]
    fn full_record_carries_the_shard_count() {
        let mut r = run_with_points(&[0.2]);
        r.shards = 4;
        assert_eq!(r.to_json().get("shards").unwrap().as_i64(), Some(4));
        assert_eq!(RunResult::empty("e").to_json().get("shards").unwrap().as_i64(), Some(1));
    }

    #[test]
    fn empty_run_defaults() {
        let r = RunResult::empty("e");
        assert_eq!(r.final_accuracy(), 0.0);
        assert_eq!(r.slots_to_accuracy(0.1), None);
    }

    #[test]
    fn class_metrics_appear_only_when_present() {
        let mut r = run_with_points(&[0.2]);
        assert!(r.summary_json().get("classes").is_none());
        assert!(!r.to_json().to_string_compact().contains("classes"));
        r.classes.push(ClassMetrics {
            label: "r0.5".into(),
            rate: 0.5,
            clients: 3,
            uploads: 9,
            lost_uploads: 1,
            mean_train_loss: 0.7,
            accuracy: 0.55,
            loss: 1.2,
        });
        let j = r.summary_json();
        let cells = j.get("classes").unwrap().as_array().unwrap();
        assert_eq!(cells.len(), 1);
        assert_eq!(cells[0].get("label").unwrap().as_str(), Some("r0.5"));
        assert_eq!(cells[0].get("clients").unwrap().as_i64(), Some(3));
        assert_eq!(cells[0].get("accuracy").unwrap().as_f64(), Some(0.55));
        // And they ride through the full record too.
        assert!(r.to_json().get("classes").is_some());
    }

    #[test]
    fn telemetry_rides_the_full_record_only_when_traced() {
        let mut r = run_with_points(&[0.2]);
        assert!(r.to_json().get("telemetry").is_none());
        assert!(!r.to_json().to_string_compact().contains("telemetry"));
        let mut reg = Json::object();
        reg.set("uploads_applied", Json::Int(3));
        r.telemetry = Some(reg);
        let j = r.to_json();
        assert_eq!(
            j.get("telemetry").unwrap().get("uploads_applied").unwrap().as_i64(),
            Some(3)
        );
        // Never in the deterministic summary.
        assert!(r.summary_json().get("telemetry").is_none());
    }

    #[test]
    fn channel_metrics_appear_in_summaries_only_under_fading() {
        let mut r = run_with_points(&[0.2]);
        r.bytes_on_wire = 4096;
        // Ideal channel: the deterministic summary is byte-identical to
        // a pre-channel record, but the full record still meters bytes.
        let s = r.summary_json();
        assert!(s.get("channel").is_none());
        assert!(s.get("bytes_on_wire").is_none());
        assert_eq!(r.to_json().get("bytes_on_wire").unwrap().as_i64(), Some(4096));
        assert_eq!(r.to_json().get("channel").unwrap().as_str(), Some("ideal"));
        // Fading channel: the triplet joins the summary.
        r.channel = "markov:0.5,500".to_string();
        r.channel_lost = 3;
        let s = r.summary_json();
        assert_eq!(s.get("channel").unwrap().as_str(), Some("markov:0.5,500"));
        assert_eq!(s.get("bytes_on_wire").unwrap().as_i64(), Some(4096));
        assert_eq!(s.get("channel_lost").unwrap().as_i64(), Some(3));
    }
}
