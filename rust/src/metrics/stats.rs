//! Small online statistics helper (mean / min / max / percentiles).

/// Accumulates f64 observations; percentile queries sort a copy.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    values: Vec<f64>,
}

impl Summary {
    /// An empty accumulator.
    pub fn new() -> Self {
        Summary { values: Vec::new() }
    }

    /// Record one observation.
    pub fn push(&mut self, v: f64) {
        self.values.push(v);
    }

    /// Number of observations recorded.
    pub fn count(&self) -> usize {
        self.values.len()
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    /// Smallest observation (+∞ when empty).
    pub fn min(&self) -> f64 {
        self.values.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Largest observation (−∞ when empty).
    pub fn max(&self) -> f64 {
        self.values.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Sample standard deviation (0 with fewer than two observations).
    pub fn std(&self) -> f64 {
        if self.values.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .values
            .iter()
            .map(|v| (v - m) * (v - m))
            .sum::<f64>()
            / (self.values.len() - 1) as f64;
        var.sqrt()
    }

    /// Nearest-rank percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return 0.0;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
        sorted[rank.min(sorted.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let mut s = Summary::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944487).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for v in 0..101 {
            s.push(v as f64);
        }
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 50.0);
        assert_eq!(s.percentile(95.0), 95.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn empty_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.std(), 0.0);
    }
}
