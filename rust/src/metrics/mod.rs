//! Run metrics: accuracy-vs-virtual-time series, fairness and staleness
//! statistics, CSV/JSON emission for the figure harness.

mod result;
mod stats;

pub use result::{ClassMetrics, EvalPoint, RunResult};
pub use stats::Summary;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write several runs as a long-format CSV:
/// `series,slot,ticks,iteration,accuracy,loss`.
/// This is the exact input the paper-figure plots consume.
///
/// Heterogeneous-capacity runs append one column group per capacity
/// class present in any run
/// (`<label>_accuracy,<label>_loss,<label>_uploads` — final-model
/// scalars, constant down a series); under the trivial profile no run
/// has classes and the file is byte-identical to pre-submodel output.
pub fn write_series_csv(path: impl AsRef<Path>, runs: &[&RunResult]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    // Class-label union in first-seen order (runs are already in job
    // order, so this is deterministic).
    let mut labels: Vec<&str> = Vec::new();
    for run in runs {
        for c in &run.classes {
            if !labels.contains(&c.label.as_str()) {
                labels.push(&c.label);
            }
        }
    }
    let mut header = String::from("series,slot,ticks,iteration,accuracy,loss");
    for l in &labels {
        header.push_str(&format!(",{l}_accuracy,{l}_loss,{l}_uploads"));
    }
    writeln!(f, "{header}")?;
    for run in runs {
        for p in &run.points {
            write!(
                f,
                "{},{:.4},{},{},{:.6},{:.6}",
                run.label, p.slot, p.ticks, p.iteration, p.accuracy, p.loss
            )?;
            for l in &labels {
                match run.classes.iter().find(|c| c.label.as_str() == *l) {
                    Some(c) => write!(f, ",{:.6},{:.6},{}", c.accuracy, c.loss, c.uploads)?,
                    None => write!(f, ",,,")?,
                }
            }
            writeln!(f)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let run = RunResult {
            label: "test".into(),
            points: vec![
                EvalPoint {
                    slot: 0.0,
                    ticks: 0,
                    iteration: 0,
                    accuracy: 0.1,
                    loss: 2.3,
                },
                EvalPoint {
                    slot: 1.0,
                    ticks: 2210,
                    iteration: 20,
                    accuracy: 0.4,
                    loss: 1.9,
                },
            ],
            ..RunResult::empty("test")
        };
        let tmp = std::env::temp_dir().join(format!("csmaafl_csv_{}.csv", std::process::id()));
        write_series_csv(&tmp, &[&run]).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("series,slot"));
        // No capacity classes -> exactly the pre-submodel header/rows.
        assert_eq!(lines[0], "series,slot,ticks,iteration,accuracy,loss");
        assert!(lines[1].starts_with("test,0.0000,0,0,0.100000"));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn csv_gains_one_column_group_per_capacity_class() {
        let mut run = RunResult {
            points: vec![EvalPoint {
                slot: 0.0,
                ticks: 0,
                iteration: 0,
                accuracy: 0.1,
                loss: 2.3,
            }],
            ..RunResult::empty("hetero")
        };
        for (label, rate) in [("r1", 1.0), ("r0.5", 0.5)] {
            run.classes.push(ClassMetrics {
                label: label.into(),
                rate,
                clients: 2,
                uploads: 7,
                lost_uploads: 0,
                mean_train_loss: 0.5,
                accuracy: rate,
                loss: 1.0,
            });
        }
        let plain = RunResult {
            points: run.points.clone(),
            ..RunResult::empty("plain")
        };
        let tmp =
            std::env::temp_dir().join(format!("csmaafl_csv_cls_{}.csv", std::process::id()));
        write_series_csv(&tmp, &[&run, &plain]).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(
            lines[0],
            "series,slot,ticks,iteration,accuracy,loss,\
             r1_accuracy,r1_loss,r1_uploads,r0.5_accuracy,r0.5_loss,r0.5_uploads"
        );
        assert!(lines[1].ends_with(",1.000000,1.000000,7,0.500000,1.000000,7"), "{}", lines[1]);
        // A classless run in the same file leaves its group cells empty.
        assert!(lines[2].ends_with(",,,,,,"), "{}", lines[2]);
        std::fs::remove_file(&tmp).ok();
    }
}
