//! Run metrics: accuracy-vs-virtual-time series, fairness and staleness
//! statistics, CSV/JSON emission for the figure harness.

mod result;
mod stats;

pub use result::{EvalPoint, RunResult};
pub use stats::Summary;

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Write several runs as a long-format CSV:
/// `series,slot,ticks,iteration,accuracy,loss`.
/// This is the exact input the paper-figure plots consume.
pub fn write_series_csv(path: impl AsRef<Path>, runs: &[&RunResult]) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    writeln!(f, "series,slot,ticks,iteration,accuracy,loss")?;
    for run in runs {
        for p in &run.points {
            writeln!(
                f,
                "{},{:.4},{},{},{:.6},{:.6}",
                run.label, p.slot, p.ticks, p.iteration, p.accuracy, p.loss
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let run = RunResult {
            label: "test".into(),
            points: vec![
                EvalPoint {
                    slot: 0.0,
                    ticks: 0,
                    iteration: 0,
                    accuracy: 0.1,
                    loss: 2.3,
                },
                EvalPoint {
                    slot: 1.0,
                    ticks: 2210,
                    iteration: 20,
                    accuracy: 0.4,
                    loss: 1.9,
                },
            ],
            ..RunResult::empty("test")
        };
        let tmp = std::env::temp_dir().join(format!("csmaafl_csv_{}.csv", std::process::id()));
        write_series_csv(&tmp, &[&run]).unwrap();
        let text = std::fs::read_to_string(&tmp).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("series,slot"));
        assert!(lines[1].starts_with("test,0.0000,0,0,0.100000"));
        std::fs::remove_file(&tmp).ok();
    }
}
