//! Experiment plans: cartesian grids of `RunConfig` overrides expanded
//! into an ordered list of independent jobs.
//!
//! A [`Plan`] is declarative — explicit job rows (the `compare`
//! series), sweep axes (the `--axis`/`--set` grid spelling), and an
//! optional replicate count with deterministically derived per-job
//! seeds. [`Plan::expand`] flattens it into [`Job`]s in a stable order
//! (explicit rows outermost, then axes first-to-last, replicates
//! innermost), so job indices — and therefore result files — are
//! byte-identical however many threads later execute them.

use anyhow::Result;

use crate::config::RunConfig;

/// One sweep axis: a config key and the values it takes, both in the
/// `--set key=value` string spelling.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Axis {
    /// The [`RunConfig::set_field`] key.
    pub key: String,
    /// The values the key sweeps over.
    pub values: Vec<String>,
}

/// One expanded job: the overrides applied to the base config.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Job {
    /// Position in the plan's expansion order (stable across runs and
    /// thread counts).
    pub index: usize,
    /// `(key, value)` overrides, applied in order via
    /// [`RunConfig::set_field`].
    pub overrides: Vec<(String, String)>,
    /// Series-label override; `None` keeps the engine-assigned label.
    pub label: Option<String>,
}

impl Job {
    /// Apply the job's overrides to `cfg`, in override order.
    pub fn apply(&self, cfg: &mut RunConfig) -> Result<()> {
        for (k, v) in &self.overrides {
            cfg.set_field(k, v)?;
        }
        Ok(())
    }

    /// The `k1=v1 k2=v2` spelling of the job's overrides (error
    /// context, matrix rows).
    pub fn spec(&self) -> String {
        self.overrides
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

/// Derive the seed for replicate `replicate` of a plan rooted at
/// `root`. Replicate 0 keeps the root seed (so un-replicated plans are
/// bit-identical to direct runs); later replicates mix the index
/// through a splitmix64 finalizer, giving well-separated, platform-
/// independent streams.
pub fn derive_seed(root: u64, replicate: u64) -> u64 {
    if replicate == 0 {
        return root;
    }
    let mut z = root ^ replicate.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A declarative multi-run experiment: explicit job rows × sweep axes ×
/// replicates over one base config.
///
/// ```
/// use csmaafl::experiment::Plan;
///
/// let plan = Plan::new()
///     .axis("gamma", ["0.1", "0.2"])
///     .axis("scheduler", ["oldest", "fifo"]);
/// let jobs = plan.expand(42);
/// assert_eq!(jobs.len(), 4);
/// // First axis outermost, second innermost:
/// assert_eq!(jobs[0].spec(), "gamma=0.1 scheduler=oldest");
/// assert_eq!(jobs[1].spec(), "gamma=0.1 scheduler=fifo");
/// assert_eq!(jobs[3].spec(), "gamma=0.2 scheduler=fifo");
/// assert_eq!(jobs[3].label.as_deref(), Some("gamma=0.2 scheduler=fifo"));
/// ```
#[derive(Debug, Clone, Default)]
pub struct Plan {
    explicit: Vec<Vec<(String, String)>>,
    axes: Vec<Axis>,
    replicates: usize,
}

impl Plan {
    /// An empty plan (expands to one job with no overrides).
    pub fn new() -> Plan {
        Plan::default()
    }

    /// Append an explicit job row (a fixed override set, e.g. one
    /// `compare` series). Explicit rows vary outermost in the
    /// expansion, in insertion order.
    pub fn job<K, V>(mut self, overrides: impl IntoIterator<Item = (K, V)>) -> Plan
    where
        K: Into<String>,
        V: Into<String>,
    {
        self.explicit.push(
            overrides
                .into_iter()
                .map(|(k, v)| (k.into(), v.into()))
                .collect(),
        );
        self
    }

    /// Append a sweep axis. Axes vary in declaration order, the first
    /// axis outermost. An axis with no values expands to zero jobs.
    pub fn axis<V>(mut self, key: &str, values: impl IntoIterator<Item = V>) -> Plan
    where
        V: Into<String>,
    {
        self.axes.push(Axis {
            key: key.to_string(),
            values: values.into_iter().map(Into::into).collect(),
        });
        self
    }

    /// Run every cell `n` times with per-replicate derived seeds
    /// ([`derive_seed`]; replicate 0 keeps the cell's seed — the cell's
    /// own `seed` axis/override when present, else the base seed).
    /// Replicates vary innermost. `n <= 1` means a single run per cell.
    pub fn replicates(mut self, n: usize) -> Plan {
        self.replicates = n;
        self
    }

    /// The plan's sweep axes (matrix-record provenance).
    pub fn axes(&self) -> &[Axis] {
        &self.axes
    }

    /// Number of jobs [`Plan::expand`] will produce.
    pub fn job_count(&self) -> usize {
        let rows = self.explicit.len().max(1);
        let cells: usize = self.axes.iter().map(|a| a.values.len()).product();
        rows * cells * self.replicates.max(1)
    }

    /// Expand into the ordered job list. `base_seed` roots the
    /// replicate-seed derivation (pass the base config's seed).
    pub fn expand(&self, base_seed: u64) -> Vec<Job> {
        let rows: Vec<Vec<(String, String)>> = if self.explicit.is_empty() {
            vec![Vec::new()]
        } else {
            self.explicit.clone()
        };
        // Cartesian product over axes: first axis outermost.
        let mut combos: Vec<Vec<(String, String)>> = vec![Vec::new()];
        for ax in &self.axes {
            let mut next = Vec::with_capacity(combos.len() * ax.values.len());
            for combo in &combos {
                for v in &ax.values {
                    let mut c = combo.clone();
                    c.push((ax.key.clone(), v.clone()));
                    next.push(c);
                }
            }
            combos = next;
        }
        let reps = self.replicates.max(1);
        let mut jobs = Vec::with_capacity(rows.len() * combos.len() * reps);
        for row in &rows {
            for combo in &combos {
                for rep in 0..reps {
                    let mut overrides = row.clone();
                    overrides.extend(combo.iter().cloned());
                    let mut label_parts: Vec<String> =
                        combo.iter().map(|(k, v)| format!("{k}={v}")).collect();
                    if reps > 1 {
                        // Root the replicate derivation at the cell's
                        // *effective* seed: a `seed` axis or explicit
                        // `seed` override wins over the base seed, so a
                        // seed-swept grid replicates each cell from its
                        // own root instead of silently clobbering the
                        // axis with base-derived values.
                        let root = overrides
                            .iter()
                            .rev()
                            .find(|(k, _)| k == "seed")
                            .and_then(|(_, v)| v.parse::<u64>().ok())
                            .unwrap_or(base_seed);
                        let seed = derive_seed(root, rep as u64);
                        overrides.push(("seed".to_string(), seed.to_string()));
                        label_parts.push(format!("rep={rep}"));
                    }
                    let label = if label_parts.is_empty() {
                        None
                    } else {
                        Some(label_parts.join(" "))
                    };
                    jobs.push(Job {
                        index: jobs.len(),
                        overrides,
                        label,
                    });
                }
            }
        }
        jobs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_one_bare_job() {
        let jobs = Plan::new().expand(1);
        assert_eq!(jobs.len(), 1);
        assert!(jobs[0].overrides.is_empty());
        assert_eq!(jobs[0].label, None);
        assert_eq!(Plan::new().job_count(), 1);
    }

    #[test]
    fn three_axis_grid_expands_in_row_major_order() {
        let plan = Plan::new()
            .axis("a", ["1", "2"])
            .axis("b", ["x"])
            .axis("c", ["7", "8", "9"]);
        assert_eq!(plan.job_count(), 6);
        let jobs = plan.expand(0);
        assert_eq!(jobs.len(), 6);
        assert_eq!(jobs[0].spec(), "a=1 b=x c=7");
        assert_eq!(jobs[1].spec(), "a=1 b=x c=8");
        assert_eq!(jobs[2].spec(), "a=1 b=x c=9");
        assert_eq!(jobs[3].spec(), "a=2 b=x c=7");
        assert_eq!(jobs[5].spec(), "a=2 b=x c=9");
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.index, i);
        }
    }

    #[test]
    fn explicit_rows_keep_engine_labels() {
        let plan = Plan::new()
            .job([("algorithm", "fedavg")])
            .job([("algorithm", "csmaafl"), ("gamma", "0.4")]);
        let jobs = plan.expand(0);
        assert_eq!(jobs.len(), 2);
        assert_eq!(jobs[0].label, None, "engine label wins for explicit rows");
        assert_eq!(jobs[1].spec(), "algorithm=csmaafl gamma=0.4");
    }

    #[test]
    fn explicit_rows_cross_with_axes() {
        let plan = Plan::new()
            .job([("algorithm", "fedavg")])
            .job([("algorithm", "csmaafl")])
            .axis("clients", ["4", "8"]);
        let jobs = plan.expand(0);
        assert_eq!(jobs.len(), 4);
        assert_eq!(jobs[0].spec(), "algorithm=fedavg clients=4");
        assert_eq!(jobs[3].spec(), "algorithm=csmaafl clients=8");
        assert_eq!(jobs[1].label.as_deref(), Some("clients=8"));
    }

    #[test]
    fn replicates_derive_seeds_and_keep_rep0_at_root() {
        let plan = Plan::new().axis("gamma", ["0.2"]).replicates(3);
        let jobs = plan.expand(42);
        assert_eq!(jobs.len(), 3);
        let seed_of = |j: &Job| {
            j.overrides
                .iter()
                .find(|(k, _)| k == "seed")
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        assert_eq!(seed_of(&jobs[0]), "42", "replicate 0 keeps the root seed");
        assert_ne!(seed_of(&jobs[1]), seed_of(&jobs[2]));
        assert_ne!(seed_of(&jobs[1]), "42");
        assert_eq!(jobs[1].label.as_deref(), Some("gamma=0.2 rep=1"));
        // Derivation is pure: same inputs, same seeds.
        assert_eq!(derive_seed(42, 2), derive_seed(42, 2));
        assert_ne!(derive_seed(42, 1), derive_seed(43, 1));
    }

    #[test]
    fn replicates_root_at_each_cell_of_a_seed_axis() {
        // A seed axis must not be clobbered by replicate derivation:
        // each cell replicates from its own seed.
        let plan = Plan::new().axis("seed", ["1", "2"]).replicates(2);
        let jobs = plan.expand(42);
        assert_eq!(jobs.len(), 4);
        let seed_of = |j: &Job| {
            j.overrides
                .iter()
                .rev()
                .find(|(k, _)| k == "seed")
                .map(|(_, v)| v.clone())
                .unwrap()
        };
        assert_eq!(seed_of(&jobs[0]), "1", "cell seed=1, rep 0 keeps 1");
        assert_eq!(seed_of(&jobs[2]), "2", "cell seed=2, rep 0 keeps 2");
        assert_eq!(seed_of(&jobs[1]), derive_seed(1, 1).to_string());
        assert_eq!(seed_of(&jobs[3]), derive_seed(2, 1).to_string());
        assert_ne!(seed_of(&jobs[1]), seed_of(&jobs[3]), "cells stay distinct");
    }

    #[test]
    fn jobs_apply_overrides_to_configs() {
        let plan = Plan::new().axis("gamma", ["0.4"]).axis("clients", ["8"]);
        let job = &plan.expand(0)[0];
        let mut cfg = RunConfig::default();
        job.apply(&mut cfg).unwrap();
        assert_eq!(cfg.gamma, 0.4);
        assert_eq!(cfg.clients, 8);
        let bad = Job {
            index: 0,
            overrides: vec![("gamma".into(), "banana".into())],
            label: None,
        };
        assert!(bad.apply(&mut cfg).is_err());
    }

    #[test]
    fn empty_axis_expands_to_zero_jobs() {
        let plan = Plan::new().axis("gamma", Vec::<String>::new());
        assert!(plan.expand(0).is_empty());
        assert_eq!(plan.job_count(), 0);
    }
}
