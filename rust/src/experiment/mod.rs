//! The parallel experiment engine: declarative multi-run [`Plan`]s
//! executed across OS threads by [`PlanRunner`].
//!
//! Everything the paper claims is comparative — CSMAAFL vs. synchronous
//! FL vs. naive-α, across heterogeneity levels and (now) scenarios — so
//! the repository's unit of work is rarely one run; it is a *grid* of
//! runs. This module makes that grid a first-class object:
//!
//! * [`Plan`] (`plan.rs`) — explicit job rows, cartesian sweep axes in
//!   the `--set key=value` spelling, and replicates with
//!   deterministically derived seeds ([`derive_seed`]).
//! * [`PlanRunner`] (`runner.rs`) — `std::thread::scope` workers over
//!   an atomic job counter with ordered result collection; output is
//!   byte-identical for `--jobs 1` and `--jobs N`.
//! * [`grid_record`] — the `repro grid` JSON results matrix, built from
//!   deterministic run summaries only.
//!
//! `repro sweep`, `repro compare`, `repro figures` and `repro grid` all
//! execute through this engine; see `docs/EXPERIMENTS.md` for the
//! cookbook.

mod plan;
mod runner;

pub use plan::{derive_seed, Axis, Job, Plan};
pub use runner::{effective_jobs, grid_record, PlanRunner};
