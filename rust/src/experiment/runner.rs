//! Parallel plan execution over OS threads (std-only).
//!
//! Every job of a [`Plan`] is an independent, fully seed-deterministic
//! run, so parallelism is pure scheduling: workers pull jobs from a
//! shared atomic counter, results are collected *by job index*, and the
//! first error (in job order, not completion order) wins. Output is
//! therefore byte-identical for `--jobs 1` and `--jobs N`.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;

use anyhow::{Context, Result};

use super::plan::{Job, Plan};
use crate::metrics::RunResult;
use crate::session::Session;
use crate::util::json::Json;

/// Config keys whose override invalidates the shared session's data or
/// learner state; jobs touching one get a private rebuilt session.
const SESSION_KEYS: [&str; 7] = [
    "clients",
    "samples_per_client",
    "test_samples",
    "dataset",
    "partition",
    "seed",
    "model_config",
];

/// The number of worker threads a request resolves to: `requested == 0`
/// means the machine's available parallelism, and the result is clamped
/// to `[1, job_count]` so small plans never spawn idle threads.
pub fn effective_jobs(requested: usize, job_count: usize) -> usize {
    let auto = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let n = if requested == 0 { auto } else { requested };
    n.clamp(1, job_count.max(1))
}

/// Executes a [`Plan`]'s jobs against a base [`Session`] across worker
/// threads, preserving the paired-experiment guarantees: jobs whose
/// overrides leave the shared data valid run against the base session
/// (same dataset, shards, init — exactly like a sequential
/// `Session::run_with` loop), while jobs that change data-shaping keys
/// (`clients`, `dataset`, `seed`, ...) get a private session built from
/// their own config.
pub struct PlanRunner<'a> {
    session: &'a Session,
    jobs: usize,
}

impl<'a> PlanRunner<'a> {
    /// A runner over `session` with automatic thread count.
    pub fn new(session: &'a Session) -> PlanRunner<'a> {
        PlanRunner { session, jobs: 0 }
    }

    /// Set the worker-thread count (`0` = available parallelism).
    pub fn jobs(mut self, n: usize) -> PlanRunner<'a> {
        self.jobs = n;
        self
    }

    /// Expand `plan` (seeding replicates from the session's config) and
    /// execute every job. Results come back in job order.
    pub fn run(&self, plan: &Plan) -> Result<Vec<RunResult>> {
        let jobs = plan.expand(self.session.cfg.seed);
        self.run_jobs(&jobs)
    }

    /// Execute an already-expanded job list. Results come back in job
    /// order; the first failing job (by index) aborts the batch with an
    /// error naming the job's overrides. Overrides are pre-validated
    /// before anything runs, so a typo in cell N fails in milliseconds
    /// instead of after the N-1 cells before it trained; a failure at
    /// run time stops workers from starting further jobs.
    pub fn run_jobs(&self, jobs: &[Job]) -> Result<Vec<RunResult>> {
        for (i, job) in jobs.iter().enumerate() {
            let mut cfg = self.session.cfg.clone();
            job.apply(&mut cfg)
                .and_then(|()| cfg.validate())
                .with_context(|| format!("job {i} ({})", job.spec()))?;
        }
        let threads = effective_jobs(self.jobs, jobs.len());
        let mut slots: Vec<Option<Result<RunResult>>> = Vec::new();
        if threads <= 1 {
            for job in jobs {
                let result = self.run_job(job);
                let failed = result.is_err();
                slots.push(Some(result));
                if failed {
                    break;
                }
            }
        } else {
            slots.resize_with(jobs.len(), || None);
            let next = AtomicUsize::new(0);
            let abort = AtomicBool::new(false);
            let (tx, rx) = mpsc::channel::<(usize, Result<RunResult>)>();
            std::thread::scope(|scope| {
                for _ in 0..threads {
                    let tx = tx.clone();
                    let next = &next;
                    let abort = &abort;
                    scope.spawn(move || loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs.len() {
                            break;
                        }
                        let result = self.run_job(&jobs[i]);
                        if result.is_err() {
                            abort.store(true, Ordering::Relaxed);
                        }
                        if tx.send((i, result)).is_err() {
                            break;
                        }
                    });
                }
                drop(tx);
                // Collect by index: completion order is load-dependent,
                // slot order is not.
                for (i, result) in rx {
                    slots[i] = Some(result);
                }
            });
        }
        // The job counter hands indices out monotonically and started
        // jobs always complete, so the lowest failing index is always
        // present and everything below it succeeded — the first error
        // in job order is deterministic even with the abort flag.
        let mut out = Vec::with_capacity(slots.len());
        for (i, slot) in slots.into_iter().enumerate() {
            match slot {
                Some(Ok(run)) => out.push(run),
                Some(Err(e)) => {
                    return Err(e.context(format!("job {i} ({})", jobs[i].spec())))
                }
                None => anyhow::bail!("job {i} skipped after an earlier failure"),
            }
        }
        Ok(out)
    }

    fn run_job(&self, job: &Job) -> Result<RunResult> {
        let needs_fresh = job
            .overrides
            .iter()
            .any(|(k, _)| SESSION_KEYS.contains(&k.as_str()));
        let mut run = if needs_fresh {
            let mut cfg = self.session.cfg.clone();
            job.apply(&mut cfg)?;
            self.session.rebuild(cfg)?.run()?
        } else {
            self.session.run_with_try(|cfg| job.apply(cfg))?
        };
        if let Some(label) = &job.label {
            run.label = label.clone();
        }
        Ok(run)
    }
}

/// Assemble the `repro grid` results matrix: the plan's axes plus one
/// row per job (its overrides and the run's deterministic summary).
/// Built exclusively from [`RunResult::summary_json`], so the record is
/// byte-identical across thread counts.
pub fn grid_record(plan: &Plan, jobs: &[Job], runs: &[RunResult]) -> Json {
    let axes = plan
        .axes()
        .iter()
        .map(|ax| {
            let mut a = Json::object();
            a.set("key", Json::Str(ax.key.clone())).set(
                "values",
                Json::Array(ax.values.iter().map(|v| Json::Str(v.clone())).collect()),
            );
            a
        })
        .collect();
    let rows = jobs
        .iter()
        .zip(runs)
        .map(|(job, run)| {
            let mut overrides = Json::object();
            for (k, v) in &job.overrides {
                overrides.set(k, Json::Str(v.clone()));
            }
            let mut row = Json::object();
            row.set("index", Json::Int(job.index as i64))
                .set("spec", Json::Str(job.spec()))
                .set("overrides", overrides)
                .set("summary", run.summary_json());
            row
        })
        .collect();
    let mut record = Json::object();
    record
        .set("axes", Json::Array(axes))
        .set("jobs", Json::Array(rows));
    record
}
