//! High-level launcher API: build data + learner once, run (paired)
//! federated experiments against them.
//!
//! Pairing matters for the paper's comparisons: FedAvg and every CSMAAFL
//! γ-variant must see the *same* synthetic dataset, partition, client
//! speed factors and model init, so accuracy differences are attributable
//! to the algorithm alone. A `Session` owns those shared pieces.

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::Result;

use crate::config::{AggregatorKind, RunConfig};
use crate::coordinator::{self, FlContext};
use crate::data::{generate, partition, ClientShard, Dataset};
use crate::learner::{Learner, LinearLearner, PjrtLearner};
use crate::log_info;
use crate::metrics::RunResult;
use crate::runtime::Engine;
use crate::telemetry::Telemetry;

/// Which learner executes local training.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnerKind {
    /// AOT CNN artifacts through PJRT (the production path).
    Pjrt,
    /// Pure-Rust softmax regression (fast; tests/benches).
    Linear,
}

impl LearnerKind {
    /// The learner a stock build can actually execute end-to-end — the
    /// single source of truth for "no `--learner` flag given".
    ///
    /// Always `Linear` for now: the `pjrt` cargo feature compiles the
    /// CNN execution path, but `runtime::xla` is not yet bound to a
    /// native PJRT runtime, so defaulting to `Pjrt` would fail every
    /// flag-less invocation. The PR that lands the native binding
    /// should make this feature-conditional.
    pub fn default_for_build() -> LearnerKind {
        LearnerKind::Linear
    }

    /// Parse a CLI spelling (`pjrt`/`cnn`, `linear`/`native`).
    pub fn parse(s: &str) -> Option<LearnerKind> {
        match s.to_ascii_lowercase().as_str() {
            "pjrt" | "cnn" => Some(LearnerKind::Pjrt),
            "linear" | "native" => Some(LearnerKind::Linear),
            _ => None,
        }
    }
}

enum SessionLearner {
    Linear(LinearLearner),
    // Never constructed without the `pjrt` feature (PjrtLearner wraps the
    // uninhabited engine stub), but still matched in learner()/engine().
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    Pjrt(PjrtLearner),
}

/// Shared experiment state: dataset, shards, learner, engine.
pub struct Session {
    /// The base configuration variants are derived from.
    pub cfg: RunConfig,
    /// The shared training set.
    pub train: Dataset,
    /// The shared held-out test set.
    pub test: Dataset,
    /// Per-client sample-index shards over `train`.
    pub shards: Vec<ClientShard>,
    learner: SessionLearner,
    kind: LearnerKind,
    artifacts_dir: String,
}

impl Session {
    /// Build a session. `artifacts_dir` is only read for `Pjrt` learners.
    pub fn new(cfg: RunConfig, kind: LearnerKind, artifacts_dir: &str) -> Result<Session> {
        cfg.validate()?;
        let (train, test) = generate(
            cfg.dataset,
            cfg.train_samples(),
            cfg.test_samples,
            cfg.seed,
        );
        let shards = partition(&train, cfg.clients, cfg.partition, cfg.seed);
        let learner = match kind {
            LearnerKind::Linear => SessionLearner::Linear(LinearLearner::default()),
            #[cfg(feature = "pjrt")]
            LearnerKind::Pjrt => {
                let engine = Engine::load(artifacts_dir, &cfg.model_config)
                    .context("loading PJRT engine (run `make artifacts` first)")?;
                SessionLearner::Pjrt(PjrtLearner::new(engine))
            }
            // Without the `pjrt` cargo feature the engine stub would fail
            // at load time anyway; bail before touching the artifacts
            // directory so the error names the build flag rather than a
            // missing manifest.
            #[cfg(not(feature = "pjrt"))]
            LearnerKind::Pjrt => {
                let _ = artifacts_dir;
                anyhow::bail!(
                    "the PJRT learner requires a build with `--features \
                     pjrt`; this binary only ships the pure-Rust learner \
                     (--learner linear)"
                );
            }
        };
        log_info!(
            "session: {} clients x {} samples ({} {}), {} test",
            cfg.clients,
            cfg.samples_per_client,
            cfg.dataset.name(),
            cfg.partition.name(),
            cfg.test_samples
        );
        Ok(Session {
            cfg,
            train,
            test,
            shards,
            learner,
            kind,
            artifacts_dir: artifacts_dir.to_string(),
        })
    }

    /// The learner kind the session was built with.
    pub fn learner_kind(&self) -> LearnerKind {
        self.kind
    }

    /// The artifacts directory the session was built with.
    pub fn artifacts_dir(&self) -> &str {
        &self.artifacts_dir
    }

    /// Build a sibling session over a different config with the same
    /// learner kind and artifacts directory. The experiment plan runner
    /// uses this when a job's overrides invalidate the shared data
    /// (clients, dataset, partition, seed, ...), so such jobs stay
    /// self-paired on their own config instead of silently reusing
    /// mismatched shards.
    pub fn rebuild(&self, cfg: RunConfig) -> Result<Session> {
        Session::new(cfg, self.kind, &self.artifacts_dir)
    }

    /// The session's local trainer/evaluator.
    pub fn learner(&self) -> &dyn Learner {
        match &self.learner {
            SessionLearner::Linear(l) => l,
            SessionLearner::Pjrt(p) => p,
        }
    }

    /// The PJRT engine, when the session runs the CNN learner.
    pub fn engine(&self) -> Option<&Engine> {
        match &self.learner {
            SessionLearner::Pjrt(p) => Some(p.engine()),
            SessionLearner::Linear(_) => None,
        }
    }

    /// Run with the session's config as-is.
    pub fn run(&self) -> Result<RunResult> {
        self.run_with(|_| {})
    }

    /// Run a variant: clone the config, let `mutate` adjust it, execute.
    /// Data, shards, client speeds and model init stay shared (paired).
    pub fn run_with(&self, mutate: impl FnOnce(&mut RunConfig)) -> Result<RunResult> {
        self.run_with_try(|cfg| {
            mutate(cfg);
            Ok(())
        })
    }

    /// Like [`Session::run_with`] but the mutation itself can fail (e.g.
    /// a sweep applying an untrusted `--set`-style override); its error
    /// propagates instead of panicking.
    pub fn run_with_try(
        &self,
        mutate: impl FnOnce(&mut RunConfig) -> Result<()>,
    ) -> Result<RunResult> {
        self.run_inner(mutate, &mut Telemetry::off())
    }

    /// As [`Session::run`], recording ordered trace events and aggregate
    /// histograms through `tel` (see [`crate::telemetry`]). Only the
    /// event-driven AFL engines emit; SFL and the solved-β baseline have
    /// no asynchronous decision points and run untraced.
    pub fn run_traced(&self, tel: &mut Telemetry) -> Result<RunResult> {
        self.run_inner(|_| Ok(()), tel)
    }

    fn run_inner(
        &self,
        mutate: impl FnOnce(&mut RunConfig) -> Result<()>,
        tel: &mut Telemetry,
    ) -> Result<RunResult> {
        let mut cfg = self.cfg.clone();
        mutate(&mut cfg)?;
        cfg.validate()?;
        if cfg.aggregator == AggregatorKind::Pjrt && self.engine().is_none() {
            anyhow::bail!("PJRT aggregator requires the PJRT learner");
        }
        let ctx = FlContext {
            cfg: &cfg,
            learner: self.learner(),
            engine: self.engine(),
            train: &self.train,
            shards: &self.shards,
            test: &self.test,
        };
        let t0 = std::time::Instant::now();
        let result = coordinator::run_traced(&ctx, tel)?;
        log_info!(
            "run[{}]: {} aggregations, final acc {:.3}, {:.1}s wall",
            result.label,
            result.aggregations,
            result.final_accuracy(),
            t0.elapsed().as_secs_f64()
        );
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Algorithm;
    use crate::data::Partition;

    fn tiny_cfg() -> RunConfig {
        RunConfig {
            clients: 4,
            samples_per_client: 20,
            test_samples: 50,
            local_steps: 4,
            max_slots: 3.0,
            ..RunConfig::default()
        }
    }

    #[test]
    fn linear_session_runs_all_algorithms() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        for alg in [
            Algorithm::Sfl,
            Algorithm::AflNaive,
            Algorithm::AflBaseline,
            Algorithm::Csmaafl,
        ] {
            let r = s.run_with(|c| c.algorithm = alg).unwrap();
            assert!(!r.points.is_empty(), "{alg:?} produced no points");
            assert!(r.points.iter().all(|p| p.accuracy.is_finite()));
            assert!(
                r.points.first().unwrap().slot <= 0.001,
                "first point at slot 0"
            );
        }
    }

    #[test]
    fn paired_runs_share_data() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        let a = s.run_with(|c| c.algorithm = Algorithm::Sfl).unwrap();
        let b = s.run_with(|c| c.algorithm = Algorithm::Sfl).unwrap();
        assert_eq!(a.points.len(), b.points.len());
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.accuracy, pb.accuracy, "identical reruns");
        }
    }

    #[test]
    fn rebuild_produces_a_sibling_with_its_own_data() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        assert_eq!(s.learner_kind(), LearnerKind::Linear);
        assert_eq!(s.artifacts_dir(), "artifacts");
        let mut cfg = tiny_cfg();
        cfg.clients = 2;
        let sib = s.rebuild(cfg).unwrap();
        assert_eq!(sib.shards.len(), 2);
        assert!(sib.run().unwrap().aggregations > 0);
    }

    #[test]
    fn noniid_session() {
        let mut cfg = tiny_cfg();
        cfg.partition = Partition::TwoClass;
        let s = Session::new(cfg, LearnerKind::Linear, "artifacts").unwrap();
        let r = s.run_with(|c| c.algorithm = Algorithm::Csmaafl).unwrap();
        assert!(r.aggregations > 0);
    }
}
