//! Run configuration: typed config with JSON file loading + CLI overrides.
//!
//! Every knob of a federated run lives here — algorithm, population,
//! data, time model, heterogeneity, CSMAAFL hyper-parameters — so a run
//! is fully described by one config (plus the artifacts manifest).

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::policy::{AggregationPolicy, PolicyParams};
use crate::coordinator::scheduler::SchedulerPolicy;
use crate::data::{Partition, SynthKind};
use crate::sim::{capacity, channel, scenario, HeterogeneityProfile, TimeModel};
use crate::util::json::{self, Json};

/// Which federated algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Synchronous FedAvg (the paper's comparator).
    Sfl,
    /// Sec. III-A: SFL α reused asynchronously (negative result).
    AflNaive,
    /// Sec. III-B: exact-equivalence AFL with solved β.
    AflBaseline,
    /// Sec. III-C: the paper's contribution.
    Csmaafl,
}

impl Algorithm {
    /// Parse a CLI/JSON spelling (`fedavg`, `afl-naive`, `baseline`,
    /// `csmaafl`, ...); returns `None` for unknown names.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s.to_ascii_lowercase().as_str() {
            "sfl" | "fedavg" => Some(Algorithm::Sfl),
            "afl-naive" | "naive" => Some(Algorithm::AflNaive),
            "afl-baseline" | "baseline" => Some(Algorithm::AflBaseline),
            "csmaafl" | "afl" => Some(Algorithm::Csmaafl),
            _ => None,
        }
    }

    /// Canonical series label used in CSVs, JSON records and figures.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Sfl => "fedavg",
            Algorithm::AflNaive => "afl-naive",
            Algorithm::AflBaseline => "afl-baseline",
            Algorithm::Csmaafl => "csmaafl",
        }
    }
}

/// Which aggregation implementation the server uses (ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggregatorKind {
    /// Rust axpy over host tensors (default hot path).
    Native,
    /// The AOT Pallas kernel artifact through PJRT.
    Pjrt,
}

impl AggregatorKind {
    /// Parse a CLI/JSON spelling (`native`, `pjrt`/`pallas`).
    pub fn parse(s: &str) -> Option<AggregatorKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Some(AggregatorKind::Native),
            "pjrt" | "pallas" => Some(AggregatorKind::Pjrt),
            _ => None,
        }
    }

    /// Canonical config spelling (JSON provenance).
    pub fn name(&self) -> &'static str {
        match self {
            AggregatorKind::Native => "native",
            AggregatorKind::Pjrt => "pjrt",
        }
    }
}

/// Full description of one federated run.
#[derive(Debug, Clone, PartialEq)]
pub struct RunConfig {
    /// Which federated algorithm the run executes.
    pub algorithm: Algorithm,
    /// Artifact model config name (manifest key), e.g. `mnist_small`.
    pub model_config: String,
    /// Number of clients M.
    pub clients: usize,
    /// Training samples owned by each client (equal shards ⇒ uniform α).
    pub samples_per_client: usize,
    /// Held-out test-set size.
    pub test_samples: usize,
    /// Which synthetic dataset to generate.
    pub dataset: SynthKind,
    /// How the training set is split across clients (IID vs two-class).
    pub partition: Partition,
    /// Base local SGD steps E per upload (adaptive policy scales this).
    pub local_steps: usize,
    /// Eq. (11) γ.
    pub gamma: f64,
    /// μ_ji EMA rate.
    pub mu_rho: f64,
    /// Root seed for data synthesis, partitioning, speeds and init.
    pub seed: u64,
    /// Sec. II-C communication/computation time parameters.
    pub time: TimeModel,
    /// How per-client compute speed factors are drawn.
    pub heterogeneity: HeterogeneityProfile,
    /// Per-round multiplicative compute jitter (0.1 = ±10%).
    pub jitter: f64,
    /// Stop after this many relative time slots.
    pub max_slots: f64,
    /// Evaluate the global model every this many slots.
    pub eval_every_slots: f64,
    /// Sec. III-C adaptive local-iteration policy on/off.
    pub adaptive_iters: bool,
    /// Which eq.-(3) aggregation implementation the server uses.
    pub aggregator: AggregatorKind,
    /// Aggregation-policy registry spelling (e.g. `staleness:0.4`,
    /// `fedasync:0.5`) overriding the algorithm's paper default for AFL
    /// runs; `None` (spelled `auto`) keeps the default.
    pub aggregation: Option<String>,
    /// Scenario-registry spelling (e.g. `dropout:0.1`, `churn:0.3`,
    /// `drift:8`) selecting the world model the event-driven AFL
    /// engines simulate; `None` (spelled `static`) keeps today's fixed
    /// world and is bit-identical to the pre-scenario engine.
    pub scenario: Option<String>,
    /// Capacity-profile registry spelling (e.g. `uniform:0.5`,
    /// `classes:1.0x0.5,0.5x0.3,0.25x0.2`) assigning each client a
    /// HeteroFL-style submodel rate; `None` (spelled `full`) keeps
    /// every client at rate 1.0 and is bit-identical to the
    /// pre-submodel engines.
    pub capacity: Option<String>,
    /// Fading-channel registry spelling (e.g. `markov:0.5,500`) giving
    /// each client a block-fading link that scales upload time and
    /// drives correlated transmission failures; `None` (spelled
    /// `ideal`) keeps every link perfect and is bit-identical to the
    /// pre-channel engines. Simulation-only: `repro serve`/`join`
    /// reject it (deployment uses real links).
    pub channel: Option<String>,
    /// Upload-slot arbitration policy (AFL engines).
    pub scheduler: SchedulerPolicy,
    /// Worker threads for the learner-driven AFL engines (`repro
    /// train/compare/figures`): `None` (spelled `auto`) uses every
    /// available core. Bit-identical at any value by the
    /// `coordinator::learner_shard` contract, so — unlike
    /// aggregation/scenario/capacity — no algorithm gating: engines
    /// without a sharded twin simply run single-threaded and the
    /// setting only ever changes wall-clock.
    pub shards: Option<usize>,
    /// Failure injection: probability that a granted upload is lost in
    /// transit (the server re-downloads the current global so the client
    /// rejoins; its local work is wasted). 0 = reliable channel.
    pub upload_loss: f64,
    /// SFL client sampling fraction (McMahan et al. [2]): each round the
    /// server waits only for this share of clients, chosen at random.
    /// 1.0 = full participation (the paper's default setting).
    pub sfl_sample_fraction: f64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            algorithm: Algorithm::Csmaafl,
            model_config: "mnist_small".into(),
            clients: 20,
            samples_per_client: 80,
            test_samples: 500,
            dataset: SynthKind::Mnist,
            partition: Partition::Iid,
            // ~3 local epochs per upload (the paper's clients run ~120
            // steps per round on 600 images; scaled to 80-image shards).
            local_steps: 48,
            gamma: 0.2,
            mu_rho: 0.1,
            seed: 42,
            time: TimeModel::default(),
            heterogeneity: HeterogeneityProfile::Uniform { max_factor: 4.0 },
            jitter: 0.1,
            max_slots: 40.0,
            eval_every_slots: 1.0,
            adaptive_iters: true,
            aggregator: AggregatorKind::Native,
            aggregation: None,
            scenario: None,
            capacity: None,
            channel: None,
            scheduler: SchedulerPolicy::OldestModelFirst,
            shards: None,
            upload_loss: 0.0,
            sfl_sample_fraction: 1.0,
        }
    }
}

impl RunConfig {
    /// Check cross-field invariants; every entry point calls this before
    /// running so misconfigurations fail fast with a named field.
    pub fn validate(&self) -> Result<()> {
        if self.clients == 0 {
            bail!("clients must be > 0");
        }
        if self.samples_per_client < 2 {
            bail!("samples_per_client must be >= 2 (non-IID needs 2 shards)");
        }
        if self.local_steps == 0 {
            bail!("local_steps must be > 0");
        }
        if self.gamma <= 0.0 {
            bail!("gamma must be > 0");
        }
        if !(0.0..=1.0).contains(&self.mu_rho) {
            bail!("mu_rho must be in [0,1]");
        }
        if self.max_slots <= 0.0 || self.eval_every_slots <= 0.0 {
            bail!("max_slots and eval_every_slots must be > 0");
        }
        if !(0.0..1.0).contains(&self.upload_loss) {
            bail!("upload_loss must be in [0,1)");
        }
        if !(0.0..=1.0).contains(&self.sfl_sample_fraction) || self.sfl_sample_fraction == 0.0 {
            bail!("sfl_sample_fraction must be in (0,1]");
        }
        if let Some(spec) = &self.aggregation {
            // Only the event-driven AFL engines consult the registry;
            // accepting the override elsewhere would silently run a
            // different rule than the user asked for.
            if !matches!(self.algorithm, Algorithm::AflNaive | Algorithm::Csmaafl) {
                bail!(
                    "aggregation overrides apply only to the event-driven AFL \
                     engines (afl-naive/csmaafl); algorithm {} uses its fixed rule",
                    self.algorithm.name()
                );
            }
            let params = PolicyParams {
                clients: self.clients,
                gamma: self.gamma,
            };
            <dyn AggregationPolicy>::parse(spec, &params)
                .with_context(|| format!("aggregation policy {spec:?}"))?;
        }
        if let Some(spec) = &self.scenario {
            // Only the event-driven AFL engines consult the scenario
            // hooks; accepting the spelling elsewhere would silently run
            // a different world than the user asked for.
            if !matches!(self.algorithm, Algorithm::AflNaive | Algorithm::Csmaafl) {
                bail!(
                    "scenario overrides apply only to the event-driven AFL \
                     engines (afl-naive/csmaafl); algorithm {} simulates the \
                     static world",
                    self.algorithm.name()
                );
            }
            scenario::parse(spec).with_context(|| format!("scenario {spec:?}"))?;
        }
        if self.shards == Some(0) {
            bail!("shards must be >= 1 (or `auto`)");
        }
        let profile = capacity::resolve(self.capacity.as_deref())?;
        if !profile.is_trivial()
            && !matches!(self.algorithm, Algorithm::AflNaive | Algorithm::Csmaafl)
        {
            // Only the event-driven AFL engines thread submodels through
            // aggregation; the SFL and solved-β sweeps presume every
            // client trains the full model, so accepting the profile
            // would silently run a different workload.
            bail!(
                "capacity profiles apply only to the event-driven AFL \
                 engines (afl-naive/csmaafl); algorithm {} trains full \
                 models",
                self.algorithm.name()
            );
        }
        let fading = channel::resolve(self.channel.as_deref())?;
        if !fading.is_trivial()
            && !matches!(self.algorithm, Algorithm::AflNaive | Algorithm::Csmaafl)
        {
            // Only the event-driven AFL engines consult the channel
            // process; SFL and solved-β presume the TDMA slot structure
            // of an ideal link, so accepting the model would silently
            // simulate a different medium.
            bail!(
                "channel models apply only to the event-driven AFL \
                 engines (afl-naive/csmaafl); algorithm {} assumes an \
                 ideal channel",
                self.algorithm.name()
            );
        }
        Ok(())
    }

    /// Total training samples across clients.
    pub fn train_samples(&self) -> usize {
        self.clients * self.samples_per_client
    }

    /// Load from a JSON config file, then apply `overrides` ("key=value").
    pub fn load(path: &str, overrides: &[(String, String)]) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {path}"))?;
        let j = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
        let mut cfg = Self::from_json(&j)?;
        for (k, v) in overrides {
            cfg.set_field(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build a config from a parsed JSON object: defaults first, then
    /// every present key applied through [`RunConfig::set_field`].
    pub fn from_json(j: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let obj = j.as_object().ok_or_else(|| anyhow!("config must be an object"))?;
        for (k, v) in obj {
            let vs = match v {
                Json::Str(s) => s.clone(),
                other => other.to_string_compact(),
            };
            cfg.set_field(k, &vs)
                .with_context(|| format!("config field {k}"))?;
        }
        Ok(cfg)
    }

    /// Set one field from its string form (shared by JSON + CLI overrides).
    pub fn set_field(&mut self, key: &str, val: &str) -> Result<()> {
        let badval = || anyhow!("invalid value {val:?} for {key}");
        match key {
            "algorithm" => self.algorithm = Algorithm::parse(val).ok_or_else(badval)?,
            "model_config" => self.model_config = val.to_string(),
            "clients" => self.clients = val.parse().map_err(|_| badval())?,
            "samples_per_client" => self.samples_per_client = val.parse().map_err(|_| badval())?,
            "test_samples" => self.test_samples = val.parse().map_err(|_| badval())?,
            "dataset" => self.dataset = SynthKind::parse(val).ok_or_else(badval)?,
            "partition" => self.partition = Partition::parse(val).ok_or_else(badval)?,
            "local_steps" => self.local_steps = val.parse().map_err(|_| badval())?,
            "gamma" => self.gamma = val.parse().map_err(|_| badval())?,
            "mu_rho" => self.mu_rho = val.parse().map_err(|_| badval())?,
            "seed" => self.seed = val.parse().map_err(|_| badval())?,
            "tau_down" => self.time.tau_down = val.parse().map_err(|_| badval())?,
            "tau_step" => self.time.tau_step = val.parse().map_err(|_| badval())?,
            "tau_up" => self.time.tau_up = val.parse().map_err(|_| badval())?,
            "heterogeneity" => {
                self.heterogeneity = HeterogeneityProfile::parse(val).ok_or_else(badval)?
            }
            "max_factor" => {
                self.heterogeneity = HeterogeneityProfile::Uniform {
                    max_factor: val.parse().map_err(|_| badval())?,
                }
            }
            "jitter" => self.jitter = val.parse().map_err(|_| badval())?,
            "max_slots" => self.max_slots = val.parse().map_err(|_| badval())?,
            "eval_every_slots" => self.eval_every_slots = val.parse().map_err(|_| badval())?,
            "adaptive_iters" => self.adaptive_iters = val.parse().map_err(|_| badval())?,
            "aggregator" => self.aggregator = AggregatorKind::parse(val).ok_or_else(badval)?,
            // Policy spellings are validated against the registry (with
            // the final clients/gamma) in `validate`.
            "aggregation" => {
                self.aggregation = if val.eq_ignore_ascii_case("auto") {
                    None
                } else {
                    Some(val.to_string())
                }
            }
            // Scenario spellings are validated against the registry in
            // `validate` (like aggregation); `static` is the pinned
            // default, stored as None so provenance roundtrips.
            "scenario" => {
                self.scenario = if val.eq_ignore_ascii_case("static") {
                    None
                } else {
                    Some(val.to_string())
                }
            }
            // Capacity spellings are validated against the registry in
            // `validate`; `full` is the pinned default, stored as None
            // so provenance roundtrips.
            "capacity" => {
                self.capacity = if val.eq_ignore_ascii_case("full") {
                    None
                } else {
                    Some(val.to_string())
                }
            }
            // Channel spellings are validated against the registry in
            // `validate`; `ideal` is the pinned default, stored as None
            // so provenance roundtrips.
            "channel" => {
                self.channel = if val.eq_ignore_ascii_case("ideal") {
                    None
                } else {
                    Some(val.to_string())
                }
            }
            "scheduler" => self.scheduler = SchedulerPolicy::parse(val).ok_or_else(badval)?,
            // Learner-engine worker count; `auto` (all cores) is the
            // pinned default, stored as None so provenance roundtrips.
            "shards" => {
                self.shards = if val.eq_ignore_ascii_case("auto") {
                    None
                } else {
                    let n: usize = val.parse().map_err(|_| badval())?;
                    if n == 0 {
                        bail!("shards must be >= 1 (or `auto`), got 0");
                    }
                    Some(n)
                }
            }
            "upload_loss" => self.upload_loss = val.parse().map_err(|_| badval())?,
            "sfl_sample_fraction" => {
                self.sfl_sample_fraction = val.parse().map_err(|_| badval())?
            }
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    /// Serialize to the JSON object form accepted by
    /// [`RunConfig::from_json`] (run-record provenance).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("algorithm", Json::Str(self.algorithm.name().into()))
            .set("model_config", Json::Str(self.model_config.clone()))
            .set("clients", Json::Int(self.clients as i64))
            .set("samples_per_client", Json::Int(self.samples_per_client as i64))
            .set("test_samples", Json::Int(self.test_samples as i64))
            .set("dataset", Json::Str(self.dataset.name().into()))
            .set("partition", Json::Str(self.partition.name().into()))
            .set("local_steps", Json::Int(self.local_steps as i64))
            .set("gamma", Json::Float(self.gamma))
            .set("mu_rho", Json::Float(self.mu_rho))
            .set("seed", Json::Int(self.seed as i64))
            .set("tau_down", Json::Int(self.time.tau_down as i64))
            .set("tau_step", Json::Int(self.time.tau_step as i64))
            .set("tau_up", Json::Int(self.time.tau_up as i64))
            .set("jitter", Json::Float(self.jitter))
            .set("max_slots", Json::Float(self.max_slots))
            .set("eval_every_slots", Json::Float(self.eval_every_slots))
            .set("adaptive_iters", Json::Bool(self.adaptive_iters))
            .set("upload_loss", Json::Float(self.upload_loss))
            .set("sfl_sample_fraction", Json::Float(self.sfl_sample_fraction))
            .set("heterogeneity", Json::Str(self.heterogeneity.spec()))
            .set("aggregator", Json::Str(self.aggregator.name().into()))
            .set(
                "aggregation",
                Json::Str(self.aggregation.clone().unwrap_or_else(|| "auto".into())),
            )
            .set(
                "scenario",
                Json::Str(self.scenario.clone().unwrap_or_else(|| "static".into())),
            )
            .set(
                "capacity",
                Json::Str(self.capacity.clone().unwrap_or_else(|| "full".into())),
            )
            .set(
                "channel",
                Json::Str(self.channel.clone().unwrap_or_else(|| "ideal".into())),
            )
            .set("scheduler", Json::Str(self.scheduler.name().into()))
            .set(
                "shards",
                Json::Str(
                    self.shards
                        .map_or_else(|| "auto".into(), |n| n.to_string()),
                ),
            );
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn set_field_roundtrip() {
        let mut c = RunConfig::default();
        c.set_field("algorithm", "fedavg").unwrap();
        assert_eq!(c.algorithm, Algorithm::Sfl);
        c.set_field("clients", "50").unwrap();
        assert_eq!(c.clients, 50);
        c.set_field("gamma", "0.4").unwrap();
        assert_eq!(c.gamma, 0.4);
        c.set_field("dataset", "fashion").unwrap();
        assert_eq!(c.dataset, SynthKind::Fashion);
        c.set_field("partition", "noniid").unwrap();
        assert_eq!(c.partition, Partition::TwoClass);
        c.set_field("adaptive_iters", "false").unwrap();
        assert!(!c.adaptive_iters);
        c.set_field("scheduler", "fifo").unwrap();
        assert_eq!(c.scheduler, SchedulerPolicy::Fifo);
        c.set_field("aggregator", "pjrt").unwrap();
        assert_eq!(c.aggregator, AggregatorKind::Pjrt);
        c.set_field("aggregation", "fedasync:0.5").unwrap();
        assert_eq!(c.aggregation.as_deref(), Some("fedasync:0.5"));
        c.set_field("aggregation", "auto").unwrap();
        assert_eq!(c.aggregation, None);
        c.set_field("scenario", "dropout:0.1").unwrap();
        assert_eq!(c.scenario.as_deref(), Some("dropout:0.1"));
        c.set_field("scenario", "static").unwrap();
        assert_eq!(c.scenario, None);
        c.set_field("capacity", "classes:1.0x0.5,0.5x0.5").unwrap();
        assert_eq!(c.capacity.as_deref(), Some("classes:1.0x0.5,0.5x0.5"));
        c.set_field("capacity", "full").unwrap();
        assert_eq!(c.capacity, None);
        c.set_field("channel", "markov:0.5,500").unwrap();
        assert_eq!(c.channel.as_deref(), Some("markov:0.5,500"));
        c.set_field("channel", "ideal").unwrap();
        assert_eq!(c.channel, None);
        c.set_field("shards", "4").unwrap();
        assert_eq!(c.shards, Some(4));
        c.set_field("shards", "auto").unwrap();
        assert_eq!(c.shards, None);
        assert!(c.set_field("shards", "0").is_err());
        assert!(c.set_field("shards", "many").is_err());
        assert!(c.set_field("nonsense", "1").is_err());
        assert!(c.set_field("clients", "abc").is_err());
    }

    #[test]
    fn validation_catches_zero_shards() {
        let c = RunConfig {
            shards: Some(0),
            ..RunConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("shards must be >= 1"), "{err}");
        // Any positive count is valid for ANY algorithm: engines without
        // a sharded twin just run single-threaded (wall-clock only).
        let c = RunConfig {
            algorithm: Algorithm::Sfl,
            shards: Some(8),
            ..RunConfig::default()
        };
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_aggregation_spec() {
        let mut c = RunConfig {
            aggregation: Some("bogus".into()),
            ..RunConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        c.aggregation = Some("staleness:0.4".into());
        c.validate().unwrap();
        // Engines that cannot honor the override must refuse it rather
        // than silently running their fixed rule.
        c.algorithm = Algorithm::Sfl;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("fixed rule"), "{err}");
        c.algorithm = Algorithm::AflBaseline;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_scenario_spec() {
        let mut c = RunConfig {
            scenario: Some("bogus".into()),
            ..RunConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        c.scenario = Some("churn:0.3,2".into());
        c.validate().unwrap();
        // Engines that never consult the scenario hooks must refuse the
        // override rather than silently simulating the static world.
        c.algorithm = Algorithm::Sfl;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("static world"), "{err}");
        c.algorithm = Algorithm::AflBaseline;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_capacity_spec() {
        let mut c = RunConfig {
            capacity: Some("bogus".into()),
            ..RunConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        c.capacity = Some("classes:1.0x0.5,0.5x0.5".into());
        c.validate().unwrap();
        // Engines that train full models must refuse a non-trivial
        // profile rather than silently ignoring it...
        c.algorithm = Algorithm::Sfl;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("full models"), "{err}");
        c.algorithm = Algorithm::AflBaseline;
        assert!(c.validate().is_err());
        // ...but the trivial spelling is fine everywhere (it IS the
        // full-model workload).
        c.capacity = Some("uniform:1.0".into());
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_channel_spec() {
        let mut c = RunConfig {
            channel: Some("bogus".into()),
            ..RunConfig::default()
        };
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        c.channel = Some("markov:0.3,200".into());
        c.validate().unwrap();
        // Engines with no channel hooks must refuse the model rather
        // than silently simulating a perfect medium...
        c.algorithm = Algorithm::Sfl;
        let err = c.validate().unwrap_err().to_string();
        assert!(err.contains("ideal channel"), "{err}");
        c.algorithm = Algorithm::AflBaseline;
        assert!(c.validate().is_err());
        // ...but the trivial spelling is fine everywhere (it IS the
        // perfect medium those engines presume).
        c.channel = Some("ideal".into());
        c.validate().unwrap();
    }

    #[test]
    fn from_json_full() {
        let j = json::parse(
            r#"{"algorithm": "csmaafl", "clients": 10, "gamma": 0.6,
                "dataset": "fashion", "partition": "iid", "tau_up": 200}"#,
        )
        .unwrap();
        let c = RunConfig::from_json(&j).unwrap();
        assert_eq!(c.clients, 10);
        assert_eq!(c.gamma, 0.6);
        assert_eq!(c.time.tau_up, 200);
    }

    #[test]
    fn validation_catches_bad_values() {
        let c = RunConfig {
            clients: 0,
            ..RunConfig::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            gamma: 0.0,
            ..RunConfig::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            max_slots: -1.0,
            ..RunConfig::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        // Full-struct equality: a stored run record must reproduce the
        // run, so every field — including heterogeneity, aggregator and
        // aggregation, which an earlier to_json dropped — roundtrips.
        let c = RunConfig::default();
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c);

        // And with every provenance-prone field set off-default.
        let c = RunConfig {
            heterogeneity: HeterogeneityProfile::Extreme {
                fast_frac: 0.25,
                slow_frac: 0.125,
                mid_factor: 3.5,
                slow_factor: 12.0,
            },
            aggregator: AggregatorKind::Pjrt,
            aggregation: Some("fedasync:0.5,0.9".into()),
            scenario: Some("drift:8,2.5".into()),
            capacity: Some("classes:1.0x0.5,0.5x0.5".into()),
            channel: Some("markov:0.5,500".into()),
            scheduler: SchedulerPolicy::RoundRobin,
            shards: Some(3),
            jitter: 0.25,
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c);

        let c = RunConfig {
            heterogeneity: HeterogeneityProfile::Lognormal { sigma: 0.75 },
            ..RunConfig::default()
        };
        let c2 = RunConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c2, c);
    }
}
