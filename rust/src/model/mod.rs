//! Model parameter containers shared by the runtime and the coordinator.

mod params;

pub use params::{ParamSet, Tensor, TensorSpec};
