//! Model parameter containers shared by the runtime and the coordinator.

mod params;
mod submodel;

pub use params::{
    axpy_flat, l2_accumulate, lerp_flat, ParamArena, ParamLayout, ParamSet, SlotId, Tensor,
    TensorSpec,
};
pub use submodel::{finalize_overlap_mean, SubmodelMap, SubmodelSlice};
pub(crate) use params::SlotWindow;
