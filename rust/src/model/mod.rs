//! Model parameter containers shared by the runtime and the coordinator.

mod params;
mod submodel;

pub use params::{
    axpy_flat, axpy_flat_scalar, l2_accumulate, lerp_flat, lerp_flat_par, lerp_flat_scalar,
    ParamArena, ParamLayout, ParamSet, SlotId, Tensor, TensorSpec, KERNEL_CHUNK,
};
pub use submodel::{finalize_overlap_mean, SubmodelMap, SubmodelSlice};
pub(crate) use params::SlotWindow;
