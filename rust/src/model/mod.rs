//! Model parameter containers shared by the runtime and the coordinator.

mod params;

pub use params::{
    axpy_flat, l2_accumulate, lerp_flat, ParamArena, ParamLayout, ParamSet, SlotId, Tensor,
    TensorSpec,
};
pub(crate) use params::SlotWindow;
