//! Host-side model parameters.
//!
//! The manifest fixes an *ordered* list of named tensors; `ParamSet` is the
//! host representation that flows between the PJRT runtime (as literals /
//! device buffers) and the coordinator (aggregation, distance metrics).
//!
//! Two storage forms share one arithmetic:
//!
//! * [`ParamSet`] — the interchange form (named tensors, one `Vec<f32>`
//!   each) used by learners, the PJRT seam and run records.
//! * [`ParamArena`] — the hot-path form: a structure-of-arrays pool of
//!   parameter vectors over one [`ParamLayout`], flat and contiguous,
//!   with freelist slot recycling so steady-state aggregation performs
//!   **zero** per-update heap allocation.
//!
//! All weighted-average arithmetic bottoms out in the flat kernels
//! ([`lerp_flat`], [`axpy_flat`], [`l2_accumulate`]); the `ParamSet`
//! methods are per-tensor wrappers over the same code, so the two forms
//! are bit-identical by construction (asserted in `tests/properties.rs`).
//! The shipping kernels are chunked for reliable autovectorization (SSE2
//! intrinsics under `--features simd` on x86_64), with the original
//! scalar loops retained as the executable reference
//! ([`lerp_flat_scalar`], [`axpy_flat_scalar`]) and a scoped-thread
//! parallel variant ([`lerp_flat_par`]) for oversized models — every
//! variant bit-identical to the reference (differential fuzz harness in
//! `tests/properties.rs`).

use std::fmt;

/// Static description of one parameter tensor (from the manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// The tensor's manifest name (e.g. `conv1/kernel`).
    pub name: String,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total scalar element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Shape + name of this tensor.
    pub spec: TensorSpec,
    /// Row-major element data (`spec.numel()` values).
    pub data: Vec<f32>,
}

impl Tensor {
    /// An all-zero tensor of the given spec.
    pub fn zeros(spec: TensorSpec) -> Self {
        let n = spec.numel();
        Tensor {
            spec,
            data: vec![0.0; n],
        }
    }

    /// Wrap existing data; panics if the length does not match the spec.
    pub fn from_data(spec: TensorSpec, data: Vec<f32>) -> Self {
        assert_eq!(
            spec.numel(),
            data.len(),
            "tensor {}: shape {:?} != data len {}",
            spec.name,
            spec.shape,
            data.len()
        );
        Tensor { spec, data }
    }
}

// ------------------------------------------------------- flat kernels
//
// Three shapes of the same arithmetic, all bit-identical by
// construction because every element goes through the *same scalar
// expression* (`b*x + a*y` as two f32 muls then one add — never an FMA
// contraction, which would change the rounding) regardless of which
// loop shape, lane or thread computes it:
//
// * `*_scalar`  — the executable reference: the plain zip loop. Kept
//   public so the differential harness (`tests/properties.rs`) always
//   compares the shipping kernel against the original code, not against
//   a copy of itself.
// * the default — fixed-width chunks of [`KERNEL_CHUNK`] plus a scalar
//   remainder. The bounded inner loop over an 8-wide array pattern is
//   the shape LLVM's loop vectorizer reliably turns into packed mul/add
//   sequences, where the plain zip loop's vectorization depends on
//   iterator desugaring.
// * `--features simd` (x86_64 only) — explicit SSE2 intrinsics
//   (`_mm_mul_ps`/`_mm_add_ps`). SSE2 is baseline on x86_64 (no runtime
//   detection needed) and has no FMA, so each lane performs exactly the
//   scalar mul-mul-add rounding. Non-x86_64 builds with the feature get
//   the chunked path.
//
// `l2_accumulate` is deliberately *not* chunked or parallelized: its
// f64 accumulator chain is a serial dependency in program order, and
// callers (`ParamSet::l2_distance*`, `SubmodelMap::l2_distance_set`)
// chain several tensor ranges through one accumulator expecting the
// exact rounding of a single sequential pass. Any reassociation would
// change results; keeping it scalar IS the contract.

/// Fixed chunk width of the vector-friendly kernel inner loops. Public
/// so the differential fuzz harness can probe the remainder boundaries
/// (`KERNEL_CHUNK − 1`, `KERNEL_CHUNK`, `KERNEL_CHUNK + 1`).
pub const KERNEL_CHUNK: usize = 8;

/// Scalar reference of [`lerp_flat`]: the original elementwise zip loop.
/// Every other lerp variant must match this bit-for-bit on every input
/// (`tests/properties.rs` differential harness).
pub fn lerp_flat_scalar(global: &mut [f32], local: &[f32], beta: f32) {
    assert_eq!(global.len(), local.len(), "lerp over mismatched buffers");
    let b = beta;
    let a = 1.0 - beta;
    for (x, y) in global.iter_mut().zip(local) {
        *x = b * *x + a * *y;
    }
}

/// Scalar reference of [`axpy_flat`] (see [`lerp_flat_scalar`]).
pub fn axpy_flat_scalar(acc: &mut [f32], other: &[f32], w: f32) {
    assert_eq!(acc.len(), other.len(), "axpy over mismatched buffers");
    for (x, y) in acc.iter_mut().zip(other) {
        *x += w * *y;
    }
}

/// In-place convex combination over flat buffers:
/// `global[k] = beta*global[k] + (1-beta)*local[k]` — the eq. (3) server
/// aggregation kernel every storage form shares. Chunked (or, under
/// `--features simd` on x86_64, SSE2) but bit-identical to
/// [`lerp_flat_scalar`]; see the module-section comment above.
pub fn lerp_flat(global: &mut [f32], local: &[f32], beta: f32) {
    assert_eq!(global.len(), local.len(), "lerp over mismatched buffers");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    lerp_flat_sse2(global, local, beta);
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    lerp_flat_chunked(global, local, beta);
}

/// Weighted accumulation over flat buffers: `acc[k] += w * other[k]`
/// (the FedAvg reduction kernel). Chunked/SSE2 like [`lerp_flat`];
/// bit-identical to [`axpy_flat_scalar`].
pub fn axpy_flat(acc: &mut [f32], other: &[f32], w: f32) {
    assert_eq!(acc.len(), other.len(), "axpy over mismatched buffers");
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    axpy_flat_sse2(acc, other, w);
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    axpy_flat_chunked(acc, other, w);
}

#[cfg_attr(all(feature = "simd", target_arch = "x86_64"), allow(dead_code))]
fn lerp_flat_chunked(global: &mut [f32], local: &[f32], beta: f32) {
    let b = beta;
    let a = 1.0 - beta;
    let mut gc = global.chunks_exact_mut(KERNEL_CHUNK);
    let mut lc = local.chunks_exact(KERNEL_CHUNK);
    for (gs, ls) in gc.by_ref().zip(lc.by_ref()) {
        for k in 0..KERNEL_CHUNK {
            gs[k] = b * gs[k] + a * ls[k];
        }
    }
    for (x, y) in gc.into_remainder().iter_mut().zip(lc.remainder()) {
        *x = b * *x + a * *y;
    }
}

#[cfg_attr(all(feature = "simd", target_arch = "x86_64"), allow(dead_code))]
fn axpy_flat_chunked(acc: &mut [f32], other: &[f32], w: f32) {
    let mut ac = acc.chunks_exact_mut(KERNEL_CHUNK);
    let mut oc = other.chunks_exact(KERNEL_CHUNK);
    for (xs, ys) in ac.by_ref().zip(oc.by_ref()) {
        for k in 0..KERNEL_CHUNK {
            xs[k] += w * ys[k];
        }
    }
    for (x, y) in ac.into_remainder().iter_mut().zip(oc.remainder()) {
        *x += w * *y;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn lerp_flat_sse2(global: &mut [f32], local: &[f32], beta: f32) {
    use std::arch::x86_64::*;
    let b = beta;
    let a = 1.0 - beta;
    let n = global.len();
    let head = n - n % 4;
    // SAFETY: SSE2 is baseline on x86_64; unaligned loads/stores
    // (`loadu`/`storeu`) over in-bounds ranges (idx + 4 <= head <= n).
    // `_mm_mul_ps`/`_mm_add_ps` round each lane exactly like the scalar
    // f32 mul/add — no FMA contraction — so lanes match the reference.
    unsafe {
        let vb = _mm_set1_ps(b);
        let va = _mm_set1_ps(a);
        let mut idx = 0;
        while idx < head {
            let vx = _mm_loadu_ps(global.as_ptr().add(idx));
            let vy = _mm_loadu_ps(local.as_ptr().add(idx));
            let r = _mm_add_ps(_mm_mul_ps(vb, vx), _mm_mul_ps(va, vy));
            _mm_storeu_ps(global.as_mut_ptr().add(idx), r);
            idx += 4;
        }
    }
    for (x, y) in global[head..].iter_mut().zip(&local[head..]) {
        *x = b * *x + a * *y;
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
fn axpy_flat_sse2(acc: &mut [f32], other: &[f32], w: f32) {
    use std::arch::x86_64::*;
    let n = acc.len();
    let head = n - n % 4;
    // SAFETY: as `lerp_flat_sse2` — baseline SSE2, unaligned in-bounds
    // accesses, lane rounding identical to the scalar expression.
    unsafe {
        let vw = _mm_set1_ps(w);
        let mut idx = 0;
        while idx < head {
            let vx = _mm_loadu_ps(acc.as_ptr().add(idx));
            let vy = _mm_loadu_ps(other.as_ptr().add(idx));
            let r = _mm_add_ps(vx, _mm_mul_ps(vw, vy));
            _mm_storeu_ps(acc.as_mut_ptr().add(idx), r);
            idx += 4;
        }
    }
    for (x, y) in acc[head..].iter_mut().zip(&other[head..]) {
        *x += w * *y;
    }
}

/// Parallel [`lerp_flat`] over `threads` disjoint contiguous ranges
/// (sizes differing by at most one), each run through the shipping
/// kernel on its own scoped thread. Elementwise arithmetic has no
/// cross-element dependency, so the split is bit-identical to one
/// sequential pass at every thread count — the differential harness
/// asserts it.
///
/// Worth it only for buffers far larger than the paper's models (the
/// 431,080-param CNN lerps in well under a millisecond), which is why
/// the engines call [`lerp_flat`] directly and this entry point exists
/// for oversized models, the bench suite and the harness.
pub fn lerp_flat_par(global: &mut [f32], local: &[f32], beta: f32, threads: usize) {
    assert_eq!(global.len(), local.len(), "lerp over mismatched buffers");
    let n = global.len();
    let threads = threads.clamp(1, n.max(1));
    if threads == 1 {
        lerp_flat(global, local, beta);
        return;
    }
    let base = n / threads;
    let rem = n % threads;
    std::thread::scope(|scope| {
        let mut g = global;
        let mut l = local;
        for k in 0..threads {
            let len = base + usize::from(k < rem);
            // `take` moves the tail out so each head keeps the full
            // scope lifetime (a plain reborrow would not outlive the
            // loop body).
            let (gh, gt) = std::mem::take(&mut g).split_at_mut(len);
            let (lh, lt) = l.split_at(len);
            g = gt;
            l = lt;
            scope.spawn(move || lerp_flat(gh, lh, beta));
        }
    });
}

/// Accumulate the squared L2 distance of two flat buffers into `acc`
/// (element-sequential f64 accumulation, so callers chaining several
/// tensor ranges through one accumulator reproduce the exact rounding
/// of a single pass over the concatenated data). Deliberately scalar:
/// the accumulator is a serial dependency chain, and reassociating it
/// (chunked partial sums, SIMD lanes, threads) would change the
/// rounding — see the kernel-section comment above.
pub fn l2_accumulate(acc: &mut f64, a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len(), "distance over mismatched buffers");
    for (x, y) in a.iter().zip(b) {
        let d = (*x - *y) as f64;
        *acc += d * d;
    }
}

/// An ordered set of parameter tensors (the manifest contract).
#[derive(Clone, PartialEq, Default)]
pub struct ParamSet {
    /// The model's tensors in manifest order.
    pub tensors: Vec<Tensor>,
}

impl fmt::Debug for ParamSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ParamSet[{} tensors, {} params]", self.tensors.len(), self.numel())
    }
}

impl ParamSet {
    /// An all-zero parameter set over the given specs.
    pub fn zeros(specs: &[TensorSpec]) -> Self {
        ParamSet {
            tensors: specs.iter().cloned().map(Tensor::zeros).collect(),
        }
    }

    /// Total scalar parameter count across all tensors.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.spec.numel()).sum()
    }

    /// The ordered tensor specs (the manifest contract).
    pub fn specs(&self) -> Vec<TensorSpec> {
        self.tensors.iter().map(|t| t.spec.clone()).collect()
    }

    /// In-place convex combination: `self = beta*self + (1-beta)*other`
    /// — the eq.(3) server aggregation (native hot path; see
    /// coordinator::aggregation for the PJRT/Pallas alternative).
    /// Per-tensor wrapper over [`lerp_flat`], so this path and the
    /// arena's flat path are the same arithmetic.
    pub fn lerp_inplace(&mut self, other: &ParamSet, beta: f32) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            debug_assert_eq!(t.spec, o.spec);
            lerp_flat(&mut t.data, &o.data, beta);
        }
    }

    /// Weighted accumulation: `self += w * other` (FedAvg reduction).
    pub fn axpy_inplace(&mut self, other: &ParamSet, w: f32) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            axpy_flat(&mut t.data, &o.data, w);
        }
    }

    /// Multiply every element by `s`.
    pub fn scale_inplace(&mut self, s: f32) {
        for t in &mut self.tensors {
            for x in &mut t.data {
                *x *= s;
            }
        }
    }

    /// L2 distance between two parameter sets (staleness diagnostics).
    pub fn l2_distance(&self, other: &ParamSet) -> f64 {
        let mut acc = 0.0f64;
        for (t, o) in self.tensors.iter().zip(&other.tensors) {
            l2_accumulate(&mut acc, &t.data, &o.data);
        }
        acc.sqrt()
    }

    /// In-place convex combination against a flat buffer laid out in
    /// manifest order — the arena-path twin of
    /// [`ParamSet::lerp_inplace`], bit-identical because both run every
    /// element through [`lerp_flat`]. Keeps the offset walk here so the
    /// flat layout is defined in exactly one module.
    pub fn lerp_inplace_flat(&mut self, flat: &[f32], beta: f32) {
        assert_eq!(flat.len(), self.numel(), "flat buffer length mismatch");
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.data.len();
            lerp_flat(&mut t.data, &flat[off..off + n], beta);
            off += n;
        }
    }

    /// L2 distance between this set and a flat buffer laid out in
    /// manifest order — the arena-path twin of [`ParamSet::l2_distance`],
    /// bit-identical because both chain [`l2_accumulate`] through one
    /// accumulator in tensor order.
    pub fn l2_distance_flat(&self, flat: &[f32]) -> f64 {
        assert_eq!(flat.len(), self.numel(), "flat buffer length mismatch");
        let mut acc = 0.0f64;
        let mut off = 0;
        for t in &self.tensors {
            let n = t.data.len();
            l2_accumulate(&mut acc, &t.data, &flat[off..off + n]);
            off += n;
        }
        acc.sqrt()
    }

    /// Copy every tensor, in manifest order, into one contiguous flat
    /// buffer (`dst.len()` must equal [`ParamSet::numel`]).
    pub fn copy_to_flat(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.numel(), "flat buffer length mismatch");
        let mut off = 0;
        for t in &self.tensors {
            let n = t.data.len();
            dst[off..off + n].copy_from_slice(&t.data);
            off += n;
        }
    }

    /// Overwrite every tensor from one contiguous flat buffer in
    /// manifest order (the inverse of [`ParamSet::copy_to_flat`]).
    pub fn copy_from_flat(&mut self, src: &[f32]) {
        assert_eq!(src.len(), self.numel(), "flat buffer length mismatch");
        let mut off = 0;
        for t in &mut self.tensors {
            let n = t.data.len();
            t.data.copy_from_slice(&src[off..off + n]);
            off += n;
        }
    }

    /// Build a set over `layout`'s specs from a flat buffer in manifest
    /// order.
    pub fn from_flat(layout: &ParamLayout, src: &[f32]) -> ParamSet {
        assert_eq!(src.len(), layout.numel(), "flat buffer length mismatch");
        let mut p = ParamSet::zeros(layout.specs());
        p.copy_from_flat(src);
        p
    }

    /// L2 norm.
    pub fn l2_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for t in &self.tensors {
            for x in &t.data {
                acc += (*x as f64) * (*x as f64);
            }
        }
        acc.sqrt()
    }

    /// Maximum absolute elementwise difference (equivalence tests).
    pub fn max_abs_diff(&self, other: &ParamSet) -> f32 {
        let mut m = 0.0f32;
        for (t, o) in self.tensors.iter().zip(&other.tensors) {
            for (x, y) in t.data.iter().zip(&o.data) {
                m = m.max((x - y).abs());
            }
        }
        m
    }

    /// True when every element is finite (no NaN/Inf divergence).
    pub fn is_finite(&self) -> bool {
        self.tensors
            .iter()
            .all(|t| t.data.iter().all(|x| x.is_finite()))
    }

    /// FNV-1a 64 over every tensor's little-endian f32 bytes, in
    /// manifest order — a compact bit-exact fingerprint. Two models
    /// share a digest exactly when [`ParamSet::max_abs_diff`] is 0 and
    /// every element's bit pattern matches (NaN payloads included), so
    /// cross-process equivalence checks can compare one u64 instead of
    /// shipping whole models.
    pub fn digest(&self) -> u64 {
        let mut h: u64 = 0xcbf29ce484222325;
        for t in &self.tensors {
            for x in &t.data {
                for b in x.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x100000001b3);
                }
            }
        }
        h
    }
}

// ----------------------------------------------------- arena (SoA pool)

/// Flat memory layout of a parameter set: the ordered tensor specs plus
/// each tensor's offset into one contiguous f32 buffer. Shared by every
/// slot of a [`ParamArena`] (structure-of-arrays: one layout, many
/// parameter vectors).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamLayout {
    specs: Vec<TensorSpec>,
    offsets: Vec<usize>,
    numel: usize,
}

impl ParamLayout {
    /// A layout over the given ordered specs.
    pub fn new(specs: Vec<TensorSpec>) -> ParamLayout {
        let mut offsets = Vec::with_capacity(specs.len());
        let mut numel = 0;
        for s in &specs {
            offsets.push(numel);
            numel += s.numel();
        }
        ParamLayout {
            specs,
            offsets,
            numel,
        }
    }

    /// The layout of an existing parameter set.
    pub fn of(set: &ParamSet) -> ParamLayout {
        ParamLayout::new(set.specs())
    }

    /// Total scalar element count across all tensors.
    pub fn numel(&self) -> usize {
        self.numel
    }

    /// The ordered tensor specs.
    pub fn specs(&self) -> &[TensorSpec] {
        &self.specs
    }

    /// Flat element range of tensor `i`.
    pub fn range(&self, i: usize) -> std::ops::Range<usize> {
        let start = self.offsets[i];
        start..start + self.specs[i].numel()
    }
}

/// Handle to one parameter vector inside a [`ParamArena`]. Plain index,
/// `Copy`; validity is the owner's responsibility (freed slots are
/// caught by the arena's in-use tracking).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotId(u32);

impl SlotId {
    /// The slot's dense pool index (stable for the slot's lifetime).
    pub fn index(&self) -> usize {
        self.0 as usize
    }
}

/// Arena-backed, structure-of-arrays parameter store: `slots × numel`
/// f32 values in one contiguous buffer, all slots sharing one
/// [`ParamLayout`]. `alloc`/`free` recycle slots through a freelist, so
/// a steady-state aggregation loop (allocate local, aggregate, free)
/// performs no heap allocation after warm-up — the requirement for the
/// million-client hot path (`repro sim`, `coordinator::scale`).
#[derive(Debug)]
pub struct ParamArena {
    layout: ParamLayout,
    data: Vec<f32>,
    free: Vec<u32>,
    in_use: Vec<bool>,
    /// True for [`ParamArena::preallocated`] arenas: the backing buffer
    /// must never be reallocated (raw slot windows may point into it),
    /// so exhausting the freelist panics instead of growing.
    fixed: bool,
}

impl ParamArena {
    /// An empty arena over `layout` (slots are created on first alloc).
    pub fn new(layout: ParamLayout) -> ParamArena {
        ParamArena {
            layout,
            data: Vec::new(),
            free: Vec::new(),
            in_use: Vec::new(),
            fixed: false,
        }
    }

    /// An arena with all `slots` slots pre-created (zeroed) and the
    /// backing buffer at its final size. `alloc` recycles through the
    /// freelist exactly as on a grown arena but can never reallocate the
    /// backing storage; requesting more than `slots` concurrent slots
    /// panics instead of growing. This is the storage contract the
    /// sharded coordinator's raw slot window (`slot_window`, crate
    /// internal) relies on: pointers into the buffer stay valid for
    /// the arena's whole lifetime. Note that [`ParamArena::slots`]
    /// reports `slots` from the start (every slot exists), so callers
    /// needing a concurrency high-water mark must track it themselves.
    pub fn preallocated(layout: ParamLayout, slots: usize) -> ParamArena {
        let numel = layout.numel();
        ParamArena {
            layout,
            data: vec![0.0; slots * numel],
            // Reverse order so the first allocations hand out slot 0, 1,
            // ... — same visible order as a freshly grown arena.
            free: (0..slots as u32).rev().collect(),
            in_use: vec![false; slots],
            fixed: true,
        }
    }

    /// A raw, `Send` view over this arena's slot storage for concurrent
    /// disjoint-slot access from worker threads. Only sound over a
    /// [`ParamArena::preallocated`] arena (fixed-size buffer); see
    /// [`SlotWindow`] for the exclusivity protocol the caller must
    /// uphold.
    pub(crate) fn slot_window(&mut self) -> SlotWindow {
        SlotWindow {
            base: self.data.as_mut_ptr(),
            numel: self.layout.numel(),
            slots: self.in_use.len(),
        }
    }

    /// The shared layout of every slot.
    pub fn layout(&self) -> &ParamLayout {
        &self.layout
    }

    /// Total slots ever created (high-water mark of concurrent use).
    pub fn slots(&self) -> usize {
        self.in_use.len()
    }

    /// Slots currently allocated.
    pub fn live(&self) -> usize {
        self.in_use.len() - self.free.len()
    }

    /// Allocate a slot. Reuses a freed slot when one exists (contents
    /// are then whatever the previous occupant left — overwrite before
    /// reading); grows the pool otherwise.
    pub fn alloc(&mut self) -> SlotId {
        if let Some(idx) = self.free.pop() {
            self.in_use[idx as usize] = true;
            return SlotId(idx);
        }
        assert!(
            !self.fixed,
            "preallocated arena exhausted ({} slots)",
            self.in_use.len()
        );
        let idx = self.in_use.len() as u32;
        self.data.resize(self.data.len() + self.layout.numel(), 0.0);
        self.in_use.push(true);
        SlotId(idx)
    }

    /// Allocate a slot holding a flat copy of `set` (manifest order).
    pub fn alloc_from_set(&mut self, set: &ParamSet) -> SlotId {
        let id = self.alloc();
        set.copy_to_flat(self.get_mut(id));
        id
    }

    /// Return a slot to the freelist. Panics on double-free.
    pub fn free(&mut self, id: SlotId) {
        assert!(self.in_use[id.0 as usize], "double free of slot {id:?}");
        self.in_use[id.0 as usize] = false;
        self.free.push(id.0);
    }

    /// The flat parameter vector of a live slot.
    pub fn get(&self, id: SlotId) -> &[f32] {
        assert!(self.in_use[id.0 as usize], "read of freed slot {id:?}");
        let n = self.layout.numel();
        let start = id.0 as usize * n;
        &self.data[start..start + n]
    }

    /// Mutable access to the flat parameter vector of a live slot.
    pub fn get_mut(&mut self, id: SlotId) -> &mut [f32] {
        assert!(self.in_use[id.0 as usize], "write to freed slot {id:?}");
        let n = self.layout.numel();
        let start = id.0 as usize * n;
        &mut self.data[start..start + n]
    }

    /// Materialize a slot as a [`ParamSet`] (diagnostics/interchange —
    /// allocates, so keep it off the hot path).
    pub fn to_set(&self, id: SlotId) -> ParamSet {
        ParamSet::from_flat(&self.layout, self.get(id))
    }
}

/// Raw, `Send + Copy` view over a [`ParamArena::preallocated`] arena's
/// slot storage: base pointer + slot stride. The sharded coordinator
/// (`coordinator::shard`) copies one of these into every worker thread
/// so disjoint slots can be filled in parallel without locking.
///
/// # Exclusivity protocol (upheld by the owner, checked nowhere)
///
/// * All views derive from one `slot_window` call; the arena's backing
///   buffer is fixed-size, so the base pointer stays valid for the
///   arena's lifetime.
/// * At most one thread touches a given slot at a time. The sharded
///   coordinator enforces this by construction: a slot is published to
///   exactly one worker over a channel and not read back (or freed)
///   until that worker's completion message has been received — both
///   channel operations are happens-before edges.
/// * While any view is live, the owner must not create references into
///   the arena's buffer through safe accessors ([`ParamArena::get`] /
///   [`ParamArena::get_mut`]); `alloc`/`free` remain fine (they touch
///   only the freelist bookkeeping on a preallocated arena).
#[derive(Clone, Copy, Debug)]
pub(crate) struct SlotWindow {
    base: *mut f32,
    numel: usize,
    slots: usize,
}

// SAFETY: the window is a plain (pointer, stride) pair; cross-thread use
// is governed by the exclusivity protocol above.
unsafe impl Send for SlotWindow {}

impl SlotWindow {
    /// Mutable view of slot `idx`. The window is `Copy`, so the caller
    /// picks the view's lifetime — it must not outlive the arena.
    ///
    /// # Safety
    ///
    /// The caller must hold exclusive access to slot `idx` per the
    /// protocol in the type docs, `idx` must be in range (checked), and
    /// the chosen lifetime must end before the arena is dropped.
    pub(crate) unsafe fn slot_mut<'a>(self, idx: usize) -> &'a mut [f32] {
        assert!(idx < self.slots, "slot {idx} out of window ({})", self.slots);
        std::slice::from_raw_parts_mut(self.base.add(idx * self.numel), self.numel)
    }

    /// Shared view of slot `idx`.
    ///
    /// # Safety
    ///
    /// As [`SlotWindow::slot_mut`]: no other thread may be writing the
    /// slot concurrently, and the view must not outlive the arena.
    pub(crate) unsafe fn slot<'a>(self, idx: usize) -> &'a [f32] {
        assert!(idx < self.slots, "slot {idx} out of window ({})", self.slots);
        std::slice::from_raw_parts(self.base.add(idx * self.numel), self.numel)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
        }
    }

    fn pset(vals: &[&[f32]]) -> ParamSet {
        ParamSet {
            tensors: vals
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    Tensor::from_data(spec(&format!("t{i}"), &[v.len()]), v.to_vec())
                })
                .collect(),
        }
    }

    #[test]
    fn numel_sums_tensors() {
        let p = ParamSet::zeros(&[spec("a", &[2, 3]), spec("b", &[4])]);
        assert_eq!(p.numel(), 10);
    }

    #[test]
    fn lerp_endpoints() {
        let g = pset(&[&[1.0, 2.0], &[3.0]]);
        let l = pset(&[&[5.0, 6.0], &[7.0]]);
        let mut a = g.clone();
        a.lerp_inplace(&l, 1.0);
        assert_eq!(a, g);
        let mut b = g.clone();
        b.lerp_inplace(&l, 0.0);
        assert_eq!(b, l);
    }

    #[test]
    fn lerp_midpoint() {
        let g = pset(&[&[0.0, 2.0]]);
        let l = pset(&[&[4.0, 0.0]]);
        let mut m = g.clone();
        m.lerp_inplace(&l, 0.5);
        assert_eq!(m.tensors[0].data, vec![2.0, 1.0]);
    }

    #[test]
    fn axpy_and_scale_build_fedavg_mean() {
        let a = pset(&[&[1.0, 3.0]]);
        let b = pset(&[&[3.0, 5.0]]);
        let mut acc = ParamSet::zeros(&a.specs());
        acc.axpy_inplace(&a, 0.5);
        acc.axpy_inplace(&b, 0.5);
        assert_eq!(acc.tensors[0].data, vec![2.0, 4.0]);
        acc.scale_inplace(2.0);
        assert_eq!(acc.tensors[0].data, vec![4.0, 8.0]);
    }

    #[test]
    fn distances() {
        let a = pset(&[&[0.0, 0.0]]);
        let b = pset(&[&[3.0, 4.0]]);
        assert!((a.l2_distance(&b) - 5.0).abs() < 1e-9);
        assert!((b.l2_norm() - 5.0).abs() < 1e-9);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn finiteness() {
        let mut p = pset(&[&[1.0, 2.0]]);
        assert!(p.is_finite());
        p.tensors[0].data[1] = f32::NAN;
        assert!(!p.is_finite());
    }

    #[test]
    #[should_panic]
    fn from_data_checks_len() {
        Tensor::from_data(spec("x", &[3]), vec![1.0, 2.0]);
    }

    #[test]
    fn layout_offsets_and_ranges() {
        let l = ParamLayout::new(vec![spec("a", &[2, 3]), spec("b", &[4])]);
        assert_eq!(l.numel(), 10);
        assert_eq!(l.range(0), 0..6);
        assert_eq!(l.range(1), 6..10);
        assert_eq!(l.specs().len(), 2);
    }

    #[test]
    fn flat_copy_roundtrips() {
        let p = pset(&[&[1.0, 2.0, 3.0], &[4.0, 5.0]]);
        let layout = ParamLayout::of(&p);
        let mut flat = vec![0.0f32; layout.numel()];
        p.copy_to_flat(&mut flat);
        assert_eq!(flat, vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        let back = ParamSet::from_flat(&layout, &flat);
        assert_eq!(back, p);
    }

    #[test]
    fn flat_kernels_match_tensor_paths_bitwise() {
        let g = pset(&[&[1.0, -2.5, 0.125], &[3.0, 7.5]]);
        let l = pset(&[&[0.3, 4.0, -1.0], &[-2.0, 0.01]]);
        let layout = ParamLayout::of(&g);
        let mut gf = vec![0.0f32; layout.numel()];
        let mut lf = vec![0.0f32; layout.numel()];
        g.copy_to_flat(&mut gf);
        l.copy_to_flat(&mut lf);
        for &beta in &[0.0f32, 0.37, 0.93, 1.0] {
            let mut a = g.clone();
            a.lerp_inplace(&l, beta);
            let mut b = gf.clone();
            lerp_flat(&mut b, &lf, beta);
            let mut af = vec![0.0f32; layout.numel()];
            a.copy_to_flat(&mut af);
            assert_eq!(af, b, "beta={beta}");
            let mut c = g.clone();
            c.lerp_inplace_flat(&lf, beta);
            assert_eq!(c, a, "beta={beta} (flat-local twin)");
        }
        assert_eq!(g.l2_distance(&l), g.l2_distance_flat(&lf));
    }

    /// Deterministic pseudo-random buffer for kernel equivalence checks
    /// (no external RNG dependency inside the unit-test module).
    fn noise(n: usize, seed: u32) -> Vec<f32> {
        let mut state = seed.wrapping_mul(2654435761).max(1);
        (0..n)
            .map(|_| {
                // xorshift32; map to roughly [-4, 4).
                state ^= state << 13;
                state ^= state >> 17;
                state ^= state << 5;
                (state as f32 / u32::MAX as f32) * 8.0 - 4.0
            })
            .collect()
    }

    #[test]
    fn chunked_lerp_matches_scalar_reference_bitwise() {
        for n in [0, 1, KERNEL_CHUNK - 1, KERNEL_CHUNK, KERNEL_CHUNK + 1, 777] {
            let g0 = noise(n, 11);
            let l = noise(n, 23);
            for &beta in &[0.0f32, 0.31, 0.9, 1.0] {
                let mut a = g0.clone();
                lerp_flat(&mut a, &l, beta);
                let mut b = g0.clone();
                lerp_flat_scalar(&mut b, &l, beta);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a), bits(&b), "n={n} beta={beta}");
            }
        }
    }

    #[test]
    fn chunked_axpy_matches_scalar_reference_bitwise() {
        for n in [0, 1, KERNEL_CHUNK - 1, KERNEL_CHUNK, KERNEL_CHUNK + 1, 777] {
            let a0 = noise(n, 5);
            let o = noise(n, 7);
            for &w in &[0.0f32, -0.25, 0.125, 1.0] {
                let mut a = a0.clone();
                axpy_flat(&mut a, &o, w);
                let mut b = a0.clone();
                axpy_flat_scalar(&mut b, &o, w);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&a), bits(&b), "n={n} w={w}");
            }
        }
    }

    #[test]
    fn parallel_lerp_matches_scalar_reference_at_every_thread_count() {
        for n in [0, 1, 5, 64, 1000] {
            let g0 = noise(n, 3);
            let l = noise(n, 9);
            let mut expect = g0.clone();
            lerp_flat_scalar(&mut expect, &l, 0.4);
            for threads in [1, 2, 3, 8, 64] {
                let mut got = g0.clone();
                lerp_flat_par(&mut got, &l, 0.4, threads);
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&got), bits(&expect), "n={n} threads={threads}");
            }
        }
    }

    #[test]
    fn arena_recycles_slots_without_growth() {
        let layout = ParamLayout::new(vec![spec("w", &[4])]);
        let mut a = ParamArena::new(layout);
        let s0 = a.alloc();
        a.get_mut(s0).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let s1 = a.alloc();
        assert_eq!(a.slots(), 2);
        assert_eq!(a.live(), 2);
        a.free(s0);
        assert_eq!(a.live(), 1);
        // The freed slot is reused: pool does not grow.
        let s2 = a.alloc();
        assert_eq!(s2, s0);
        assert_eq!(a.slots(), 2);
        a.free(s1);
        a.free(s2);
        assert_eq!(a.live(), 0);
    }

    #[test]
    fn arena_copies_sets_in_and_out() {
        let p = pset(&[&[1.0, 2.0], &[3.0]]);
        let mut a = ParamArena::new(ParamLayout::of(&p));
        let s = a.alloc_from_set(&p);
        assert_eq!(a.get(s), &[1.0, 2.0, 3.0]);
        assert_eq!(a.to_set(s), p);
    }

    #[test]
    fn preallocated_arena_recycles_without_reallocating() {
        let layout = ParamLayout::new(vec![spec("w", &[3])]);
        let mut a = ParamArena::preallocated(layout, 4);
        assert_eq!(a.slots(), 4);
        assert_eq!(a.live(), 0);
        let base = a.slot_window().base;
        let s0 = a.alloc();
        assert_eq!(s0.index(), 0, "first alloc hands out slot 0");
        a.get_mut(s0).copy_from_slice(&[1.0, 2.0, 3.0]);
        let s1 = a.alloc();
        assert_eq!(s1.index(), 1);
        a.free(s0);
        let s2 = a.alloc();
        assert_eq!(s2, s0, "freelist recycling as on a grown arena");
        assert_eq!(a.live(), 2);
        // The backing buffer never moved.
        assert_eq!(a.slot_window().base, base);
    }

    #[test]
    #[should_panic]
    fn preallocated_arena_panics_when_exhausted() {
        let layout = ParamLayout::new(vec![spec("w", &[2])]);
        let mut a = ParamArena::preallocated(layout, 1);
        let _s0 = a.alloc();
        let _s1 = a.alloc();
    }

    #[test]
    fn slot_window_views_match_safe_accessors() {
        let layout = ParamLayout::new(vec![spec("w", &[2])]);
        let mut a = ParamArena::preallocated(layout, 2);
        let s0 = a.alloc();
        let s1 = a.alloc();
        let w = a.slot_window();
        // SAFETY: single-threaded test, no overlapping views held.
        unsafe {
            w.slot_mut(s0.index()).copy_from_slice(&[1.5, -2.5]);
            w.slot_mut(s1.index()).copy_from_slice(&[9.0, 8.0]);
            assert_eq!(w.slot(s0.index()), &[1.5, -2.5]);
        }
        assert_eq!(a.get(s0), &[1.5, -2.5]);
        assert_eq!(a.get(s1), &[9.0, 8.0]);
    }

    #[test]
    #[should_panic]
    fn arena_rejects_double_free() {
        let mut a = ParamArena::new(ParamLayout::new(vec![spec("w", &[2])]));
        let s = a.alloc();
        a.free(s);
        a.free(s);
    }
}
