//! Host-side model parameters.
//!
//! The manifest fixes an *ordered* list of named tensors; `ParamSet` is the
//! host representation that flows between the PJRT runtime (as literals /
//! device buffers) and the coordinator (aggregation, distance metrics).

use std::fmt;

/// Static description of one parameter tensor (from the manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    /// The tensor's manifest name (e.g. `conv1/kernel`).
    pub name: String,
    /// Dimension sizes, outermost first.
    pub shape: Vec<usize>,
}

impl TensorSpec {
    /// Total scalar element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One named f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    /// Shape + name of this tensor.
    pub spec: TensorSpec,
    /// Row-major element data (`spec.numel()` values).
    pub data: Vec<f32>,
}

impl Tensor {
    /// An all-zero tensor of the given spec.
    pub fn zeros(spec: TensorSpec) -> Self {
        let n = spec.numel();
        Tensor {
            spec,
            data: vec![0.0; n],
        }
    }

    /// Wrap existing data; panics if the length does not match the spec.
    pub fn from_data(spec: TensorSpec, data: Vec<f32>) -> Self {
        assert_eq!(
            spec.numel(),
            data.len(),
            "tensor {}: shape {:?} != data len {}",
            spec.name,
            spec.shape,
            data.len()
        );
        Tensor { spec, data }
    }
}

/// An ordered set of parameter tensors (the manifest contract).
#[derive(Clone, PartialEq, Default)]
pub struct ParamSet {
    /// The model's tensors in manifest order.
    pub tensors: Vec<Tensor>,
}

impl fmt::Debug for ParamSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ParamSet[{} tensors, {} params]", self.tensors.len(), self.numel())
    }
}

impl ParamSet {
    /// An all-zero parameter set over the given specs.
    pub fn zeros(specs: &[TensorSpec]) -> Self {
        ParamSet {
            tensors: specs.iter().cloned().map(Tensor::zeros).collect(),
        }
    }

    /// Total scalar parameter count across all tensors.
    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.spec.numel()).sum()
    }

    /// The ordered tensor specs (the manifest contract).
    pub fn specs(&self) -> Vec<TensorSpec> {
        self.tensors.iter().map(|t| t.spec.clone()).collect()
    }

    /// In-place convex combination: `self = beta*self + (1-beta)*other`
    /// — the eq.(3) server aggregation (native hot path; see
    /// coordinator::aggregation for the PJRT/Pallas alternative).
    pub fn lerp_inplace(&mut self, other: &ParamSet, beta: f32) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        let b = beta;
        let a = 1.0 - beta;
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            debug_assert_eq!(t.spec, o.spec);
            // Simple indexed loop: LLVM auto-vectorizes this cleanly.
            for (x, y) in t.data.iter_mut().zip(&o.data) {
                *x = b * *x + a * *y;
            }
        }
    }

    /// Weighted accumulation: `self += w * other` (FedAvg reduction).
    pub fn axpy_inplace(&mut self, other: &ParamSet, w: f32) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (t, o) in self.tensors.iter_mut().zip(&other.tensors) {
            for (x, y) in t.data.iter_mut().zip(&o.data) {
                *x += w * *y;
            }
        }
    }

    /// Multiply every element by `s`.
    pub fn scale_inplace(&mut self, s: f32) {
        for t in &mut self.tensors {
            for x in &mut t.data {
                *x *= s;
            }
        }
    }

    /// L2 distance between two parameter sets (staleness diagnostics).
    pub fn l2_distance(&self, other: &ParamSet) -> f64 {
        let mut acc = 0.0f64;
        for (t, o) in self.tensors.iter().zip(&other.tensors) {
            for (x, y) in t.data.iter().zip(&o.data) {
                let d = (*x - *y) as f64;
                acc += d * d;
            }
        }
        acc.sqrt()
    }

    /// L2 norm.
    pub fn l2_norm(&self) -> f64 {
        let mut acc = 0.0f64;
        for t in &self.tensors {
            for x in &t.data {
                acc += (*x as f64) * (*x as f64);
            }
        }
        acc.sqrt()
    }

    /// Maximum absolute elementwise difference (equivalence tests).
    pub fn max_abs_diff(&self, other: &ParamSet) -> f32 {
        let mut m = 0.0f32;
        for (t, o) in self.tensors.iter().zip(&other.tensors) {
            for (x, y) in t.data.iter().zip(&o.data) {
                m = m.max((x - y).abs());
            }
        }
        m
    }

    /// True when every element is finite (no NaN/Inf divergence).
    pub fn is_finite(&self) -> bool {
        self.tensors
            .iter()
            .all(|t| t.data.iter().all(|x| x.is_finite()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec {
            name: name.into(),
            shape: shape.to_vec(),
        }
    }

    fn pset(vals: &[&[f32]]) -> ParamSet {
        ParamSet {
            tensors: vals
                .iter()
                .enumerate()
                .map(|(i, v)| {
                    Tensor::from_data(spec(&format!("t{i}"), &[v.len()]), v.to_vec())
                })
                .collect(),
        }
    }

    #[test]
    fn numel_sums_tensors() {
        let p = ParamSet::zeros(&[spec("a", &[2, 3]), spec("b", &[4])]);
        assert_eq!(p.numel(), 10);
    }

    #[test]
    fn lerp_endpoints() {
        let g = pset(&[&[1.0, 2.0], &[3.0]]);
        let l = pset(&[&[5.0, 6.0], &[7.0]]);
        let mut a = g.clone();
        a.lerp_inplace(&l, 1.0);
        assert_eq!(a, g);
        let mut b = g.clone();
        b.lerp_inplace(&l, 0.0);
        assert_eq!(b, l);
    }

    #[test]
    fn lerp_midpoint() {
        let g = pset(&[&[0.0, 2.0]]);
        let l = pset(&[&[4.0, 0.0]]);
        let mut m = g.clone();
        m.lerp_inplace(&l, 0.5);
        assert_eq!(m.tensors[0].data, vec![2.0, 1.0]);
    }

    #[test]
    fn axpy_and_scale_build_fedavg_mean() {
        let a = pset(&[&[1.0, 3.0]]);
        let b = pset(&[&[3.0, 5.0]]);
        let mut acc = ParamSet::zeros(&a.specs());
        acc.axpy_inplace(&a, 0.5);
        acc.axpy_inplace(&b, 0.5);
        assert_eq!(acc.tensors[0].data, vec![2.0, 4.0]);
        acc.scale_inplace(2.0);
        assert_eq!(acc.tensors[0].data, vec![4.0, 8.0]);
    }

    #[test]
    fn distances() {
        let a = pset(&[&[0.0, 0.0]]);
        let b = pset(&[&[3.0, 4.0]]);
        assert!((a.l2_distance(&b) - 5.0).abs() < 1e-9);
        assert!((b.l2_norm() - 5.0).abs() < 1e-9);
        assert_eq!(a.max_abs_diff(&b), 4.0);
    }

    #[test]
    fn finiteness() {
        let mut p = pset(&[&[1.0, 2.0]]);
        assert!(p.is_finite());
        p.tensors[0].data[1] = f32::NAN;
        assert!(!p.is_finite());
    }

    #[test]
    #[should_panic]
    fn from_data_checks_len() {
        Tensor::from_data(spec("x", &[3]), vec![1.0, 2.0]);
    }
}
