//! HeteroFL-style rate-scaled submodels over the flat parameter layout.
//!
//! A capacity-constrained client trains and uploads only a *leading
//! slice* of every tensor (the HeteroFL selection rule: the first
//! `ceil(rate * n)` elements of each tensor's flat range — nested, so a
//! 0.25-rate submodel is contained in the 0.5-rate one). A
//! [`SubmodelMap`] precomputes those slices from a [`ParamLayout`] once
//! per capacity class; the flat kernels ([`SubmodelMap::extract_flat`] /
//! [`SubmodelMap::merge_flat`]) then move parameters between full-model
//! arena slots and rate-scaled submodel buffers with no allocation, and
//! the overlap-count kernels ([`SubmodelMap::accumulate_counts`],
//! [`accumulate_overlap`], [`finalize_overlap_mean`]) implement the
//! HeteroFL batch average `w[e] = Σ_k sub_k[e] / |{k covering e}|`.
//!
//! Rate 1.0 is the identity map by construction: every slice covers its
//! whole tensor, extract→merge round-trips bitwise, and the slice-wise
//! aggregation in `ServerCore::on_update_submodel` delegates to the
//! ordinary flat path — which is what keeps `capacity=uniform:1.0`
//! bit-identical to the pre-submodel engines (`tests/properties.rs`,
//! `tests/sharded.rs`).

use super::params::{l2_accumulate, lerp_flat, ParamLayout, ParamSet};

/// One tensor's covered slice: where the tensor starts in the full flat
/// layout, how many leading elements the submodel keeps, and the
/// tensor's full element count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SubmodelSlice {
    /// Start of the tensor's range in the full flat layout.
    pub full_start: usize,
    /// Leading elements covered (`1 ..= full_len`).
    pub keep: usize,
    /// The tensor's full element count.
    pub full_len: usize,
}

/// The parameter slices a capacity rate covers, derived from a
/// [`ParamLayout`]: per tensor, the leading `ceil(rate * n)` elements
/// (clamped to `[1, n]` so even tiny rates keep every tensor present).
/// Slices are in layout order, in-bounds and mutually disjoint by
/// construction.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmodelMap {
    rate: f64,
    slices: Vec<SubmodelSlice>,
    numel: usize,
    full_numel: usize,
}

impl SubmodelMap {
    /// The slice map of `rate` over `layout`. `rate` must be in (0, 1]
    /// (validated by the capacity registry before maps are built).
    pub fn new(layout: &ParamLayout, rate: f64) -> SubmodelMap {
        assert!(
            rate > 0.0 && rate <= 1.0,
            "submodel rate {rate} outside (0, 1]"
        );
        let mut slices = Vec::with_capacity(layout.specs().len());
        let mut numel = 0;
        for (i, spec) in layout.specs().iter().enumerate() {
            let n = spec.numel();
            let keep = ((rate * n as f64).ceil() as usize).clamp(1, n);
            slices.push(SubmodelSlice {
                full_start: layout.range(i).start,
                keep,
                full_len: n,
            });
            numel += keep;
        }
        SubmodelMap {
            rate,
            slices,
            numel,
            full_numel: layout.numel(),
        }
    }

    /// The capacity rate this map was built for.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Submodel element count (the upload size of this capacity class).
    pub fn numel(&self) -> usize {
        self.numel
    }

    /// Full-model element count of the underlying layout.
    pub fn full_numel(&self) -> usize {
        self.full_numel
    }

    /// The per-tensor slices, in layout order.
    pub fn slices(&self) -> &[SubmodelSlice] {
        &self.slices
    }

    /// Whether every slice covers its whole tensor (rate 1.0 ≡ identity).
    pub fn is_full(&self) -> bool {
        self.numel == self.full_numel
    }

    /// Gather the covered slices of a full flat buffer into a packed
    /// submodel buffer (`out.len() == self.numel()`).
    pub fn extract_flat(&self, full: &[f32], out: &mut [f32]) {
        assert_eq!(full.len(), self.full_numel, "full buffer length mismatch");
        assert_eq!(out.len(), self.numel, "submodel buffer length mismatch");
        let mut off = 0;
        for s in &self.slices {
            out[off..off + s.keep]
                .copy_from_slice(&full[s.full_start..s.full_start + s.keep]);
            off += s.keep;
        }
    }

    /// Scatter a packed submodel buffer back into the covered slices of
    /// a full flat buffer (the inverse of [`SubmodelMap::extract_flat`]
    /// on the covered elements; uncovered elements are untouched).
    pub fn merge_flat(&self, full: &mut [f32], sub: &[f32]) {
        assert_eq!(full.len(), self.full_numel, "full buffer length mismatch");
        assert_eq!(sub.len(), self.numel, "submodel buffer length mismatch");
        let mut off = 0;
        for s in &self.slices {
            full[s.full_start..s.full_start + s.keep]
                .copy_from_slice(&sub[off..off + s.keep]);
            off += s.keep;
        }
    }

    /// Gather the covered slices of a [`ParamSet`] (manifest order) into
    /// a packed submodel buffer — the set-side twin of
    /// [`SubmodelMap::extract_flat`].
    pub fn extract_from_set(&self, set: &ParamSet, out: &mut [f32]) {
        assert_eq!(set.tensors.len(), self.slices.len(), "tensor count mismatch");
        assert_eq!(out.len(), self.numel, "submodel buffer length mismatch");
        let mut off = 0;
        for (t, s) in set.tensors.iter().zip(&self.slices) {
            debug_assert_eq!(t.data.len(), s.full_len);
            out[off..off + s.keep].copy_from_slice(&t.data[..s.keep]);
            off += s.keep;
        }
    }

    /// Slice-wise eq.-(3) aggregation: lerp the covered leading span of
    /// every tensor against the packed submodel buffer, leaving
    /// uncovered elements untouched. Chunks per tensor through
    /// [`lerp_flat`] exactly like [`ParamSet::lerp_inplace_flat`], so at
    /// rate 1.0 the two are the same arithmetic to the last bit.
    pub fn merge_lerp_set(&self, global: &mut ParamSet, sub: &[f32], beta: f32) {
        assert_eq!(global.tensors.len(), self.slices.len(), "tensor count mismatch");
        assert_eq!(sub.len(), self.numel, "submodel buffer length mismatch");
        let mut off = 0;
        for (t, s) in global.tensors.iter_mut().zip(&self.slices) {
            lerp_flat(&mut t.data[..s.keep], &sub[off..off + s.keep], beta);
            off += s.keep;
        }
    }

    /// L2 distance between the covered slices of `set` and a packed
    /// submodel buffer, chained through one accumulator in tensor order
    /// (the covered-slice twin of [`ParamSet::l2_distance_flat`]).
    pub fn l2_distance_set(&self, set: &ParamSet, sub: &[f32]) -> f64 {
        assert_eq!(set.tensors.len(), self.slices.len(), "tensor count mismatch");
        assert_eq!(sub.len(), self.numel, "submodel buffer length mismatch");
        let mut acc = 0.0f64;
        let mut off = 0;
        for (t, s) in set.tensors.iter().zip(&self.slices) {
            l2_accumulate(&mut acc, &t.data[..s.keep], &sub[off..off + s.keep]);
            off += s.keep;
        }
        acc.sqrt()
    }

    /// Add 1 to the overlap count of every full-layout element this map
    /// covers (`counts.len() == self.full_numel()`).
    pub fn accumulate_counts(&self, counts: &mut [u32]) {
        assert_eq!(counts.len(), self.full_numel, "count buffer length mismatch");
        for s in &self.slices {
            for c in &mut counts[s.full_start..s.full_start + s.keep] {
                *c += 1;
            }
        }
    }

    /// Scatter-add a packed submodel buffer into a full-layout
    /// accumulator and bump the matching overlap counts — one
    /// contribution of the HeteroFL batch average (see
    /// [`finalize_overlap_mean`]).
    pub fn accumulate_overlap(&self, acc: &mut [f32], counts: &mut [u32], sub: &[f32]) {
        assert_eq!(acc.len(), self.full_numel, "accumulator length mismatch");
        assert_eq!(counts.len(), self.full_numel, "count buffer length mismatch");
        assert_eq!(sub.len(), self.numel, "submodel buffer length mismatch");
        let mut off = 0;
        for s in &self.slices {
            let full = &mut acc[s.full_start..s.full_start + s.keep];
            let cnt = &mut counts[s.full_start..s.full_start + s.keep];
            let part = &sub[off..off + s.keep];
            for ((a, c), v) in full.iter_mut().zip(cnt.iter_mut()).zip(part) {
                *a += *v;
                *c += 1;
            }
            off += s.keep;
        }
    }
}

/// Turn an overlap accumulator into the per-element mean: every element
/// covered at least once becomes `acc[e] / counts[e]`; uncovered
/// elements are left untouched (HeteroFL keeps the previous global
/// there).
pub fn finalize_overlap_mean(acc: &mut [f32], counts: &[u32]) {
    assert_eq!(acc.len(), counts.len(), "count buffer length mismatch");
    for (a, &c) in acc.iter_mut().zip(counts) {
        if c > 0 {
            *a /= c as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Tensor, TensorSpec};

    fn layout(sizes: &[usize]) -> ParamLayout {
        ParamLayout::new(
            sizes
                .iter()
                .enumerate()
                .map(|(i, &n)| TensorSpec {
                    name: format!("t{i}"),
                    shape: vec![n],
                })
                .collect(),
        )
    }

    #[test]
    fn rate_one_is_the_identity_map() {
        let l = layout(&[6, 1, 17]);
        let m = SubmodelMap::new(&l, 1.0);
        assert!(m.is_full());
        assert_eq!(m.numel(), l.numel());
        for (i, s) in m.slices().iter().enumerate() {
            assert_eq!(s.keep, s.full_len, "slice {i}");
        }
    }

    #[test]
    fn slices_keep_ceil_rate_and_at_least_one() {
        let l = layout(&[10, 1, 3]);
        let m = SubmodelMap::new(&l, 0.25);
        // ceil(0.25*10)=3, clamp(ceil(0.25*1))=1, ceil(0.25*3)=1.
        let keeps: Vec<usize> = m.slices().iter().map(|s| s.keep).collect();
        assert_eq!(keeps, vec![3, 1, 1]);
        assert_eq!(m.numel(), 5);
        assert_eq!(m.full_numel(), 14);
        assert!(!m.is_full());
    }

    #[test]
    fn extract_then_merge_covers_exactly_the_slices() {
        let l = layout(&[4, 3]);
        let m = SubmodelMap::new(&l, 0.5);
        let full: Vec<f32> = (0..7).map(|i| i as f32).collect();
        let mut sub = vec![0.0f32; m.numel()];
        m.extract_flat(&full, &mut sub);
        // ceil(0.5*4)=2 of [0,1,2,3]; ceil(0.5*3)=2 of [4,5,6].
        assert_eq!(sub, vec![0.0, 1.0, 4.0, 5.0]);
        let mut target = vec![-1.0f32; 7];
        m.merge_flat(&mut target, &sub);
        assert_eq!(target, vec![0.0, 1.0, -1.0, -1.0, 4.0, 5.0, -1.0]);
    }

    #[test]
    fn extract_from_set_matches_flat_extract() {
        let l = layout(&[5, 2]);
        let m = SubmodelMap::new(&l, 0.6);
        let set = ParamSet {
            tensors: vec![
                Tensor::from_data(l.specs()[0].clone(), vec![1.0, 2.0, 3.0, 4.0, 5.0]),
                Tensor::from_data(l.specs()[1].clone(), vec![6.0, 7.0]),
            ],
        };
        let mut flat = vec![0.0f32; l.numel()];
        set.copy_to_flat(&mut flat);
        let mut a = vec![0.0f32; m.numel()];
        let mut b = vec![0.0f32; m.numel()];
        m.extract_flat(&flat, &mut a);
        m.extract_from_set(&set, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn merge_lerp_at_rate_one_matches_lerp_inplace_flat_bitwise() {
        let l = layout(&[3, 4]);
        let m = SubmodelMap::new(&l, 1.0);
        let mk = |vals: &[f32]| ParamSet {
            tensors: vec![
                Tensor::from_data(l.specs()[0].clone(), vals[..3].to_vec()),
                Tensor::from_data(l.specs()[1].clone(), vals[3..].to_vec()),
            ],
        };
        let g = mk(&[0.1, -2.0, 3.5, 0.0, 7.25, -0.125, 9.0]);
        let local = [1.0f32, 0.3, -4.0, 2.0, 0.0, 5.5, -6.0];
        for &beta in &[0.0f32, 0.31, 0.77, 1.0] {
            let mut a = g.clone();
            a.lerp_inplace_flat(&local, beta);
            let mut b = g.clone();
            m.merge_lerp_set(&mut b, &local, beta);
            assert_eq!(a, b, "beta={beta}");
            assert_eq!(
                g.l2_distance_flat(&local),
                m.l2_distance_set(&g, &local),
                "distance twin"
            );
        }
    }

    #[test]
    fn merge_lerp_touches_only_covered_elements() {
        let l = layout(&[4]);
        let m = SubmodelMap::new(&l, 0.5);
        let mut g = ParamSet {
            tensors: vec![Tensor::from_data(
                l.specs()[0].clone(),
                vec![1.0, 1.0, 1.0, 1.0],
            )],
        };
        m.merge_lerp_set(&mut g, &[3.0, 5.0], 0.5);
        assert_eq!(g.tensors[0].data, vec![2.0, 3.0, 1.0, 1.0]);
    }

    #[test]
    fn overlap_counts_and_mean() {
        let l = layout(&[4]);
        let m_half = SubmodelMap::new(&l, 0.5);
        let m_full = SubmodelMap::new(&l, 1.0);
        let mut acc = vec![0.0f32; 4];
        let mut counts = vec![0u32; 4];
        m_half.accumulate_overlap(&mut acc, &mut counts, &[2.0, 4.0]);
        m_full.accumulate_overlap(&mut acc, &mut counts, &[4.0, 8.0, 3.0, 7.0]);
        assert_eq!(counts, vec![2, 2, 1, 1]);
        finalize_overlap_mean(&mut acc, &counts);
        assert_eq!(acc, vec![3.0, 6.0, 3.0, 7.0]);
        let mut only = vec![0u32; 4];
        m_half.accumulate_counts(&mut only);
        assert_eq!(only, vec![1, 1, 0, 0]);
    }

    #[test]
    #[should_panic]
    fn rejects_rate_zero() {
        SubmodelMap::new(&layout(&[4]), 0.0);
    }

    #[test]
    #[should_panic]
    fn extract_checks_buffer_length() {
        let l = layout(&[4]);
        let m = SubmodelMap::new(&l, 0.5);
        let mut out = vec![0.0f32; 1];
        m.extract_flat(&[0.0; 4], &mut out);
    }
}
