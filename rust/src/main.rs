//! `repro` — the CSMAAFL launcher CLI.
//!
//! Subcommands:
//!   train     run one federated experiment from a config file
//!   compare   run all four algorithms paired on one config
//!   figures   regenerate the paper's figures (fig3 fig4 fig5a fig5b)
//!   sweep     sweep one config field over a value list
//!   grid      cartesian multi-axis sweep -> JSON/table results matrix
//!   timeline  emit the Sec. II-C SFL-vs-AFL time comparison (Fig. 2)
//!   inspect   analytic tables (naive-decay, beta-solver)
//!   smoke     compile + run every artifact once (installation check)
//!   sim       coordinator-only scale simulation (10^6 clients, no learner)
//!   bench     pinned-seed perf suite -> `BENCH_<date>.json` (+ CI --check gate)
//!   trace     validate / summarize a `--trace` JSONL file (staleness
//!             timeline, fairness, loss causes)
//!
//! Every multi-run command (`compare`, `figures`, `sweep`, `grid`)
//! executes through the experiment engine (`csmaafl::experiment`) on
//! `--jobs N` worker threads with byte-identical output at any N.
//! `train`, `sim` and `serve` accept `--trace <file>` (ordered telemetry
//! events as JSONL — see `docs/OBSERVABILITY.md`); every command honors
//! `--log-level` (or the `REPRO_LOG` env var).
//!
//! The argument parser is hand-rolled: the crate stays
//! dependency-minimal by design (`anyhow` is the only dependency — no
//! clap).

use anyhow::{anyhow, bail, ensure, Context, Result};

use csmaafl::config::RunConfig;
use csmaafl::coordinator::{
    run_sharded_sim, run_sharded_sim_traced, ScaleSimConfig, SchedulerPolicy,
};
use csmaafl::experiment::{self, Plan, PlanRunner};
use csmaafl::figures::{self, FigureSpec, FIGURES};
use csmaafl::metrics::write_series_csv;
use csmaafl::perf;
use csmaafl::session::{LearnerKind, Session};
use csmaafl::sim::{HeterogeneityProfile, TimeModel};
use csmaafl::telemetry::Telemetry;
use csmaafl::util::json::{self, Json};
use csmaafl::util::logging::{self, Level};

const USAGE: &str = "\
repro — CSMAAFL asynchronous federated learning reproduction

USAGE:
  repro <COMMAND> [OPTIONS]

COMMANDS:
  train     --config <file> [--set key=value ...] [--learner pjrt|linear]
            [--shards K] [--out results/] [--label name]
            [--trace file.jsonl]
            (--shards K runs local training on K worker threads,
            default = available cores; results are bit-identical at
            any K — only wall-clock changes. --trace records ordered
            telemetry events, byte-identical at any K)
  compare   --config <file> [--learner pjrt|linear] [--jobs N]
            [--shards K] [--out results/]
            (four paper series + fedasync/adaptive policy series;
            without --shards each of the --jobs runs is single-threaded)
  figures   [--fig fig3|fig4|fig5a|fig5b|all] [--learner pjrt|linear]
            [--set key=value ...] [--jobs N] [--shards K] [--out results/]
  sweep     --param gamma --values 0.1,0.2,0.4,0.6 [--config <file>]
            [--learner pjrt|linear] [--jobs N] [--out results/]
            (E-GAMMA table)
  grid      --axis key=v1,v2,... [--axis ...] [--set key=value ...]
            [--replicates R] [--jobs N] [--format table|json]
            [--config <file>] [--learner pjrt|linear] [--out results/]
            (cartesian results matrix -> grid.json + grid.csv; a key
            repeated across --set flags also forms an axis; separate
            axis values with ';' when they contain commas, e.g.
            --axis scenario=static;churn:0.3,2 or
            --axis channel=ideal;markov:0.5,500)
            with --sim: sweep the coordinator scale simulator instead
            (keys: clients iterations params seed gamma mu_rho
            local_steps train_passes jitter scheduler aggregation
            scenario capacity channel heterogeneity shards) -> grid.json
            of deterministic sim summaries, e.g. --sim --axis shards=1,2,4,8
  analyze   [--results results/]   (comparison tables from stored records)
  timeline  [--clients M] [--local-steps E] [--slow-factor a] [--out results/]
  inspect   naive-decay [--clients M] | betas [--clients M]
  smoke     [--artifacts artifacts]
  sim       [--clients N] [--iterations J] [--params P] [--shards K]
            [--scheduler oldest|fifo|roundrobin|channel-aware]
            [--aggregation spec]
            [--scenario spec | --set scenario=spec] [--train-passes P]
            [--capacity spec | --set capacity=spec]
            [--channel spec | --set channel=spec]
            [--heterogeneity prof] [--gamma g] [--seed S]
            [--format table|json] [--trace file.jsonl]
            (coordinator-only scale simulation: real event loop,
            scheduler and arena aggregation; synthetic local training —
            completes at --clients 1000000. --shards K runs K shard
            workers, default = available cores; every non-wall-clock
            field is bit-identical at any K, including the --trace
            event stream)
  trace     <file.jsonl> [--check]
            (summarize a --trace file: per-kind event counts, staleness
            and queue-depth histograms, Jain fairness, loss causes and
            a staleness timeline; --check only validates the file and
            prints the event count)
  bench     [--quick] [--suite aggregation|kernels|scheduler|event_loop|
            end_to_end|sharded|submodel|net|channel|telemetry] [--shards K]
            [--format table|json]
            [--out results/] [--check BENCH_baseline.json] [--factor 2.0]
            (pinned-seed perf suite -> <out>/BENCH_<date>.json; --check
            fails when any case regresses past factor x the baseline;
            --shards sets the multi-shard case of the sharded suite)
  serve     --bind 0.0.0.0:7070 --clients N [--iterations J] [--gamma g]
            [--net-shards K] [--net-timeout-ms MS] [--net-queue CAP]
            [--net-rejoin-ms MS] [--lockstep] [--format table|json]
            [--learner pjrt|linear] [--stats-addr host:port]
            [--trace file.jsonl]
            (TCP deployment leader: K ingest shards frame-decode
            uploads concurrently into one ordered aggregation stage;
            --net-timeout-ms is the per-connection mid-frame stall
            deadline (0 disables), --net-queue bounds the ingest queue
            (backpressure), --net-rejoin-ms aborts the run when a
            disconnected worker still owes a move after that much event
            silence (0 waits forever), --lockstep gates rounds so the
            run is bit-identical at any K and to the in-process
            reference. --stats-addr serves a Prometheus-text snapshot
            of live counters over plain TCP and logs a 10s digest;
            --trace records the aggregation stage's apply order)
  join      --connect host:7070 --worker-id K --workers N
            [--learner pjrt|linear] [--local-steps E] [--delta]
            [--faults drop=p,cut=p,churn=pxR] [--fault-seed S]
            [--reconnect-ms MS] [--connect-attempts N]
            (TCP worker; --faults injects a seeded, replayable
            socket-fault schedule: in-band drops, mid-frame cuts,
            churn with reconnect-and-resume; --delta uploads
            XOR-bitpattern deltas against the received global —
            bit-identical results, same frame size, compressible
            payload. serve and join run over real links, so both
            reject a channel=<spec> config)

COMMON OPTIONS:
  --artifacts <dir>   artifacts directory (default: artifacts)
  --jobs <N>          worker threads for multi-run commands
                      (default: available cores; results are
                      byte-identical at any N)
  -v / -q             raise / lower log verbosity
  --log-level <l>     error|warn|info|debug|trace (wins over -v/-q;
                      the REPRO_LOG env var is the fallback when no
                      verbosity flag is given)
  --help              this text

AGGREGATION POLICIES (--set aggregation=<spec>, also honored by serve):
  naive | solved | staleness[:g] | fedasync[:a[,mix]] | adaptive[:eta[,rho]]

SCENARIOS (--set scenario=<spec>, event-driven AFL engines):
  static | dropout:p | churn:rate[,cycle] | drift:period[,factor]

CAPACITY PROFILES (--set capacity=<spec>, event-driven AFL engines +
sim; rate-r clients train/upload the leading r-slice of each tensor):
  full | uniform:rate | classes:r1xf1,r2xf2,...

CHANNEL MODELS (--set channel=<spec>, event-driven AFL engines + sim;
per-client block-fading Markov chain scaling upload slots and losing
deep-faded uploads; pair with --scheduler channel-aware):
  ideal | markov[:p_move[,block_ticks]]
";

/// Boolean options (present/absent, no value) — everything else spelled
/// `--name` expects a value.
const BOOL_FLAGS: [&str; 4] = ["quick", "sim", "lockstep", "delta"];

/// Minimal option parser: flags with values, repeated --set collection,
/// whitelisted boolean flags.
struct Args {
    positional: Vec<String>,
    options: Vec<(String, String)>,
    sets: Vec<(String, String)>,
    flags: Vec<String>,
    /// Whether `-v`/`-q` was passed (suppresses the `REPRO_LOG` env
    /// fallback; an explicit `--log-level` still wins over both).
    verbosity_flag: bool,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args> {
        let mut positional: Vec<String> = Vec::new();
        let mut options = Vec::new();
        let mut sets = Vec::new();
        let mut flags = Vec::new();
        let mut verbosity_flag = false;
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            // `--check` is valueless only under `repro trace`; everywhere
            // else (`repro bench --check <baseline>`) it expects a path.
            // The command is always the first positional, so it is known
            // by the time its flags are parsed.
            let trace_cmd = positional.first().map(String::as_str) == Some("trace");
            if a == "--help" || a == "-h" {
                print!("{USAGE}");
                std::process::exit(0);
            } else if a == "-v" {
                logging::set_level(Level::Debug);
                verbosity_flag = true;
            } else if a == "-q" {
                logging::set_level(Level::Warn);
                verbosity_flag = true;
            } else if let Some(name) = a
                .strip_prefix("--")
                .filter(|n| BOOL_FLAGS.contains(n) || (trace_cmd && *n == "check"))
            {
                flags.push(name.to_string());
            } else if a == "--set" {
                let kv = it
                    .next()
                    .ok_or_else(|| anyhow!("--set expects key=value"))?;
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| anyhow!("--set expects key=value, got {kv:?}"))?;
                sets.push((k.to_string(), v.to_string()));
            } else if let Some(name) = a.strip_prefix("--") {
                let v = it
                    .next()
                    .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                options.push((name.to_string(), v.clone()));
            } else {
                positional.push(a.clone());
            }
        }
        Ok(Args {
            positional,
            options,
            sets,
            flags,
            verbosity_flag,
        })
    }

    /// Whether a whitelisted boolean flag (e.g. `--quick`) was passed.
    fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    fn opt(&self, name: &str) -> Option<&str> {
        self.options
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// All values of a repeatable option (`--axis`), in order.
    fn opts(&self, name: &str) -> Vec<&str> {
        self.options
            .iter()
            .filter(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    /// The `--jobs` worker-thread count (0 = auto).
    fn jobs(&self) -> Result<usize> {
        match self.opt("jobs") {
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--jobs expects an integer, got {s:?}")),
            None => Ok(0),
        }
    }

    fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    fn learner(&self) -> Result<LearnerKind> {
        match self.opt("learner") {
            Some(s) => LearnerKind::parse(s).ok_or_else(|| anyhow!("unknown learner {s:?}")),
            None => Ok(LearnerKind::default_for_build()),
        }
    }
}

/// Resolve the log level: an explicit `--log-level` always wins; the
/// `REPRO_LOG` env var is the fallback, unless `-v`/`-q` already chose.
/// A bad spelling is an error naming its source.
fn apply_log_level(args: &Args) -> Result<()> {
    let (source, spec) = match args.opt("log-level") {
        Some(s) => ("--log-level", s.to_string()),
        None => match std::env::var("REPRO_LOG") {
            Ok(s) if !args.verbosity_flag && !s.is_empty() => ("REPRO_LOG", s),
            _ => return Ok(()),
        },
    };
    let level = Level::parse(&spec).ok_or_else(|| {
        anyhow!("{source} expects error|warn|info|debug|trace, got {spec:?}")
    })?;
    logging::set_level(level);
    Ok(())
}

/// Build a run's telemetry handle from `--trace <file>`: a JSONL file
/// sink when the flag is present, the allocation-free no-op sink
/// otherwise.
fn open_telemetry(args: &Args) -> Result<Telemetry> {
    match args.opt("trace") {
        Some(p) => {
            Telemetry::to_file(std::path::Path::new(p)).with_context(|| format!("opening {p}"))
        }
        None => Ok(Telemetry::off()),
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path, &args.sets)?,
        None => {
            let mut c = RunConfig::default();
            for (k, v) in &args.sets {
                c.set_field(k, v)?;
            }
            c.validate()?;
            c
        }
    };
    Ok(cfg)
}

fn print_run_table(runs: &[&csmaafl::RunResult]) {
    println!(
        "{:<18} {:>7} {:>9} {:>9} {:>10} {:>9} {:>6} {:>9}",
        "series", "aggs", "final", "best", "stale(avg)", "fairness", "lost", "wall(s)"
    );
    for r in runs {
        println!(
            "{:<18} {:>7} {:>9.4} {:>9.4} {:>10.2} {:>9.3} {:>6} {:>9.1}",
            r.label,
            r.aggregations,
            r.final_accuracy(),
            r.best_accuracy(),
            r.mean_staleness,
            r.fairness,
            r.lost_uploads,
            r.wallclock_secs
        );
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    apply_train_shards(args, &mut cfg, false)?;
    let out_dir = args.opt_or("out", "results");
    let session = Session::new(cfg, args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    let mut tel = open_telemetry(args)?;
    let mut run = session.run_traced(&mut tel)?;
    tel.finish()?;
    if let Some(label) = args.opt("label") {
        run.label = label.to_string();
    }
    std::fs::create_dir_all(out_dir)?;
    let base = format!("{out_dir}/{}", run.label.replace([' ', '='], "_"));
    write_series_csv(format!("{base}.csv"), &[&run])?;
    std::fs::write(format!("{base}.json"), run.to_json().to_string_pretty())?;
    print_run_table(&[&run]);
    println!("wrote {base}.csv / .json");
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let mut cfg = load_config(args)?;
    apply_train_shards(args, &mut cfg, true)?;
    let out_dir = args.opt_or("out", "results");
    let session = Session::new(cfg, args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    // The four paper series always use each algorithm's own default
    // aggregation rule, whatever the base config says; the two
    // related-work policies (FedAsync polynomial decay, AsyncFedED-style
    // adaptive weighting) ride the same event-driven engine.
    // FedAvg and the solved-β baseline cannot simulate dynamic worlds,
    // so their rows pin `scenario=static`; the event-driven rows inherit
    // the base config's scenario (e.g. `--set scenario=dropout:0.1`
    // compares async-under-dropout against the clean sync baseline).
    let mut plan = Plan::new();
    for (alg, pin_static) in [
        ("fedavg", true),
        ("afl-naive", false),
        ("afl-baseline", true),
        ("csmaafl", false),
    ] {
        let mut row = vec![
            ("algorithm".to_string(), alg.to_string()),
            ("aggregation".to_string(), "auto".to_string()),
        ];
        if pin_static {
            row.push(("scenario".to_string(), "static".to_string()));
        }
        plan = plan.job(row);
    }
    for spec in ["fedasync:0.5", "adaptive"] {
        plan = plan.job([("algorithm", "csmaafl"), ("aggregation", spec)]);
    }
    let runs = PlanRunner::new(&session).jobs(args.jobs()?).run(&plan)?;
    std::fs::create_dir_all(out_dir)?;
    write_series_csv(
        format!("{out_dir}/compare.csv"),
        &runs.iter().collect::<Vec<_>>(),
    )?;
    print_run_table(&runs.iter().collect::<Vec<_>>());
    println!("wrote {out_dir}/compare.csv");
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let mut base = load_config(args)?;
    apply_train_shards(args, &mut base, true)?;
    let out_dir = args.opt_or("out", "results");
    let which = args.opt_or("fig", "all");
    let specs: Vec<&FigureSpec> = if which == "all" {
        FIGURES.iter().collect()
    } else {
        vec![figures::figure_spec(which)
            .ok_or_else(|| anyhow!("unknown figure {which:?} (fig3|fig4|fig5a|fig5b|all)"))?]
    };
    for spec in specs {
        let runs = figures::generate_figure(
            spec,
            &base,
            args.learner()?,
            args.opt_or("artifacts", "artifacts"),
            out_dir,
            args.jobs()?,
        )?;
        println!("--- {} ({}) ---", spec.id, spec.title);
        print_run_table(&runs.iter().collect::<Vec<_>>());
    }
    Ok(())
}

/// Sweep any config field over a value list: a one-axis plan on the
/// parallel runner (paired session; data-shaping params get per-job
/// sessions).
fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let out_dir = args.opt_or("out", "results");
    let param = args.opt_or("param", "gamma").to_string();
    let values: Vec<String> = args
        .opt_or("values", "0.1,0.2,0.4,0.6")
        .split(',')
        .map(str::to_string)
        .collect();
    let session = Session::new(cfg, args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    let plan = Plan::new().axis(&param, values);
    let runs = PlanRunner::new(&session)
        .jobs(args.jobs()?)
        .run(&plan)
        .with_context(|| format!("sweep over --param {param}"))?;
    std::fs::create_dir_all(out_dir)?;
    write_series_csv(
        format!("{out_dir}/sweep_{param}.csv"),
        &runs.iter().collect::<Vec<_>>(),
    )?;
    print_run_table(&runs.iter().collect::<Vec<_>>());
    println!("wrote {out_dir}/sweep_{param}.csv");
    Ok(())
}

/// Scalar `--set` overrides plus sweep axes, in CLI order.
type GridAxes = (Vec<(String, String)>, Vec<(String, Vec<String>)>);

/// Partition `--set` pairs (a repeated key is an axis, a unique key is
/// a base override) and parse `--axis` flags. Shared by the learner
/// grid and the `--sim` grid.
fn collect_axes(args: &Args) -> Result<GridAxes> {
    let mut scalars: Vec<(String, String)> = Vec::new();
    let mut axes: Vec<(String, Vec<String>)> = Vec::new();
    for (k, v) in &args.sets {
        if let Some((_, vs)) = axes.iter_mut().find(|(ak, _)| ak == k) {
            vs.push(v.clone());
        } else if let Some(pos) = scalars.iter().position(|(sk, _)| sk == k) {
            let (_, first) = scalars.remove(pos);
            axes.push((k.clone(), vec![first, v.clone()]));
        } else {
            scalars.push((k.clone(), v.clone()));
        }
    }
    for spec in args.opts("axis") {
        let (k, vs) = spec
            .split_once('=')
            .ok_or_else(|| anyhow!("--axis expects key=v1,v2,..., got {spec:?}"))?;
        // Values containing commas (churn:0.3,2 / fedasync:0.5,0.9) can
        // be separated with ';' instead: `--axis scenario=static;churn:0.3,2`.
        let sep = if vs.contains(';') { ';' } else { ',' };
        let values: Vec<String> = vs.split(sep).map(|s| s.trim().to_string()).collect();
        ensure!(
            values.iter().all(|v| !v.is_empty()),
            "--axis {k} has an empty value in {vs:?}"
        );
        ensure!(
            !axes.iter().any(|(ak, _)| ak == k) && !scalars.iter().any(|(sk, _)| sk == k),
            "axis {k:?} conflicts with an earlier --set/--axis for the same key"
        );
        axes.push((k.to_string(), values));
    }
    Ok((scalars, axes))
}

/// Cartesian multi-axis sweep: `--axis key=v1,v2` flags (and any key
/// repeated across `--set` flags) become plan axes; single-valued
/// `--set` keys configure the base. Emits a JSON results matrix plus
/// the long-format curves CSV. With `--sim`, sweeps the coordinator
/// scale simulator instead (`cmd_grid_sim`).
fn cmd_grid(args: &Args) -> Result<()> {
    if args.flag("sim") {
        return cmd_grid_sim(args);
    }
    let out_dir = args.opt_or("out", "results");
    let format = args.opt_or("format", "table");
    ensure!(
        format == "table" || format == "json",
        "unknown --format {format:?} (table|json)"
    );
    let (scalars, axes) = collect_axes(args)?;

    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(path, &scalars)?,
        None => {
            let mut c = RunConfig::default();
            for (k, v) in &scalars {
                c.set_field(k, v)?;
            }
            c.validate()?;
            c
        }
    };
    apply_train_shards(args, &mut cfg, true)?;

    let mut plan = Plan::new();
    for (k, vs) in axes {
        plan = plan.axis(&k, vs);
    }
    if let Some(r) = args.opt("replicates") {
        let r: usize = r
            .parse()
            .map_err(|_| anyhow!("--replicates expects an integer, got {r:?}"))?;
        plan = plan.replicates(r);
    }
    let jobs = plan.expand(cfg.seed);
    ensure!(!jobs.is_empty(), "grid expanded to zero jobs (empty axis?)");

    let session = Session::new(cfg, args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    let threads = experiment::effective_jobs(args.jobs()?, jobs.len());
    let t0 = std::time::Instant::now();
    let runs = PlanRunner::new(&session).jobs(threads).run_jobs(&jobs)?;
    let elapsed = t0.elapsed().as_secs_f64();

    std::fs::create_dir_all(out_dir)?;
    let record = experiment::grid_record(&plan, &jobs, &runs);
    std::fs::write(format!("{out_dir}/grid.json"), record.to_string_pretty())?;
    write_series_csv(
        format!("{out_dir}/grid.csv"),
        &runs.iter().collect::<Vec<_>>(),
    )?;
    if format == "json" {
        println!("{}", record.to_string_pretty());
    } else {
        print_run_table(&runs.iter().collect::<Vec<_>>());
    }
    println!(
        "grid: {} jobs on {} thread(s) in {elapsed:.1}s; wrote {out_dir}/grid.json + grid.csv",
        jobs.len(),
        threads
    );
    Ok(())
}

/// `repro grid --sim`: cartesian sweep over the coordinator scale
/// simulator. Axes/overrides use `ScaleSimConfig::set_field` keys plus
/// the engine's `shards` knob; cells run sequentially (each cell is
/// itself multi-threaded) and the matrix rows are the deterministic
/// `ScaleSimReport::summary_json` records, so `grid.json` is
/// byte-identical whatever hardware parallelism each cell used.
fn cmd_grid_sim(args: &Args) -> Result<()> {
    let out_dir = args.opt_or("out", "results");
    let format = args.opt_or("format", "table");
    ensure!(
        format == "table" || format == "json",
        "unknown --format {format:?} (table|json)"
    );
    ensure!(
        args.opt("config").is_none(),
        "--config does not apply to --sim grids (use --set/--axis sim keys)"
    );
    ensure!(
        args.opt("replicates").is_none(),
        "--replicates does not apply to --sim grids (sweep seed=... instead)"
    );
    let (scalars, axes) = collect_axes(args)?;
    ensure!(!axes.is_empty(), "--sim grid needs at least one --axis");

    let mut base = ScaleSimConfig::default();
    let mut base_shards = parse_shards(args.opt("shards"))?;
    for (k, v) in &scalars {
        if k == "shards" {
            base_shards = parse_shards(Some(v))?;
        } else {
            base.set_field(k, v)?;
        }
    }

    // Expand through the experiment engine's Plan (first axis
    // outermost, last innermost — the same stable order and `k=v`
    // spelling as learner grids), with the sim keys as overrides.
    let mut plan = Plan::new();
    for (k, vs) in &axes {
        plan = plan.axis(k, vs.clone());
    }
    let cells = plan.expand(base.seed);
    ensure!(!cells.is_empty(), "grid expanded to zero cells (empty axis?)");

    // Validate every cell before any cell runs (same fail-fast contract
    // as the learner grid): `shards` parses here, everything else
    // through set_field + the registry-spelling validation.
    let mut jobs = Vec::with_capacity(cells.len());
    for cell in &cells {
        let mut cfg = base.clone();
        let mut shards = base_shards;
        let mut outcome = Ok(());
        for (k, v) in &cell.overrides {
            outcome = if k == "shards" {
                parse_shards(Some(v)).map(|n| shards = n)
            } else {
                cfg.set_field(k, v)
            };
            if outcome.is_err() {
                break;
            }
        }
        outcome
            .and_then(|()| cfg.validate())
            .with_context(|| format!("cell {} ({})", cell.index, cell.spec()))?;
        jobs.push((cfg, shards));
    }

    let t0 = std::time::Instant::now();
    let mut rows = Vec::with_capacity(jobs.len());
    for (cell, (cfg, shards)) in cells.iter().zip(&jobs) {
        let report = run_sharded_sim(cfg, *shards)
            .with_context(|| format!("cell {} ({})", cell.index, cell.spec()))?;
        if format == "table" {
            println!(
                "{:<40} aggs={:<8} events={:<9} ticks={:<10} lost={:<6} wall={:.2}s",
                cell.spec(),
                report.aggregations,
                report.events,
                report.virtual_ticks,
                report.lost_uploads,
                report.wall_secs
            );
        }
        let mut overrides = Json::object();
        for (k, v) in &cell.overrides {
            overrides.set(k, Json::Str(v.clone()));
        }
        let mut row = Json::object();
        row.set("index", Json::Int(cell.index as i64))
            .set("spec", Json::Str(cell.spec()))
            .set("overrides", overrides)
            .set("summary", report.summary_json());
        rows.push(row);
    }
    let elapsed = t0.elapsed().as_secs_f64();

    let axes_json = axes
        .iter()
        .map(|(key, values)| {
            let mut a = Json::object();
            a.set("key", Json::Str(key.clone())).set(
                "values",
                Json::Array(values.iter().map(|v| Json::Str(v.clone())).collect()),
            );
            a
        })
        .collect();
    let mut record = Json::object();
    record
        .set("axes", Json::Array(axes_json))
        .set("jobs", Json::Array(rows));
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(format!("{out_dir}/grid.json"), record.to_string_pretty())?;
    if format == "json" {
        println!("{}", record.to_string_pretty());
    }
    println!(
        "sim grid: {} cell(s) in {elapsed:.1}s; wrote {out_dir}/grid.json",
        jobs.len()
    );
    Ok(())
}

/// Parse a `--shards` value: a positive worker count, defaulting to the
/// machine's available parallelism when absent.
fn parse_shards(opt: Option<&str>) -> Result<usize> {
    parse_shard_count("--shards", opt)
}

/// Thread the learner-engine `--shards` flag into a run config.
///
/// An explicit value is validated here — before `Session::new`
/// generates any data — and, on multi-run commands, checked against an
/// explicit `--jobs` so the two axes of parallelism cannot silently
/// oversubscribe the machine. When the flag is absent, multi-run
/// commands pin `shards=1` (the plan-level `--jobs` already owns the
/// cores) unless the config asked for something else; `repro train`
/// runs one cell so it keeps the config's `auto` (= all cores).
fn apply_train_shards(args: &Args, cfg: &mut RunConfig, multi_run: bool) -> Result<()> {
    match args.opt("shards") {
        Some(s) => {
            let shards = parse_shards(Some(s))?;
            let jobs = args.jobs()?;
            if jobs >= 2 && shards >= 2 {
                let cores = std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1);
                ensure!(
                    jobs.saturating_mul(shards) <= cores,
                    "--jobs {jobs} x --shards {shards} = {} worker threads \
                     oversubscribes this machine's {cores} core(s); lower one of \
                     them or drop --shards (results are bit-identical at any \
                     shard count — the flags only change wall-clock)",
                    jobs.saturating_mul(shards)
                );
            }
            cfg.shards = Some(shards);
        }
        None => {
            if multi_run && cfg.shards.is_none() {
                cfg.shards = Some(1);
            }
        }
    }
    Ok(())
}

/// Shared by `--shards` and `--net-shards`: a positive integer, default
/// = available cores.
fn parse_shard_count(flag: &str, opt: Option<&str>) -> Result<usize> {
    match opt {
        Some(s) => {
            let n: usize = s
                .parse()
                .map_err(|_| anyhow!("{flag} expects a positive integer, got {s:?}"))?;
            ensure!(n >= 1, "{flag} must be >= 1, got {n}");
            Ok(n)
        }
        None => Ok(std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)),
    }
}

/// Paper-facing comparison tables from the stored figure records.
fn cmd_analyze(args: &Args) -> Result<()> {
    let dir = args.opt_or("results", "results");
    let mut found = false;
    for fig in ["fig3", "fig4", "fig5a", "fig5b"] {
        let path = format!("{dir}/{fig}.json");
        if std::path::Path::new(&path).exists() {
            let (title, runs) = csmaafl::analyze::load_figure_record(&path)?;
            println!("{}", csmaafl::analyze::figure_table(&title, &runs));
            found = true;
        }
    }
    if !found {
        bail!("no figure records in {dir}/ — run `repro figures` first");
    }
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let clients: usize = args.opt_or("clients", "20").parse()?;
    let local_steps: usize = args.opt_or("local-steps", "16").parse()?;
    let slow: f64 = args.opt_or("slow-factor", "4.0").parse()?;
    let out = args.opt_or("out", "results");
    let path = figures::generate_timeline(clients, local_steps, TimeModel::default(), slow, out)?;
    println!("{}", std::fs::read_to_string(&path)?);
    println!("wrote {path}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let what = args
        .positional
        .get(1)
        .map(String::as_str)
        .unwrap_or("naive-decay");
    let clients: usize = args.opt_or("clients", "20").parse()?;
    match what {
        "naive-decay" => print!("{}", figures::naive_decay_table(clients)),
        "betas" => {
            let alpha = vec![1.0 / clients as f64; clients];
            let betas = csmaafl::coordinator::solve_betas(&alpha)?;
            println!("schedule_position,beta");
            for (t, b) in betas.iter().enumerate() {
                println!("{},{b:.10}", t + 1);
            }
        }
        other => bail!("unknown inspect target {other:?} (naive-decay|betas)"),
    }
    Ok(())
}

fn cmd_smoke(args: &Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let manifest = csmaafl::runtime::Manifest::load(dir)?;
    for name in manifest.configs.keys() {
        let engine = csmaafl::runtime::Engine::from_manifest(&manifest, name)?;
        let p = engine.init(0)?;
        println!(
            "config {name}: init OK ({} tensors, {} params)",
            p.tensors.len(),
            p.numel()
        );
        let m = engine.model();
        let img = m.image_numel();
        let xs = vec![0.5f32; m.batch * img];
        let ys: Vec<i32> = (0..m.batch as i32).collect();
        let (_, loss) = engine.train_step(&p, &xs, &ys)?;
        println!("config {name}: train_step OK (loss {loss:.4})");
        let ex = vec![0.5f32; m.eval_batch * img];
        let ey = vec![0i32; m.eval_batch];
        let (correct, _) = engine.eval_chunk(&p, &ex, &ey)?;
        println!("config {name}: eval_chunk OK ({correct}/{} correct)", m.eval_batch);
        let agg = engine.aggregate(&p, &p, 0.5)?;
        anyhow::ensure!(agg.max_abs_diff(&p) < 1e-6, "aggregate(p,p) != p");
        println!("config {name}: aggregate OK");
    }
    println!("smoke: all artifacts healthy");
    Ok(())
}

/// Coordinator-only scale simulation: the real event loop, scheduler
/// fast paths and arena-backed aggregation at up to 10^6 clients, with
/// synthetic local training (no learner, no dataset) parallelized over
/// `--shards` workers — bit-identical output at any shard count.
fn cmd_sim(args: &Args) -> Result<()> {
    let format = args.opt_or("format", "table");
    ensure!(
        format == "table" || format == "json",
        "unknown --format {format:?} (table|json)"
    );
    let sched_spec = args.opt_or("scheduler", "oldest");
    let scheduler = SchedulerPolicy::parse(sched_spec)
        .ok_or_else(|| anyhow!("unknown scheduler {sched_spec:?}"))?;
    let het_spec = args.opt_or("heterogeneity", "uniform:4");
    let heterogeneity = HeterogeneityProfile::parse(het_spec)
        .ok_or_else(|| anyhow!("unknown heterogeneity {het_spec:?}"))?;
    let shards = parse_shards(args.opt("shards"))?;
    // `--set` on sim is reserved for the registry spellings shared with
    // the experiment engine; everything else has a dedicated flag.
    let mut scenario = args.opt("scenario").map(str::to_string);
    let mut capacity = args.opt("capacity").map(str::to_string);
    let mut channel = args.opt("channel").map(str::to_string);
    for (k, v) in &args.sets {
        match k.as_str() {
            "scenario" => scenario = Some(v.clone()),
            "capacity" => capacity = Some(v.clone()),
            "channel" => channel = Some(v.clone()),
            other => bail!(
                "repro sim --set supports only scenario=<spec> | capacity=<spec> \
                 | channel=<spec> \
                 (got {other:?}; use the dedicated --{other} flag if one exists)"
            ),
        }
    }
    let cfg = ScaleSimConfig {
        clients: args.opt_or("clients", "100000").parse()?,
        iterations: args.opt_or("iterations", "0").parse()?,
        params: args.opt_or("params", "64").parse()?,
        seed: args.opt_or("seed", "42").parse()?,
        scheduler,
        aggregation: args.opt("aggregation").map(str::to_string),
        scenario,
        capacity,
        channel,
        gamma: args.opt_or("gamma", "0.2").parse()?,
        train_passes: args.opt_or("train-passes", "1").parse()?,
        heterogeneity,
        ..ScaleSimConfig::default()
    };
    let mut tel = open_telemetry(args)?;
    let (report, _) = run_sharded_sim_traced(&cfg, shards, &mut tel)?;
    tel.finish()?;
    if format == "json" {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.table());
    }
    Ok(())
}

/// Pinned-seed perf suite -> `BENCH_<date>.json`, with the optional
/// `--check <baseline>` regression gate CI runs.
fn cmd_bench(args: &Args) -> Result<()> {
    let format = args.opt_or("format", "table");
    ensure!(
        format == "table" || format == "json",
        "unknown --format {format:?} (table|json)"
    );
    let factor: f64 = args
        .opt_or("factor", "2.0")
        .parse()
        .map_err(|_| anyhow!("--factor expects a number"))?;
    let cfg = perf::BenchConfig {
        quick: args.flag("quick"),
        suite: args.opt("suite").map(str::to_string),
        // Only an explicit --shards is forwarded; the suite otherwise
        // picks min(4, available cores) for its multi-shard case.
        shards: args.opt("shards").map(|s| parse_shards(Some(s))).transpose()?,
    };
    // Load and schema-check the baseline up front so a bad path, bad
    // JSON or wrong-schema file fails before the (slow) suites run —
    // and before anything is written to --out.
    let baseline = match args.opt("check") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading baseline {path}"))?;
            let j = json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))?;
            let schema = j.get("schema").and_then(Json::as_str);
            ensure!(
                schema == Some(perf::BENCH_SCHEMA),
                "baseline {path}: schema {schema:?} != {:?} — re-record it",
                perf::BENCH_SCHEMA
            );
            Some((path, j))
        }
        None => None,
    };
    let record = perf::run(&cfg)?;
    let out_dir = args.opt_or("out", "results");
    std::fs::create_dir_all(out_dir)?;
    // Name the file by the record's own date stamp so the two can
    // never disagree across a UTC midnight boundary.
    let date = record
        .get("date")
        .and_then(Json::as_str)
        .unwrap_or("undated")
        .to_string();
    let path = format!("{out_dir}/BENCH_{date}.json");
    std::fs::write(&path, record.to_string_pretty())?;
    if format == "json" {
        println!("{}", record.to_string_pretty());
    } else {
        perf::print_table(&record);
    }
    // Status lines go to stderr: `--format json` stdout stays parseable.
    eprintln!("wrote {path}");
    if let Some((baseline_path, baseline)) = baseline {
        // An unfiltered run must measure every baseline suite; with a
        // --suite filter only the measured suites are compared.
        let strict = cfg.suite.is_none();
        let (failures, compared) = perf::check(&record, &baseline, factor, strict)?;
        if failures.is_empty() {
            eprintln!("bench check: {compared} case(s) within {factor}x of {baseline_path}");
        } else {
            for f in &failures {
                eprintln!("bench check: {f}");
            }
            bail!("{} case(s) regressed beyond {factor}x vs {baseline_path}", failures.len());
        }
    }
    Ok(())
}

/// TCP deployment leader: same Algorithm-1 logic as the simulator, over
/// real sockets (rust/src/net/), ingesting through `--net-shards`
/// concurrent frame-decoding shards into one ordered aggregation stage.
fn cmd_serve(args: &Args) -> Result<()> {
    let format = args.opt_or("format", "table");
    ensure!(
        format == "table" || format == "json",
        "unknown --format {format:?} (table|json)"
    );
    let cfg = load_config(args)?;
    // Validate every net knob before Session::new generates data, so a
    // typo'd flag fails fast.
    let net_shards = parse_shard_count("--net-shards", args.opt("net-shards"))?;
    let read_timeout_ms: u64 = args
        .opt_or("net-timeout-ms", "5000")
        .parse()
        .map_err(|_| anyhow!("--net-timeout-ms expects milliseconds (integer, 0 disables)"))?;
    let queue_capacity: usize = args
        .opt_or("net-queue", "1024")
        .parse()
        .map_err(|_| anyhow!("--net-queue expects a positive integer"))?;
    ensure!(queue_capacity >= 1, "--net-queue must be >= 1, got {queue_capacity}");
    let rejoin_timeout_ms: u64 = args
        .opt_or("net-rejoin-ms", "30000")
        .parse()
        .map_err(|_| anyhow!("--net-rejoin-ms expects milliseconds (integer, 0 disables)"))?;
    ensure!(
        cfg.channel.is_none(),
        "serve runs over real links; channel=<spec> applies only to the \
         simulation engines — drop the channel setting"
    );
    let session =
        Session::new(cfg.clone(), args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    let leader_cfg = csmaafl::net::LeaderConfig {
        bind: args.opt_or("bind", "127.0.0.1:7070").to_string(),
        clients: args.opt_or("clients", "4").parse()?,
        max_iterations: args.opt_or("iterations", "200").parse()?,
        gamma: args.opt_or("gamma", &cfg.gamma.to_string()).parse()?,
        mu_rho: cfg.mu_rho,
        aggregation: cfg.aggregation.clone(),
        net_shards,
        read_timeout_ms,
        queue_capacity,
        lockstep: args.flag("lockstep"),
        rejoin_timeout_ms,
        stats_addr: args.opt("stats-addr").map(str::to_string),
        trace: args.opt("trace").map(str::to_string),
    };
    let w0 = session.learner().init(cfg.seed as u32)?;
    let report = csmaafl::net::run_leader(&leader_cfg, w0)?;
    let (acc, loss) = session.learner().evaluate(&report.final_model, &session.test)?;
    if format == "json" {
        // Config (every knob at its effective value, defaults included)
        // and deterministic summary separated the way `repro sim` does
        // it: the summary of a lockstep run is bit-identical at any
        // --net-shards.
        let mut config = Json::object();
        config
            .set("bind", Json::Str(leader_cfg.bind.clone()))
            .set("clients", Json::Int(leader_cfg.clients as i64))
            .set("iterations", Json::Int(leader_cfg.max_iterations as i64))
            .set("net_shards", Json::Int(net_shards as i64))
            .set("net_timeout_ms", Json::Int(read_timeout_ms as i64))
            .set("net_queue", Json::Int(queue_capacity as i64))
            .set("net_rejoin_ms", Json::Int(rejoin_timeout_ms as i64))
            .set("lockstep", Json::Bool(leader_cfg.lockstep))
            .set("gamma", Json::Float(leader_cfg.gamma));
        let mut j = Json::object();
        j.set("schema", Json::Str("csmaafl-serve-v1".to_string()))
            .set("config", config)
            .set("summary", report.summary_json())
            .set("wallclock_secs", Json::Float(report.wallclock_secs))
            .set("accuracy", Json::Float(acc))
            .set("loss", Json::Float(loss));
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "leader: {} aggregations, {} lost uploads, {:.2}s wall, mean staleness {:.2}",
            report.aggregations, report.lost_uploads, report.wallclock_secs, report.mean_staleness
        );
        println!("updates per client: {:?}", report.updates_per_client);
        println!("final test accuracy {acc:.4}, loss {loss:.4}");
    }
    Ok(())
}

/// Validate / summarize a `--trace` JSONL file: per-kind event counts,
/// staleness + queue-depth histograms, Jain fairness over grants, loss
/// causes and a staleness timeline. `--check` only validates.
fn cmd_trace(args: &Args) -> Result<()> {
    let path = args.positional.get(1).ok_or_else(|| {
        anyhow!("usage: repro trace <file.jsonl> [--check] — see `repro --help`")
    })?;
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading trace {path}"))?;
    let summary = csmaafl::analyze::summarize_trace(&text)
        .with_context(|| format!("invalid trace {path}"))?;
    if args.flag("check") {
        println!("trace ok: {} event(s) in {path}", summary.events);
    } else {
        print!("{}", csmaafl::analyze::trace_table(&summary));
    }
    Ok(())
}

/// TCP deployment worker. `--worker-id K --workers N` selects shard K of
/// an N-way partition so independent processes agree on the data split.
fn cmd_join(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let workers: usize = args.opt_or("workers", "4").parse()?;
    let worker_id: usize = args.opt_or("worker-id", "0").parse()?;
    anyhow::ensure!(worker_id < workers, "worker-id out of range");
    // Validate the fault spec before Session::new generates data, so a
    // typo'd flag fails fast.
    let faults = args
        .opt("faults")
        .map(|spec| -> Result<csmaafl::net::FaultPlan> {
            let seed: u64 = args
                .opt_or("fault-seed", &cfg.seed.to_string())
                .parse()
                .map_err(|_| anyhow!("--fault-seed expects an integer"))?;
            csmaafl::net::FaultPlan::parse(spec, seed)
        })
        .transpose()?;
    ensure!(
        cfg.channel.is_none(),
        "join runs over real links; channel=<spec> applies only to the \
         simulation engines — drop the channel setting"
    );
    let session =
        Session::new(cfg.clone(), args.learner()?, args.opt_or("artifacts", "artifacts"))?;
    let shards = csmaafl::data::partition(&session.train, workers, cfg.partition, cfg.seed);
    let uploads = csmaafl::net::run_worker(&csmaafl::net::WorkerConfig {
        connect: args.opt_or("connect", "127.0.0.1:7070").to_string(),
        worker: worker_id as u32,
        name: format!("worker-{worker_id}"),
        learner: session.learner(),
        data: &session.train,
        indices: shards[worker_id].indices.clone(),
        local_steps: args.opt_or("local-steps", &cfg.local_steps.to_string()).parse()?,
        faults,
        delta_uploads: args.flag("delta"),
        reconnect_delay_ms: args.opt_or("reconnect-ms", "50").parse()?,
        max_connect_attempts: args.opt_or("connect-attempts", "100").parse()?,
    })?;
    println!("worker-{worker_id}: {uploads} uploads, shutting down");
    Ok(())
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print!("{USAGE}");
        return Ok(());
    }
    let args = Args::parse(&argv).context("parsing arguments")?;
    apply_log_level(&args)?;
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "compare" => cmd_compare(&args),
        "figures" => cmd_figures(&args),
        "sweep" => cmd_sweep(&args),
        "grid" => cmd_grid(&args),
        "analyze" => cmd_analyze(&args),
        "timeline" => cmd_timeline(&args),
        "inspect" => cmd_inspect(&args),
        "smoke" => cmd_smoke(&args),
        "sim" => cmd_sim(&args),
        "bench" => cmd_bench(&args),
        "trace" => cmd_trace(&args),
        "serve" => cmd_serve(&args),
        "join" => cmd_join(&args),
        "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}
