//! Deterministic synthetic 28x28 image generators.
//!
//! Each class has a smooth prototype field built from Gaussian bumps at
//! class-specific (seeded) positions; a sample is a randomly shifted,
//! brightness-jittered, noise-corrupted copy of its class prototype.
//!
//! * `Mnist` — 3 compact bumps per class (stroke-like), light noise:
//!   an easy task, like MNIST.
//! * `Fashion` — broader bumps plus horizontal texture, heavier noise,
//!   and consecutive class pairs sharing bumps (shirt/pullover-style
//!   confusability): deliberately harder, like Fashion-MNIST.

use crate::util::rng::Rng;

/// Image height/width in pixels.
pub const HW: usize = 28;
/// Flattened pixels per image (28×28).
pub const IMG: usize = HW * HW;
/// Number of label classes.
pub const NUM_CLASSES: usize = 10;

/// Which synthetic distribution to generate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    /// Easy stroke-like prototypes with light noise (MNIST-like).
    Mnist,
    /// Broader, textured, pairwise-confusable prototypes with heavier
    /// noise (Fashion-MNIST-like; deliberately harder).
    Fashion,
}

impl SynthKind {
    /// Parse a CLI/JSON spelling (`mnist`, `fashion`/`fmnist`).
    pub fn parse(s: &str) -> Option<SynthKind> {
        match s.to_ascii_lowercase().as_str() {
            "mnist" => Some(SynthKind::Mnist),
            "fashion" | "fashion-mnist" | "fmnist" => Some(SynthKind::Fashion),
            _ => None,
        }
    }

    /// Canonical name used in labels and serialized configs.
    pub fn name(&self) -> &'static str {
        match self {
            SynthKind::Mnist => "mnist",
            SynthKind::Fashion => "fashion",
        }
    }
}

/// A labelled image set, images flattened row-major (n * 784 f32 in [0,1]).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Flattened images, `len() * IMG` f32 pixels in [0, 1].
    pub x: Vec<f32>,
    /// Class labels in `0..NUM_CLASSES`, one per image.
    pub y: Vec<i32>,
}

impl Dataset {
    /// Number of labelled images.
    pub fn len(&self) -> usize {
        self.y.len()
    }

    /// Whether the set holds no images.
    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    /// The `i`-th image as a flat 784-pixel slice.
    pub fn image(&self, i: usize) -> &[f32] {
        &self.x[i * IMG..(i + 1) * IMG]
    }
}

struct Bump {
    cx: f64,
    cy: f64,
    sigma: f64,
    amp: f64,
}

fn class_prototype(kind: SynthKind, class: usize, rng: &Rng) -> Vec<f32> {
    let mut r = rng.fork(1000 + class as u64);
    let mut bumps: Vec<Bump> = Vec::new();
    match kind {
        SynthKind::Mnist => {
            for _ in 0..3 {
                bumps.push(Bump {
                    cx: r.range_f64(6.0, 22.0),
                    cy: r.range_f64(6.0, 22.0),
                    sigma: r.range_f64(2.2, 3.4),
                    amp: r.range_f64(0.75, 1.0),
                });
            }
        }
        SynthKind::Fashion => {
            // Shared bumps between class pairs (2k, 2k+1): confusable pairs.
            let mut pair = rng.fork(2000 + (class / 2) as u64);
            for _ in 0..2 {
                bumps.push(Bump {
                    cx: pair.range_f64(7.0, 21.0),
                    cy: pair.range_f64(7.0, 21.0),
                    sigma: pair.range_f64(4.0, 6.0),
                    amp: pair.range_f64(0.5, 0.8),
                });
            }
            for _ in 0..3 {
                bumps.push(Bump {
                    cx: r.range_f64(5.0, 23.0),
                    cy: r.range_f64(5.0, 23.0),
                    sigma: r.range_f64(3.0, 5.0),
                    amp: r.range_f64(0.4, 0.7),
                });
            }
        }
    }
    let mut proto = vec![0.0f32; IMG];
    for (idx, p) in proto.iter_mut().enumerate() {
        let yy = (idx / HW) as f64;
        let xx = (idx % HW) as f64;
        let mut v = 0.0f64;
        for b in &bumps {
            let d2 = (xx - b.cx).powi(2) + (yy - b.cy).powi(2);
            v += b.amp * (-d2 / (2.0 * b.sigma * b.sigma)).exp();
        }
        if kind == SynthKind::Fashion {
            // Class-dependent horizontal texture (garment weave).
            let freq = 0.5 + 0.15 * class as f64;
            v += 0.12 * ((yy * freq).sin() * 0.5 + 0.5);
        }
        *p = v.min(1.0) as f32;
    }
    proto
}

fn noise_level(kind: SynthKind) -> f32 {
    match kind {
        SynthKind::Mnist => 0.08,
        SynthKind::Fashion => 0.16,
    }
}

fn max_shift(kind: SynthKind) -> i64 {
    match kind {
        SynthKind::Mnist => 2,
        SynthKind::Fashion => 3,
    }
}

/// Generate one sample of `class` into `out` (784 f32).
fn sample_into(
    out: &mut [f32],
    proto: &[f32],
    kind: SynthKind,
    r: &mut Rng,
) {
    let ms = max_shift(kind);
    let dx = r.below((2 * ms + 1) as u64) as i64 - ms;
    let dy = r.below((2 * ms + 1) as u64) as i64 - ms;
    let bright = 0.75 + 0.25 * r.f32();
    let noise = noise_level(kind);
    for yy in 0..HW as i64 {
        for xx in 0..HW as i64 {
            let sx = xx - dx;
            let sy = yy - dy;
            let base = if (0..HW as i64).contains(&sx) && (0..HW as i64).contains(&sy) {
                proto[(sy * HW as i64 + sx) as usize]
            } else {
                0.0
            };
            let v = base * bright + noise * r.normal();
            out[(yy * HW as i64 + xx) as usize] = v.clamp(0.0, 1.0);
        }
    }
}

/// Generate a (train, test) pair. Labels are balanced (n rounded up to a
/// multiple of 10 then truncated back) and shuffled.
pub fn generate(
    kind: SynthKind,
    n_train: usize,
    n_test: usize,
    seed: u64,
) -> (Dataset, Dataset) {
    let root = Rng::new(seed ^ 0xC5_3A_AF_1u64);
    let protos: Vec<Vec<f32>> = (0..NUM_CLASSES)
        .map(|c| class_prototype(kind, c, &root))
        .collect();
    let make = |n: usize, label: u64| -> Dataset {
        let mut r = root.fork(label);
        let mut y: Vec<i32> = (0..n).map(|i| (i % NUM_CLASSES) as i32).collect();
        r.shuffle(&mut y);
        let mut x = vec![0.0f32; n * IMG];
        for (i, &cls) in y.iter().enumerate() {
            sample_into(
                &mut x[i * IMG..(i + 1) * IMG],
                &protos[cls as usize],
                kind,
                &mut r,
            );
        }
        Dataset { x, y }
    };
    (make(n_train, 1), make(n_test, 2))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_in_seed() {
        let (a, _) = generate(SynthKind::Mnist, 50, 10, 7);
        let (b, _) = generate(SynthKind::Mnist, 50, 10, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        let (c, _) = generate(SynthKind::Mnist, 50, 10, 8);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn pixels_in_unit_range() {
        for kind in [SynthKind::Mnist, SynthKind::Fashion] {
            let (tr, te) = generate(kind, 100, 40, 3);
            for v in tr.x.iter().chain(te.x.iter()) {
                assert!((0.0..=1.0).contains(v));
            }
        }
    }

    #[test]
    fn labels_balanced() {
        let (tr, _) = generate(SynthKind::Mnist, 200, 10, 1);
        let mut counts = [0usize; NUM_CLASSES];
        for &c in &tr.y {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 20), "{counts:?}");
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-prototype classification on clean samples should beat
        // random guessing by a wide margin: the task must be learnable.
        for kind in [SynthKind::Mnist, SynthKind::Fashion] {
            let root = Rng::new(7 ^ 0xC5_3A_AF_1u64);
            let protos: Vec<Vec<f32>> = (0..NUM_CLASSES)
                .map(|c| class_prototype(kind, c, &root))
                .collect();
            let (tr, _) = generate(kind, 400, 10, 7);
            let mut correct = 0usize;
            for i in 0..tr.len() {
                let img = tr.image(i);
                let mut best = (f32::MAX, 0usize);
                for (c, p) in protos.iter().enumerate() {
                    let d: f32 = img
                        .iter()
                        .zip(p.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if best.1 == tr.y[i] as usize {
                    correct += 1;
                }
            }
            let acc = correct as f64 / tr.len() as f64;
            assert!(acc > 0.5, "{kind:?} nearest-proto acc {acc}");
        }
    }

    #[test]
    fn fashion_is_harder_than_mnist() {
        // Same nearest-prototype probe: fashion accuracy should be lower.
        let probe = |kind: SynthKind| -> f64 {
            let root = Rng::new(11 ^ 0xC5_3A_AF_1u64);
            let protos: Vec<Vec<f32>> = (0..NUM_CLASSES)
                .map(|c| class_prototype(kind, c, &root))
                .collect();
            let (tr, _) = generate(kind, 400, 10, 11);
            let mut correct = 0usize;
            for i in 0..tr.len() {
                let img = tr.image(i);
                let mut best = (f32::MAX, 0usize);
                for (c, p) in protos.iter().enumerate() {
                    let d: f32 = img
                        .iter()
                        .zip(p.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    if d < best.0 {
                        best = (d, c);
                    }
                }
                if best.1 == tr.y[i] as usize {
                    correct += 1;
                }
            }
            correct as f64 / tr.len() as f64
        };
        assert!(probe(SynthKind::Mnist) > probe(SynthKind::Fashion));
    }

    #[test]
    fn train_test_disjoint_noise() {
        let (tr, te) = generate(SynthKind::Mnist, 30, 30, 5);
        // Same prototypes but different sample streams.
        assert_ne!(tr.x[..IMG], te.x[..IMG]);
    }

    #[test]
    fn parse_kind() {
        assert_eq!(SynthKind::parse("MNIST"), Some(SynthKind::Mnist));
        assert_eq!(SynthKind::parse("fmnist"), Some(SynthKind::Fashion));
        assert_eq!(SynthKind::parse("cifar"), None);
    }
}
