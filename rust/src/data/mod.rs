//! Data substrate: synthetic MNIST/Fashion-MNIST-like generators and the
//! IID / non-IID client partitioners of Sec. IV.
//!
//! The evaluation image datasets cannot be downloaded in this offline
//! environment, so we synthesize class-structured 28x28 imagery with the
//! properties the paper's phenomena actually depend on (see DESIGN.md §5):
//! 10 visually distinct classes, intra-class variation, a harder "fashion"
//! variant, and exact client partitioning (IID shuffle vs 2-classes-per-
//! client shards).

mod partition;
mod synth;

pub use partition::{partition, ClientShard, Partition};
pub use synth::{generate, Dataset, SynthKind};
