//! Client data partitioners (Sec. IV simulation setup).
//!
//! * `Iid` — images randomly allocated equally among clients.
//! * `TwoClass` — each client holds samples of exactly two classes (the
//!   classical FedAvg shard construction the paper uses for non-IID).

use crate::data::synth::{Dataset, NUM_CLASSES};
use crate::util::rng::Rng;

/// Data distribution across clients.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Random equal allocation: every shard is class-diverse.
    Iid,
    /// Each client holds samples of exactly two classes (non-IID).
    TwoClass,
}

impl Partition {
    /// Parse a CLI/JSON spelling (`iid`, `noniid`/`twoclass`).
    pub fn parse(s: &str) -> Option<Partition> {
        match s.to_ascii_lowercase().as_str() {
            "iid" => Some(Partition::Iid),
            "noniid" | "non-iid" | "twoclass" | "2class" => Some(Partition::TwoClass),
            _ => None,
        }
    }

    /// Canonical name used in labels and serialized configs.
    pub fn name(&self) -> &'static str {
        match self {
            Partition::Iid => "iid",
            Partition::TwoClass => "noniid",
        }
    }
}

/// The sample indices owned by one client.
#[derive(Debug, Clone)]
pub struct ClientShard {
    /// Indices into the training [`Dataset`] this client owns.
    pub indices: Vec<usize>,
}

impl ClientShard {
    /// Number of samples on this shard.
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// Whether the shard holds no samples.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Distinct classes present on this shard.
    pub fn classes(&self, ds: &Dataset) -> Vec<i32> {
        let mut cs: Vec<i32> = self.indices.iter().map(|&i| ds.y[i]).collect();
        cs.sort_unstable();
        cs.dedup();
        cs
    }
}

/// Split `ds` across `m` clients. Every client receives the same number of
/// samples (`ds.len() / m`, remainder dropped) so the FedAvg aggregation
/// coefficients are uniform, matching the paper's equal-allocation setup.
pub fn partition(ds: &Dataset, m: usize, p: Partition, seed: u64) -> Vec<ClientShard> {
    assert!(m > 0, "need at least one client");
    let per = ds.len() / m;
    assert!(per > 0, "dataset smaller than client count");
    let mut rng = Rng::new(seed ^ 0x9a_27_44_71);
    match p {
        Partition::Iid => {
            let mut idx: Vec<usize> = (0..ds.len()).collect();
            rng.shuffle(&mut idx);
            (0..m)
                .map(|c| ClientShard {
                    indices: idx[c * per..(c + 1) * per].to_vec(),
                })
                .collect()
        }
        Partition::TwoClass => {
            // Sort indices by class, cut into 2m shards, deal 2 per client.
            let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); NUM_CLASSES];
            for (i, &c) in ds.y.iter().enumerate() {
                by_class[c as usize].push(i);
            }
            // Shuffle within class for sample diversity across runs.
            for v in &mut by_class {
                rng.shuffle(v);
            }
            let sorted: Vec<usize> = by_class.into_iter().flatten().collect();
            let shard_len = per / 2;
            assert!(shard_len > 0, "need >= 2 samples per client");
            let n_shards = 2 * m;
            let mut shard_ids: Vec<usize> = (0..n_shards).collect();
            rng.shuffle(&mut shard_ids);
            (0..m)
                .map(|c| {
                    let mut indices = Vec::with_capacity(2 * shard_len);
                    for s in 0..2 {
                        let sid = shard_ids[2 * c + s];
                        let start = sid * shard_len;
                        indices.extend_from_slice(&sorted[start..start + shard_len]);
                    }
                    ClientShard { indices }
                })
                .collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{generate, SynthKind};

    fn ds() -> Dataset {
        generate(SynthKind::Mnist, 400, 10, 3).0
    }

    #[test]
    fn iid_equal_disjoint_cover() {
        let d = ds();
        let shards = partition(&d, 20, Partition::Iid, 1);
        assert_eq!(shards.len(), 20);
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        assert_eq!(all.len(), 400);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 400, "shards must be disjoint");
        assert!(shards.iter().all(|s| s.len() == 20));
    }

    #[test]
    fn iid_shards_are_class_diverse() {
        let d = ds();
        let shards = partition(&d, 10, Partition::Iid, 2);
        for s in &shards {
            assert!(s.classes(&d).len() >= 5, "IID shard with too few classes");
        }
    }

    #[test]
    fn twoclass_shards_have_at_most_two_classes() {
        let d = ds();
        let shards = partition(&d, 20, Partition::TwoClass, 3);
        for s in &shards {
            let cs = s.classes(&d);
            assert!(!cs.is_empty() && cs.len() <= 2, "{cs:?}");
        }
        // Equal sizes and disjoint.
        let mut all: Vec<usize> = shards.iter().flat_map(|s| s.indices.clone()).collect();
        let total = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total);
        assert!(shards.iter().all(|s| s.len() == shards[0].len()));
    }

    #[test]
    fn noniid_differs_from_iid() {
        let d = ds();
        let iid = partition(&d, 10, Partition::Iid, 4);
        let non = partition(&d, 10, Partition::TwoClass, 4);
        let iid_c: usize = iid.iter().map(|s| s.classes(&d).len()).sum();
        let non_c: usize = non.iter().map(|s| s.classes(&d).len()).sum();
        assert!(non_c < iid_c);
    }

    #[test]
    fn deterministic_in_seed() {
        let d = ds();
        let a = partition(&d, 8, Partition::TwoClass, 9);
        let b = partition(&d, 8, Partition::TwoClass, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.indices, y.indices);
        }
    }

    #[test]
    #[should_panic]
    fn rejects_zero_clients() {
        partition(&ds(), 0, Partition::Iid, 0);
    }
}
