//! Deterministic aggregate counters and log2-bucket histograms.
//!
//! The [`Registry`] is a pure function of the event sequence: every
//! field is updated only from the ordered decision point of an engine,
//! so a sharded run produces bit-identical aggregates to the 1-shard
//! run. The JSON form rides the *full* run record (`RunResult` /
//! `ScaleSimReport` `to_json`) and never the deterministic summary.

use crate::util::json::Json;

use super::LossCause;

/// Number of buckets in a [`Histogram`]; bucket `i` (for `i >= 1`)
/// holds values `v` with `floor(log2(v)) == i - 1`, bucket 0 holds 0.
pub const HISTOGRAM_BUCKETS: usize = 32;

/// Maximum capacity classes tracked per-class (matches the profile
/// parser's practical limit; higher classes fold into the last cell).
pub const MAX_CLASSES: usize = 16;

/// A log2-bucket histogram over `u64` samples.
///
/// Bucket 0 counts zeros; bucket `i >= 1` counts samples in
/// `[2^(i-1), 2^i)`. The top bucket saturates. Recording is two adds
/// and a `leading_zeros` — cheap enough for the per-event hot path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Histogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// An empty histogram.
    pub fn new() -> Self {
        Histogram {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum: 0,
            max: 0,
        }
    }

    /// Bucket index for a sample (0 for 0, else `floor(log2(v)) + 1`,
    /// saturating at the top bucket).
    pub fn bucket_of(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            ((64 - v.leading_zeros()) as usize).min(HISTOGRAM_BUCKETS - 1)
        }
    }

    /// Record one sample.
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        if v > self.max {
            self.max = v;
        }
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Largest sample seen (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Arithmetic mean of the samples (0.0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Bucket counts trimmed after the last non-zero cell.
    pub fn trimmed_buckets(&self) -> &[u64] {
        let last = self
            .buckets
            .iter()
            .rposition(|&c| c != 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        &self.buckets[..last]
    }

    /// JSON form: `{count, sum, max, mean, buckets}` with the bucket
    /// array trimmed after the last non-zero cell.
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("count", Json::Int(self.count as i64));
        o.set("sum", Json::Int(self.sum as i64));
        o.set("max", Json::Int(self.max as i64));
        o.set("mean", Json::Float(self.mean()));
        let buckets = self
            .trimmed_buckets()
            .iter()
            .map(|&c| Json::Int(c as i64))
            .collect();
        o.set("buckets", Json::Array(buckets));
        o
    }
}

/// Jain's fairness index over a slice of per-client counts: 1.0 for a
/// uniform allocation, `1/n` when one client takes everything. Empty
/// or all-zero slices report 1.0 (nothing was unfairly shared).
pub fn jain_fairness(counts: &[u64]) -> f64 {
    let n = counts.len();
    if n == 0 {
        return 1.0;
    }
    let sum: f64 = counts.iter().map(|&c| c as f64).sum();
    if sum == 0.0 {
        return 1.0;
    }
    let sq: f64 = counts.iter().map(|&c| (c as f64) * (c as f64)).sum();
    (sum * sum) / (n as f64 * sq)
}

/// Run-scoped deterministic aggregates, fed from the same ordered
/// decision points that emit trace events.
#[derive(Debug, Clone, PartialEq)]
pub struct Registry {
    /// Staleness (iterations behind) of every applied upload.
    pub staleness: Histogram,
    /// Scheduler queue depth observed after each grant.
    pub queue_depth: Histogram,
    /// Arena occupancy (models in flight) observed at each allocation.
    pub arena: Histogram,
    /// Uplink grants per client (Jain fairness input).
    pub grants_per_client: Vec<u64>,
    /// Grants by gain-ladder level at grant time (fading channels).
    pub grants_per_level: [u64; 4],
    /// Grants by capacity class of the winning client.
    pub grants_per_class: [u64; MAX_CLASSES],
    /// Uploads folded into the global model.
    pub uploads_applied: u64,
    /// Uploads lost to the scenario (or legacy `upload_loss`).
    pub lost_scenario: u64,
    /// Uploads lost to a channel fade.
    pub lost_channel: u64,
    /// Uploads lost to a worker disconnect (deployment path).
    pub lost_disconnect: u64,
    /// Observed gain-level changes across consecutive grants.
    pub channel_transitions: u64,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

impl Registry {
    /// An empty registry; call [`Registry::bind`] before recording.
    pub fn new() -> Self {
        Registry {
            staleness: Histogram::new(),
            queue_depth: Histogram::new(),
            arena: Histogram::new(),
            grants_per_client: Vec::new(),
            grants_per_level: [0; 4],
            grants_per_class: [0; MAX_CLASSES],
            uploads_applied: 0,
            lost_scenario: 0,
            lost_channel: 0,
            lost_disconnect: 0,
            channel_transitions: 0,
        }
    }

    /// Size the per-client table for `clients` participants.
    pub fn bind(&mut self, clients: usize) {
        self.grants_per_client = vec![0; clients];
    }

    /// Record one grant: winner, post-grant queue depth, gain level
    /// (`-1` = ideal channel) and the winner's capacity class.
    pub fn record_grant(&mut self, client: usize, queue: usize, level: i8, class: u8) {
        if client >= self.grants_per_client.len() {
            self.grants_per_client.resize(client + 1, 0);
        }
        self.grants_per_client[client] += 1;
        self.queue_depth.record(queue as u64);
        if level >= 0 {
            self.grants_per_level[(level as usize).min(3)] += 1;
        }
        self.grants_per_class[(class as usize).min(MAX_CLASSES - 1)] += 1;
    }

    /// Record one applied upload's staleness.
    pub fn record_apply(&mut self, staleness: u64) {
        self.uploads_applied += 1;
        self.staleness.record(staleness);
    }

    /// Record one lost upload by cause.
    pub fn record_lost(&mut self, cause: LossCause) {
        match cause {
            LossCause::Scenario => self.lost_scenario += 1,
            LossCause::Channel => self.lost_channel += 1,
            LossCause::Disconnect => self.lost_disconnect += 1,
        }
    }

    /// Record arena occupancy observed at an allocation.
    pub fn record_arena(&mut self, live: usize) {
        self.arena.record(live as u64);
    }

    /// Jain fairness over per-client grant counts.
    pub fn grant_fairness(&self) -> f64 {
        jain_fairness(&self.grants_per_client)
    }

    /// Full JSON form (deterministic: `Json` objects emit keys in
    /// sorted order, and every value is a pure function of the event
    /// sequence).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("uploads_applied", Json::Int(self.uploads_applied as i64));
        let mut lost = Json::object();
        lost.set("scenario", Json::Int(self.lost_scenario as i64));
        lost.set("channel", Json::Int(self.lost_channel as i64));
        lost.set("disconnect", Json::Int(self.lost_disconnect as i64));
        o.set("uploads_lost", lost);
        o.set(
            "channel_transitions",
            Json::Int(self.channel_transitions as i64),
        );
        o.set("grant_fairness", Json::Float(self.grant_fairness()));
        o.set(
            "grants_per_level",
            Json::Array(
                self.grants_per_level
                    .iter()
                    .map(|&c| Json::Int(c as i64))
                    .collect(),
            ),
        );
        let classes = self
            .grants_per_class
            .iter()
            .rposition(|&c| c != 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        o.set(
            "grants_per_class",
            Json::Array(
                self.grants_per_class[..classes]
                    .iter()
                    .map(|&c| Json::Int(c as i64))
                    .collect(),
            ),
        );
        o.set("staleness", self.staleness.to_json());
        o.set("queue_depth", self.queue_depth.to_json());
        o.set("arena", self.arena.to_json());
        o
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_follow_the_log2_rule() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(7), 3);
        assert_eq!(Histogram::bucket_of(8), 4);
        assert_eq!(Histogram::bucket_of(u64::MAX), HISTOGRAM_BUCKETS - 1);
    }

    #[test]
    fn histogram_tracks_count_sum_max_and_mean() {
        let mut h = Histogram::new();
        for v in [0, 1, 2, 3, 10] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 16);
        assert_eq!(h.max(), 10);
        assert!((h.mean() - 3.2).abs() < 1e-12);
        // 0 -> bucket 0, 1 -> 1, {2,3} -> 2, 10 -> 4.
        assert_eq!(h.trimmed_buckets(), &[1, 1, 2, 0, 1]);
    }

    #[test]
    fn histogram_json_trims_trailing_zero_buckets() {
        let mut h = Histogram::new();
        h.record(5);
        let j = h.to_json();
        let buckets = match j.get("buckets") {
            Some(Json::Array(a)) => a.len(),
            other => panic!("buckets missing: {other:?}"),
        };
        assert_eq!(buckets, 4);
    }

    #[test]
    fn jain_fairness_matches_hand_computed_cases() {
        assert!((jain_fairness(&[]) - 1.0).abs() < 1e-12);
        assert!((jain_fairness(&[0, 0]) - 1.0).abs() < 1e-12);
        assert!((jain_fairness(&[5, 5, 5]) - 1.0).abs() < 1e-12);
        // One of four takes everything: 1/4.
        assert!((jain_fairness(&[8, 0, 0, 0]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn registry_counts_grants_losses_and_applies() {
        let mut r = Registry::new();
        r.bind(4);
        r.record_grant(0, 3, 2, 0);
        r.record_grant(1, 2, -1, 1);
        r.record_apply(5);
        r.record_lost(LossCause::Scenario);
        r.record_lost(LossCause::Channel);
        assert_eq!(r.grants_per_client, vec![1, 1, 0, 0]);
        assert_eq!(r.grants_per_level, [0, 0, 1, 0]);
        assert_eq!(r.grants_per_class[0], 1);
        assert_eq!(r.grants_per_class[1], 1);
        assert_eq!(r.uploads_applied, 1);
        assert_eq!(r.lost_scenario, 1);
        assert_eq!(r.lost_channel, 1);
        assert_eq!(r.staleness.max(), 5);
        assert_eq!(r.queue_depth.count(), 2);
    }

    #[test]
    fn registry_json_carries_the_contract_keys() {
        let mut r = Registry::new();
        r.bind(2);
        r.record_grant(0, 1, 0, 0);
        r.record_apply(3);
        let j = r.to_json();
        for key in [
            "uploads_applied",
            "uploads_lost",
            "channel_transitions",
            "grant_fairness",
            "grants_per_level",
            "grants_per_class",
            "staleness",
            "queue_depth",
            "arena",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
