//! Live deployment stats: lock-free counters shared by the leader's
//! threads, rendered as a Prometheus text-format snapshot over a
//! hand-rolled TCP endpoint (`repro serve --stats-addr <addr>`).
//!
//! Nothing here touches the deterministic path: every counter is a
//! relaxed atomic observed only by the stats endpoint and the periodic
//! stderr digest, so scrape timing can never perturb aggregation order.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

/// Shared live counters for one `repro serve` run. All loads/stores
/// are `Relaxed`: the values are monitoring snapshots, not
/// synchronization.
#[derive(Debug)]
pub struct LiveStats {
    /// Frames ingested per net shard (indexed by shard id).
    ingest_frames: Vec<AtomicU64>,
    /// Inbound records currently queued between ingest and aggregation.
    queue_depth: AtomicU64,
    /// Worker rejoin events observed by the aggregation stage.
    reconnects: AtomicU64,
    /// Payload bytes carried by accepted update frames.
    bytes_on_wire: AtomicU64,
    /// Uploads folded into the global model.
    aggregations: AtomicU64,
    /// Uploads lost to disconnects/timeouts.
    lost_uploads: AtomicU64,
}

impl LiveStats {
    /// Counters for a leader with `shards` ingest shards.
    pub fn new(shards: usize) -> LiveStats {
        LiveStats {
            ingest_frames: (0..shards.max(1)).map(|_| AtomicU64::new(0)).collect(),
            queue_depth: AtomicU64::new(0),
            reconnects: AtomicU64::new(0),
            bytes_on_wire: AtomicU64::new(0),
            aggregations: AtomicU64::new(0),
            lost_uploads: AtomicU64::new(0),
        }
    }

    /// Count one ingested frame on `shard`.
    pub fn frame_ingested(&self, shard: usize) {
        if let Some(c) = self.ingest_frames.get(shard) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// A record entered the ingest→aggregation queue.
    pub fn queue_push(&self) {
        self.queue_depth.fetch_add(1, Ordering::Relaxed);
    }

    /// A record left the ingest→aggregation queue.
    pub fn queue_pop(&self) {
        // Saturate at zero: pops can race ahead of the matching push
        // observation, and a monitoring gauge must never wrap.
        let _ = self
            .queue_depth
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| d.checked_sub(1));
    }

    /// A worker rejoined after a dropped connection.
    pub fn reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` payload bytes from an accepted update frame.
    pub fn wire_bytes(&self, n: u64) {
        self.bytes_on_wire.fetch_add(n, Ordering::Relaxed);
    }

    /// One upload was folded into the global model.
    pub fn aggregated(&self) {
        self.aggregations.fetch_add(1, Ordering::Relaxed);
    }

    /// One upload was lost to a disconnect/timeout.
    pub fn upload_lost(&self) {
        self.lost_uploads.fetch_add(1, Ordering::Relaxed);
    }

    /// Render the Prometheus text-format snapshot.
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        out.push_str("# TYPE repro_ingest_frames_total counter\n");
        for (k, c) in self.ingest_frames.iter().enumerate() {
            out.push_str(&format!(
                "repro_ingest_frames_total{{shard=\"{k}\"}} {}\n",
                c.load(Ordering::Relaxed)
            ));
        }
        out.push_str("# TYPE repro_queue_depth gauge\n");
        out.push_str(&format!(
            "repro_queue_depth {}\n",
            self.queue_depth.load(Ordering::Relaxed)
        ));
        out.push_str("# TYPE repro_reconnects_total counter\n");
        out.push_str(&format!(
            "repro_reconnects_total {}\n",
            self.reconnects.load(Ordering::Relaxed)
        ));
        out.push_str("# TYPE repro_bytes_on_wire_total counter\n");
        out.push_str(&format!(
            "repro_bytes_on_wire_total {}\n",
            self.bytes_on_wire.load(Ordering::Relaxed)
        ));
        out.push_str("# TYPE repro_aggregations_total counter\n");
        out.push_str(&format!(
            "repro_aggregations_total {}\n",
            self.aggregations.load(Ordering::Relaxed)
        ));
        out.push_str("# TYPE repro_lost_uploads_total counter\n");
        out.push_str(&format!(
            "repro_lost_uploads_total {}\n",
            self.lost_uploads.load(Ordering::Relaxed)
        ));
        out
    }

    /// One-line digest for the periodic stderr heartbeat.
    pub fn digest_line(&self) -> String {
        let frames: u64 = self
            .ingest_frames
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum();
        format!(
            "stats: frames={frames} queue={} aggs={} lost={} reconnects={} wire_bytes={}",
            self.queue_depth.load(Ordering::Relaxed),
            self.aggregations.load(Ordering::Relaxed),
            self.lost_uploads.load(Ordering::Relaxed),
            self.reconnects.load(Ordering::Relaxed),
            self.bytes_on_wire.load(Ordering::Relaxed),
        )
    }
}

/// Serve Prometheus snapshots on `listener` until `done` flips.
///
/// Hand-rolled like the wire layer: each accepted connection gets one
/// minimal HTTP/1.1 response and is closed. The listener is switched
/// to non-blocking so the loop can observe `done` and return, letting
/// the caller's `thread::scope` join.
pub fn serve_stats(listener: TcpListener, stats: &LiveStats, done: &AtomicBool) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    while !done.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((mut conn, _)) => {
                // Drain whatever request line arrived (best-effort; a
                // scraper that writes nothing still gets the snapshot).
                let _ = conn.set_nonblocking(false);
                let _ = conn.set_read_timeout(Some(Duration::from_millis(200)));
                let mut scratch = [0u8; 1024];
                let _ = conn.read(&mut scratch);
                let body = stats.render_prometheus();
                let resp = format!(
                    "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
                    body.len()
                );
                let _ = conn.write_all(resp.as_bytes());
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(20));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpStream;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn counters_land_in_the_prometheus_snapshot() {
        let s = LiveStats::new(2);
        s.frame_ingested(0);
        s.frame_ingested(1);
        s.frame_ingested(1);
        s.queue_push();
        s.reconnect();
        s.wire_bytes(128);
        s.aggregated();
        s.upload_lost();
        let text = s.render_prometheus();
        assert!(text.contains("repro_ingest_frames_total{shard=\"0\"} 1\n"));
        assert!(text.contains("repro_ingest_frames_total{shard=\"1\"} 2\n"));
        assert!(text.contains("repro_queue_depth 1\n"));
        assert!(text.contains("repro_reconnects_total 1\n"));
        assert!(text.contains("repro_bytes_on_wire_total 128\n"));
        assert!(text.contains("repro_aggregations_total 1\n"));
        assert!(text.contains("repro_lost_uploads_total 1\n"));
    }

    #[test]
    fn queue_depth_saturates_at_zero() {
        let s = LiveStats::new(1);
        s.queue_pop();
        s.queue_push();
        s.queue_pop();
        s.queue_pop();
        assert!(s.render_prometheus().contains("repro_queue_depth 0\n"));
    }

    #[test]
    fn digest_line_summarizes_all_counters() {
        let s = LiveStats::new(3);
        s.frame_ingested(2);
        s.aggregated();
        let line = s.digest_line();
        assert!(line.contains("frames=1"));
        assert!(line.contains("aggs=1"));
    }

    #[test]
    fn stats_endpoint_answers_one_scrape_and_stops_on_done() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stats = LiveStats::new(1);
        stats.aggregated();
        let done = AtomicBool::new(false);
        std::thread::scope(|scope| {
            scope.spawn(|| serve_stats(listener, &stats, &done));
            let mut conn = TcpStream::connect(addr).unwrap();
            conn.write_all(b"GET /metrics HTTP/1.1\r\n\r\n").unwrap();
            let mut text = String::new();
            conn.read_to_string(&mut text).unwrap();
            assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
            assert!(text.contains("repro_aggregations_total 1"), "{text}");
            done.store(true, Ordering::Relaxed);
        });
    }
}
