//! The typed trace-event vocabulary and its JSONL encoding.
//!
//! Every event is a scalar-only enum variant — no owned strings, no
//! heap — so constructing one is free and encoding is a pure formatting
//! pass into a caller-owned scratch buffer. The wire form is one
//! compact JSON object per line with a fixed, hand-written key order
//! (`ev` first), so byte-identity of two traces is exactly
//! event-sequence identity: the shard-invariance contract of
//! `rust/tests/sharded.rs` compares traces with `assert_eq!` on bytes.
//!
//! Floats (`beta`, `weight`) are encoded with Rust's default `Display`
//! (shortest round-trip form) — deterministic across runs and shard
//! counts because the values themselves are, by the engines' contract.

use std::fmt::Write;

/// Why an upload that occupied its TDMA slot never reached the global
/// model. The priority when multiple draws fire on one upload is
/// scenario first, then channel — the same order the engines draw them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LossCause {
    /// Scenario transit loss (`dropout`) or the legacy `upload_loss`
    /// knob of the learner-driven engine.
    Scenario,
    /// Channel fade (`sim::channel` correlated per-level loss).
    Channel,
    /// Deployment-path loss: a worker connection died or timed out
    /// mid-upload (`net::leader`).
    Disconnect,
}

impl LossCause {
    /// Canonical spelling used in the trace `cause` field.
    pub fn name(self) -> &'static str {
        match self {
            LossCause::Scenario => "scenario",
            LossCause::Channel => "channel",
            LossCause::Disconnect => "disconnect",
        }
    }
}

/// One ordered decision of an AFL engine (or the TCP leader's
/// aggregation stage), in the order the coordinator made it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// Setup-time capacity-class assignment (one per client, emitted
    /// only under a non-trivial capacity profile).
    ClassAssign {
        /// Client id.
        client: usize,
        /// Capacity-class index (profile order).
        class: u8,
    },
    /// The client's observed gain level changed since its last grant
    /// (fading channels only; the first grant records the entry level).
    ChannelTransition {
        /// Virtual time of the observing grant.
        t: u64,
        /// Client id.
        client: usize,
        /// New gain-ladder level (`sim::channel::GAIN_LADDER` index).
        level: u8,
    },
    /// The scheduler granted the uplink slot to a client.
    Grant {
        /// Virtual time of the grant.
        t: u64,
        /// Winning client.
        client: usize,
        /// Requests still pending after this grant (queue depth).
        queue: usize,
        /// Winner's gain-ladder level at grant time; `-1` under the
        /// ideal channel.
        level: i8,
    },
    /// An upload survived and was folded into the global model.
    UploadApplied {
        /// Virtual time of the aggregation.
        t: u64,
        /// Uploading client.
        client: usize,
        /// Global iteration after the aggregation.
        iteration: u64,
        /// Staleness of the uploaded model (iterations behind).
        staleness: u64,
        /// Eq.-(3) retention coefficient the policy chose.
        beta: f32,
        /// Raw policy weight before clamping to β.
        weight: f64,
    },
    /// An upload occupied its slot but was lost before aggregation.
    UploadLost {
        /// Virtual time of the loss.
        t: u64,
        /// Uploading client.
        client: usize,
        /// What lost it.
        cause: LossCause,
    },
    /// The arena's in-flight local-model count reached a new high.
    ArenaHighWater {
        /// Virtual time of the allocation.
        t: u64,
        /// The new high-water mark (slots in flight).
        high: usize,
    },
}

impl TraceEvent {
    /// Append the one-line JSON form (no trailing newline) to `out`.
    pub fn encode_into(&self, out: &mut String) {
        // Writing to a String is infallible; unwrap is fine.
        match *self {
            TraceEvent::ClassAssign { client, class } => {
                write!(out, r#"{{"ev":"class","client":{client},"class":{class}}}"#)
            }
            TraceEvent::ChannelTransition { t, client, level } => {
                write!(
                    out,
                    r#"{{"ev":"channel","t":{t},"client":{client},"level":{level}}}"#
                )
            }
            TraceEvent::Grant {
                t,
                client,
                queue,
                level,
            } => {
                write!(
                    out,
                    r#"{{"ev":"grant","t":{t},"client":{client},"queue":{queue},"level":{level}}}"#
                )
            }
            TraceEvent::UploadApplied {
                t,
                client,
                iteration,
                staleness,
                beta,
                weight,
            } => {
                write!(
                    out,
                    r#"{{"ev":"apply","t":{t},"client":{client},"iter":{iteration},"stale":{staleness},"beta":{beta},"weight":{weight}}}"#
                )
            }
            TraceEvent::UploadLost { t, client, cause } => {
                write!(
                    out,
                    r#"{{"ev":"lost","t":{t},"client":{client},"cause":"{}"}}"#,
                    cause.name()
                )
            }
            TraceEvent::ArenaHighWater { t, high } => {
                write!(out, r#"{{"ev":"arena","t":{t},"high":{high}}}"#)
            }
        }
        .expect("writing to a String cannot fail");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn encoded(ev: &TraceEvent) -> String {
        let mut s = String::new();
        ev.encode_into(&mut s);
        s
    }

    #[test]
    fn every_variant_encodes_as_one_compact_json_object() {
        let cases = [
            (
                TraceEvent::ClassAssign { client: 3, class: 1 },
                r#"{"ev":"class","client":3,"class":1}"#,
            ),
            (
                TraceEvent::ChannelTransition {
                    t: 120,
                    client: 3,
                    level: 2,
                },
                r#"{"ev":"channel","t":120,"client":3,"level":2}"#,
            ),
            (
                TraceEvent::Grant {
                    t: 120,
                    client: 3,
                    queue: 5,
                    level: -1,
                },
                r#"{"ev":"grant","t":120,"client":3,"queue":5,"level":-1}"#,
            ),
            (
                TraceEvent::UploadLost {
                    t: 150,
                    client: 3,
                    cause: LossCause::Channel,
                },
                r#"{"ev":"lost","t":150,"client":3,"cause":"channel"}"#,
            ),
            (
                TraceEvent::ArenaHighWater { t: 100, high: 42 },
                r#"{"ev":"arena","t":100,"high":42}"#,
            ),
        ];
        for (ev, want) in cases {
            assert_eq!(encoded(&ev), want);
        }
    }

    #[test]
    fn apply_event_floats_use_shortest_display_form() {
        let ev = TraceEvent::UploadApplied {
            t: 150,
            client: 3,
            iteration: 7,
            staleness: 2,
            beta: 0.8,
            weight: 1.0,
        };
        assert_eq!(
            encoded(&ev),
            r#"{"ev":"apply","t":150,"client":3,"iter":7,"stale":2,"beta":0.8,"weight":1}"#
        );
    }

    #[test]
    fn every_encoded_line_parses_as_json() {
        let events = [
            TraceEvent::ClassAssign { client: 0, class: 0 },
            TraceEvent::Grant {
                t: 1,
                client: 2,
                queue: 3,
                level: 2,
            },
            TraceEvent::UploadApplied {
                t: 9,
                client: 1,
                iteration: 4,
                staleness: 0,
                beta: 0.123,
                weight: 0.456,
            },
            TraceEvent::UploadLost {
                t: 9,
                client: 1,
                cause: LossCause::Scenario,
            },
        ];
        for ev in events {
            let line = encoded(&ev);
            let j = crate::util::json::parse(&line).unwrap_or_else(|e| panic!("{line}: {e}"));
            assert!(j.get("ev").is_some(), "{line}");
        }
    }

    #[test]
    fn loss_causes_spell_their_trace_names() {
        assert_eq!(LossCause::Scenario.name(), "scenario");
        assert_eq!(LossCause::Channel.name(), "channel");
        assert_eq!(LossCause::Disconnect.name(), "disconnect");
    }
}
