//! Run-scoped deterministic telemetry: ordered trace events,
//! aggregate histograms, and live deployment stats.
//!
//! Three faces, all dependency-free:
//!
//! 1. **Ordered trace events** ([`TraceEvent`]) — emitted from the
//!    single ordered decision point of every AFL engine and from the
//!    TCP leader's aggregation stage, encoded as one compact JSON
//!    object per line. Because all emission happens on the coordinator
//!    thread in exact event order, the trace of a `--shards N` run is
//!    byte-identical to `--shards 1` (asserted in
//!    `rust/tests/sharded.rs`).
//! 2. **Deterministic aggregates** ([`Registry`]) — counters and
//!    log2-bucket [`Histogram`]s (staleness, queue depth, arena
//!    occupancy, per-client/level/class grants) riding the *full* run
//!    record only, never the deterministic summary.
//! 3. **Live deployment stats** ([`LiveStats`]) — relaxed atomics
//!    rendered as a Prometheus text snapshot by
//!    `repro serve --stats-addr`.
//!
//! The [`Telemetry`] handle is the engine-facing API. When built with
//! [`Telemetry::off`] every method is a single load-and-branch with
//! zero allocation — the `telemetry` bench suite's `noop_sink` case
//! pins that down under the perf gate.

mod event;
mod live;
mod registry;

pub use event::{LossCause, TraceEvent};
pub use live::{serve_stats, LiveStats};
pub use registry::{jain_fairness, Histogram, Registry, HISTOGRAM_BUCKETS, MAX_CLASSES};

use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Where encoded trace lines go.
enum Sink {
    /// Tracing disabled: no bytes retained, no allocation.
    Off,
    /// In-memory buffer (tests compare these byte-for-byte).
    Buf(Vec<u8>),
    /// Buffered file writer (`--trace <path>`).
    File(BufWriter<File>),
}

/// The engine-facing telemetry handle: owns the trace sink, the
/// aggregate [`Registry`], and the small per-client state needed to
/// detect channel transitions and arena high-water marks.
///
/// Every recording method early-returns when tracing is disabled, so
/// an engine can call them unconditionally on its hot path.
pub struct Telemetry {
    enabled: bool,
    sink: Sink,
    reg: Registry,
    line: String,
    last_level: Vec<i8>,
    class_of: Vec<u8>,
    arena_live: usize,
    arena_high: usize,
    io_error: Option<io::Error>,
}

impl Telemetry {
    fn with_sink(enabled: bool, sink: Sink) -> Telemetry {
        Telemetry {
            enabled,
            sink,
            reg: Registry::new(),
            line: String::new(),
            last_level: Vec::new(),
            class_of: Vec::new(),
            arena_live: 0,
            arena_high: 0,
            io_error: None,
        }
    }

    /// A disabled handle: every method is a no-op after one branch.
    pub fn off() -> Telemetry {
        Telemetry::with_sink(false, Sink::Off)
    }

    /// An enabled handle writing to an in-memory buffer (take it with
    /// [`Telemetry::take_buffer`]).
    pub fn buffered() -> Telemetry {
        Telemetry::with_sink(true, Sink::Buf(Vec::new()))
    }

    /// An enabled handle writing JSONL to `path`.
    pub fn to_file(path: &Path) -> Result<Telemetry> {
        let f = File::create(path)
            .with_context(|| format!("creating trace file {}", path.display()))?;
        Ok(Telemetry::with_sink(true, Sink::File(BufWriter::new(f))))
    }

    /// Whether this handle records anything.
    pub fn is_enabled(&self) -> bool {
        self.enabled
    }

    /// Pre-size per-client tables for `clients` participants. Call
    /// once at engine setup so the hot path never reallocates.
    pub fn bind(&mut self, clients: usize) {
        if !self.enabled {
            return;
        }
        self.reg.bind(clients);
        self.last_level = vec![i8::MIN; clients];
        self.class_of = vec![0; clients];
    }

    /// Record a setup-time capacity-class assignment.
    pub fn class_assign(&mut self, client: usize, class: u8) {
        if !self.enabled {
            return;
        }
        if let Some(c) = self.class_of.get_mut(client) {
            *c = class;
        }
        self.emit(&TraceEvent::ClassAssign { client, class });
    }

    /// Record a grant: winner, post-grant queue depth and gain level
    /// (`-1` under the ideal channel). Emits a [`TraceEvent::
    /// ChannelTransition`] first when the winner's level changed since
    /// its previous grant.
    pub fn grant(&mut self, t: u64, client: usize, queue: usize, level: i8) {
        if !self.enabled {
            return;
        }
        if level >= 0 && self.last_level.get(client).copied() != Some(level) {
            if let Some(l) = self.last_level.get_mut(client) {
                *l = level;
            }
            self.reg.channel_transitions += 1;
            self.emit(&TraceEvent::ChannelTransition {
                t,
                client,
                level: level as u8,
            });
        }
        let class = self.class_of.get(client).copied().unwrap_or(0);
        self.reg.record_grant(client, queue, level, class);
        self.emit(&TraceEvent::Grant {
            t,
            client,
            queue,
            level,
        });
    }

    /// Record an aggregated upload (the engine forwards the
    /// `AggregationOutcome` fields).
    pub fn upload_applied(
        &mut self,
        t: u64,
        client: usize,
        iteration: u64,
        staleness: u64,
        beta: f32,
        weight: f64,
    ) {
        if !self.enabled {
            return;
        }
        self.reg.record_apply(staleness);
        self.emit(&TraceEvent::UploadApplied {
            t,
            client,
            iteration,
            staleness,
            beta,
            weight,
        });
    }

    /// Record a lost upload with its cause.
    pub fn upload_lost(&mut self, t: u64, client: usize, cause: LossCause) {
        if !self.enabled {
            return;
        }
        self.reg.record_lost(cause);
        self.emit(&TraceEvent::UploadLost { t, client, cause });
    }

    /// Record an arena slot allocation; emits [`TraceEvent::
    /// ArenaHighWater`] when the in-flight count reaches a new high.
    pub fn arena_alloc(&mut self, t: u64) {
        if !self.enabled {
            return;
        }
        self.arena_live += 1;
        self.reg.record_arena(self.arena_live);
        if self.arena_live > self.arena_high {
            self.arena_high = self.arena_live;
            self.emit(&TraceEvent::ArenaHighWater {
                t,
                high: self.arena_high,
            });
        }
    }

    /// Record an arena slot release.
    pub fn arena_free(&mut self) {
        if !self.enabled {
            return;
        }
        self.arena_live = self.arena_live.saturating_sub(1);
    }

    /// The aggregate registry (always available; empty when disabled).
    pub fn registry(&self) -> &Registry {
        &self.reg
    }

    /// The registry's JSON form — `Some` only when telemetry was
    /// enabled, so untraced runs emit byte-identical records to
    /// pre-telemetry builds.
    pub fn registry_json(&self) -> Option<Json> {
        if self.enabled {
            Some(self.reg.to_json())
        } else {
            None
        }
    }

    /// Take the in-memory trace bytes (empty for non-buffer sinks).
    pub fn take_buffer(&mut self) -> Vec<u8> {
        match &mut self.sink {
            Sink::Buf(b) => std::mem::take(b),
            _ => Vec::new(),
        }
    }

    /// Flush the sink and surface any write error swallowed on the
    /// hot path. Call once after the run.
    pub fn finish(&mut self) -> Result<()> {
        if let Some(e) = self.io_error.take() {
            return Err(e).context("writing trace");
        }
        if let Sink::File(w) = &mut self.sink {
            w.flush().context("flushing trace file")?;
        }
        Ok(())
    }

    fn emit(&mut self, ev: &TraceEvent) {
        self.line.clear();
        ev.encode_into(&mut self.line);
        self.line.push('\n');
        match &mut self.sink {
            Sink::Off => {}
            Sink::Buf(b) => b.extend_from_slice(self.line.as_bytes()),
            Sink::File(w) => {
                if self.io_error.is_none() {
                    if let Err(e) = w.write_all(self.line.as_bytes()) {
                        self.io_error = Some(e);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn off_handle_records_nothing_and_reports_no_registry() {
        let mut tel = Telemetry::off();
        tel.bind(4);
        tel.grant(1, 0, 2, 1);
        tel.upload_applied(2, 0, 1, 0, 0.5, 0.5);
        tel.upload_lost(3, 1, LossCause::Channel);
        tel.arena_alloc(1);
        assert!(!tel.is_enabled());
        assert!(tel.registry_json().is_none());
        assert_eq!(tel.registry().uploads_applied, 0);
        assert!(tel.take_buffer().is_empty());
        assert!(tel.finish().is_ok());
    }

    #[test]
    fn buffered_handle_emits_ordered_jsonl() {
        let mut tel = Telemetry::buffered();
        tel.bind(2);
        tel.grant(10, 0, 1, -1);
        tel.upload_applied(20, 0, 1, 0, 0.8, 1.0);
        let text = String::from_utf8(tel.take_buffer()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains(r#""ev":"grant""#));
        assert!(lines[1].contains(r#""ev":"apply""#));
        assert!(tel.registry_json().is_some());
    }

    #[test]
    fn channel_transitions_fire_only_on_level_change() {
        let mut tel = Telemetry::buffered();
        tel.bind(2);
        tel.grant(1, 0, 0, 2);
        tel.grant(2, 0, 0, 2);
        tel.grant(3, 0, 0, 1);
        tel.grant(4, 1, 0, 2);
        let text = String::from_utf8(tel.take_buffer()).unwrap();
        let transitions = text
            .lines()
            .filter(|l| l.contains(r#""ev":"channel""#))
            .count();
        // Client 0: entry + one change; client 1: entry.
        assert_eq!(transitions, 3);
        assert_eq!(tel.registry().channel_transitions, 3);
    }

    #[test]
    fn arena_high_water_emits_once_per_new_peak() {
        let mut tel = Telemetry::buffered();
        tel.bind(4);
        tel.arena_alloc(1); // high 1
        tel.arena_alloc(2); // high 2
        tel.arena_free();
        tel.arena_alloc(3); // back to 2, no event
        tel.arena_alloc(4); // high 3
        let text = String::from_utf8(tel.take_buffer()).unwrap();
        let highs: Vec<&str> = text
            .lines()
            .filter(|l| l.contains(r#""ev":"arena""#))
            .collect();
        assert_eq!(highs.len(), 3);
        assert!(highs[2].contains(r#""high":3"#));
        assert_eq!(tel.registry().arena.count(), 4);
    }

    #[test]
    fn class_assignments_feed_per_class_grant_counts() {
        let mut tel = Telemetry::buffered();
        tel.bind(2);
        tel.class_assign(0, 1);
        tel.grant(1, 0, 0, -1);
        assert_eq!(tel.registry().grants_per_class[1], 1);
    }

    #[test]
    fn file_sink_writes_and_finishes_cleanly() {
        let dir = std::env::temp_dir().join("csmaafl_tel_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        let mut tel = Telemetry::to_file(&path).unwrap();
        tel.bind(1);
        tel.grant(1, 0, 0, -1);
        tel.finish().unwrap();
        drop(tel);
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains(r#""ev":"grant""#));
        let _ = std::fs::remove_file(&path);
    }
}
