//! Figure regeneration harness: one function per paper figure.
//!
//! Each generator builds a paired [`Session`] and emits a long-format CSV
//! under `out_dir` (`series,slot,ticks,iteration,accuracy,loss`) plus a
//! JSON run record. The series match the paper's legends: FedAvg vs
//! CSMAAFL with γ ∈ {0.1, 0.2, 0.4, 0.6}.

use anyhow::{bail, Result};

use crate::config::RunConfig;
use crate::data::{Partition, SynthKind};
use crate::experiment::{Plan, PlanRunner};
use crate::log_info;
use crate::metrics::{write_series_csv, RunResult};
use crate::session::{LearnerKind, Session};
use crate::sim::TimeModel;
use crate::util::json::Json;

/// The γ sweep of Sec. IV.
pub const GAMMAS: [f64; 4] = [0.1, 0.2, 0.4, 0.6];

/// Scenario descriptor for Figs. 3, 4, 5(a), 5(b).
#[derive(Debug, Clone, Copy)]
pub struct FigureSpec {
    /// Figure id (`fig3`, `fig4`, `fig5a`, `fig5b`).
    pub id: &'static str,
    /// Human-readable scenario title.
    pub title: &'static str,
    /// Synthetic dataset of the scenario.
    pub dataset: SynthKind,
    /// Client data partition of the scenario.
    pub partition: Partition,
    /// Artifact model config used on the PJRT path.
    pub model_config: &'static str,
}

/// The paper's four accuracy-vs-time scenarios (Figs. 3, 4, 5a, 5b).
pub const FIGURES: [FigureSpec; 4] = [
    FigureSpec {
        id: "fig3",
        title: "Scenario 1: MNIST IID",
        dataset: SynthKind::Mnist,
        partition: Partition::Iid,
        model_config: "mnist_small",
    },
    FigureSpec {
        id: "fig4",
        title: "Scenario 2: MNIST non-IID",
        dataset: SynthKind::Mnist,
        partition: Partition::TwoClass,
        model_config: "mnist_small",
    },
    FigureSpec {
        id: "fig5a",
        title: "Fashion-MNIST IID",
        dataset: SynthKind::Fashion,
        partition: Partition::Iid,
        model_config: "fashion_small",
    },
    FigureSpec {
        id: "fig5b",
        title: "Fashion-MNIST non-IID",
        dataset: SynthKind::Fashion,
        partition: Partition::TwoClass,
        model_config: "fashion_small",
    },
];

/// Look up a figure spec by id.
pub fn figure_spec(id: &str) -> Option<&'static FigureSpec> {
    FIGURES.iter().find(|f| f.id == id)
}

/// The figure's series as an experiment plan: FedAvg plus the CSMAAFL
/// γ sweep, every row pinned to `aggregation=auto` / `scenario=static`
/// so base-config overrides can't leak into the paper's legend.
pub fn figure_plan() -> Plan {
    let mut plan = Plan::new().job([
        ("algorithm", "fedavg"),
        ("aggregation", "auto"),
        ("scenario", "static"),
    ]);
    for gamma in GAMMAS {
        plan = plan.job([
            ("algorithm".to_string(), "csmaafl".to_string()),
            ("aggregation".to_string(), "auto".to_string()),
            ("scenario".to_string(), "static".to_string()),
            ("gamma".to_string(), format!("{gamma}")),
        ]);
    }
    plan
}

/// Run one accuracy-vs-time figure: FedAvg + CSMAAFL γ sweep, executed
/// through the plan runner on `jobs` worker threads (0 = auto). The
/// emitted series are byte-identical at any thread count.
pub fn generate_figure(
    spec: &FigureSpec,
    base: &RunConfig,
    learner: LearnerKind,
    artifacts_dir: &str,
    out_dir: &str,
    jobs: usize,
) -> Result<Vec<RunResult>> {
    let mut cfg = base.clone();
    cfg.dataset = spec.dataset;
    cfg.partition = spec.partition;
    cfg.model_config = spec.model_config.to_string();
    // The figure rows pin algorithm/aggregation/scenario themselves;
    // clear base overrides so the base config validates for every row.
    cfg.aggregation = None;
    cfg.scenario = None;

    log_info!("=== {} ({}) ===", spec.id, spec.title);
    let session = Session::new(cfg, learner, artifacts_dir)?;
    let runs = PlanRunner::new(&session).jobs(jobs).run(&figure_plan())?;

    std::fs::create_dir_all(out_dir)?;
    let csv_path = format!("{out_dir}/{}.csv", spec.id);
    write_series_csv(&csv_path, &runs.iter().collect::<Vec<_>>())?;
    let mut record = Json::object();
    record
        .set("figure", Json::Str(spec.id.into()))
        .set("title", Json::Str(spec.title.into()))
        .set(
            "runs",
            Json::Array(runs.iter().map(|r| r.to_json()).collect()),
        );
    std::fs::write(
        format!("{out_dir}/{}.json", spec.id),
        record.to_string_pretty(),
    )?;
    log_info!("{}: wrote {csv_path}", spec.id);
    Ok(runs)
}

/// E-FIG2: the Sec. II-C time comparison. Emits a CSV of global-model
/// update times for SFL vs AFL under homogeneous and heterogeneous
/// settings, plus the analytic formula values.
pub fn generate_timeline(
    clients: usize,
    local_steps: usize,
    time: TimeModel,
    slow_factor: f64,
    out_dir: &str,
) -> Result<String> {
    if clients == 0 {
        bail!("clients must be > 0");
    }
    let m = clients as u64;
    let mut rows = String::from("mode,scenario,metric,value_ticks\n");
    // Analytic values (the formulas verified in sim::time_model tests).
    let sfl_ho = time.sfl_round_homogeneous(clients, local_steps);
    let sfl_he = time.sfl_round_heterogeneous(clients, local_steps, slow_factor);
    let afl_ho = time.afl_sweep_homogeneous(clients, local_steps);
    let afl_gap = time.afl_update_interval();
    rows.push_str(&format!("sfl,homogeneous,round_time,{sfl_ho}\n"));
    rows.push_str(&format!("sfl,heterogeneous,round_time,{sfl_he}\n"));
    rows.push_str(&format!("afl,homogeneous,full_sweep,{afl_ho}\n"));
    rows.push_str(&format!("afl,any,update_interval,{afl_gap}\n"));
    rows.push_str(&format!(
        "afl,homogeneous,extra_vs_sfl,{}\n",
        (m - 1) * time.tau_down
    ));
    // Update-frequency comparison over one SFL round horizon.
    let updates_sfl = 1u64;
    let updates_afl = sfl_ho / afl_gap.max(1);
    rows.push_str(&format!("sfl,homogeneous,updates_per_round,{updates_sfl}\n"));
    rows.push_str(&format!("afl,homogeneous,updates_per_round,{updates_afl}\n"));

    std::fs::create_dir_all(out_dir)?;
    let path = format!("{out_dir}/fig2_timeline.csv");
    std::fs::write(&path, &rows)?;
    Ok(path)
}

/// E-NAIVE: the Sec. III-A coefficient-decay table.
pub fn naive_decay_table(clients: usize) -> String {
    let alpha = vec![1.0 / clients as f64; clients];
    let coeff = crate::coordinator::naive_effective_coefficients(&alpha);
    let mut out = String::from("schedule_position,effective_coefficient\n");
    for (t, c) in coeff.iter().enumerate() {
        out.push_str(&format!("{},{:e}\n", t + 1, c));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_specs_resolve() {
        assert!(figure_spec("fig3").is_some());
        assert!(figure_spec("fig5b").is_some());
        assert!(figure_spec("fig9").is_none());
    }

    #[test]
    fn timeline_csv_written() {
        let dir = std::env::temp_dir().join(format!("csmaafl_tl_{}", std::process::id()));
        let path = generate_timeline(
            20,
            16,
            TimeModel::default(),
            4.0,
            dir.to_str().unwrap(),
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("sfl,homogeneous,round_time,2210"));
        assert!(text.contains("afl,any,update_interval,150"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn naive_decay_has_all_rows() {
        let t = naive_decay_table(10);
        assert_eq!(t.lines().count(), 11);
        assert!(t.lines().nth(1).unwrap().starts_with("1,"));
    }
}
