//! Self-contained utility substrates (offline build: no serde/tokio/clap).

pub mod bench;
pub mod json;
pub mod logging;
pub mod rng;
pub mod spec;
