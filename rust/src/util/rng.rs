//! Deterministic PRNG for the simulator and data generators.
//!
//! SplitMix64 seeding into xoshiro256++ — fast, well-distributed, and fully
//! reproducible across platforms. Every stochastic component of the
//! framework (data synthesis, client heterogeneity, scheduling jitter)
//! derives its stream from a run-level seed via `Rng::fork`, so paired
//! SFL/AFL comparisons see identical workloads.

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a u64; never produces the all-zero state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent child stream (stable: depends only on the
    /// parent seed path and `label`, not on how much the parent was used).
    pub fn fork(&self, label: u64) -> Rng {
        // Mix the label through splitmix on top of the parent's seed state.
        let mut sm = self
            .s[0]
            .wrapping_mul(0xA24BAED4963EE407)
            .wrapping_add(label.wrapping_mul(0x9FB21C651E98DF25));
        Rng::new(splitmix64(&mut sm))
    }

    /// The next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Log-normal with underlying N(mu, sigma^2).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal() as f64).exp()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(8);
        assert_ne!(Rng::new(7).next_u64(), c.next_u64());
    }

    #[test]
    fn fork_is_stable_and_independent() {
        let parent = Rng::new(42);
        let mut f1 = parent.fork(1);
        let mut f1_again = parent.fork(1);
        let mut f2 = parent.fork(2);
        assert_eq!(f1.next_u64(), f1_again.next_u64());
        assert_ne!(parent.fork(1).next_u64(), f2.next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(2);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let (mut sum, mut sumsq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let v = r.normal() as f64;
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "extremely unlikely");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(5);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(6);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }
}
