//! Leveled stderr logger with per-run verbosity (no external crates).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

/// Log severity, ordered most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// Run-level progress (the default).
    Info = 2,
    /// Per-iteration detail (`-v`).
    Debug = 3,
    /// Everything.
    Trace = 4,
}

impl Level {
    /// Parse a CLI/env spelling (`error|warn|info|debug|trace`,
    /// case-insensitive). Returns `None` for anything else.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global verbosity threshold.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Whether messages at `level` are currently emitted.
pub fn level_enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit one formatted record to stderr (used via the `log_*!` macros).
pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if !level_enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{:>9.3}s {}] {}", t.as_secs_f64(), tag, args);
}

/// Log at [`crate::util::logging::Level::Error`] with `format!` syntax.
#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($a)*)) } }
/// Log at [`crate::util::logging::Level::Warn`] with `format!` syntax.
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($a)*)) } }
/// Log at [`crate::util::logging::Level::Info`] with `format!` syntax.
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($a)*)) } }
/// Log at [`crate::util::logging::Level::Debug`] with `format!` syntax.
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($a)*)) } }
/// Log at [`crate::util::logging::Level::Trace`] with `format!` syntax.
#[macro_export]
macro_rules! log_trace { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_cli_spellings() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("Info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("trace"), Some(Level::Trace));
        assert_eq!(Level::parse("loud"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(level_enabled(Level::Error));
        assert!(level_enabled(Level::Warn));
        assert!(!level_enabled(Level::Info));
        set_level(Level::Info);
        assert!(level_enabled(Level::Info));
        assert!(!level_enabled(Level::Debug));
    }
}
