//! Minimal self-contained JSON parser/serializer.
//!
//! The crate is dependency-minimal by design (`anyhow` only, no serde),
//! so the framework carries its own small JSON implementation. It
//! supports the full JSON grammar; numbers are parsed as f64 (with an i64
//! fast path preserved for integers), which is sufficient for the artifact
//! manifest, run configs and metrics emission.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// The `null` literal.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Integer-valued number (no fractional part in the source).
    Int(i64),
    /// Any other number.
    Float(f64),
    /// A string.
    Str(String),
    /// An ordered array.
    Array(Vec<Json>),
    /// A key-sorted object.
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------------------------------------------------------- accessors

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer value (also accepts fraction-free floats).
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// The numeric value as f64 (integers widen).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    /// The boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items, if this is an array.
    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// `obj.get("a")` style access; returns Null-typed None on mismatch.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Path access: `j.at(&["configs", "mnist_small", "batch"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for p in path {
            cur = cur.get(p)?;
        }
        Some(cur)
    }

    // -------------------------------------------------------- constructors

    /// An empty object.
    pub fn object() -> Json {
        Json::Object(BTreeMap::new())
    }

    /// Insert/overwrite a key (no-op on non-objects); chains.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Object(o) = self {
            o.insert(key.to_string(), val);
        }
        self
    }

    // ---------------------------------------------------------- serialize

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => out.push_str(&i.to_string()),
            Json::Float(f) => {
                if f.is_finite() {
                    out.push_str(&format!("{f}"));
                } else {
                    // JSON has no Inf/NaN; emit null like most encoders.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                out.push('[');
                for (n, item) in items.iter().enumerate() {
                    if n > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (n, (k, v)) in map.iter().enumerate() {
                    if n > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ------------------------------------------------------------------ parse

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing data after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\')
                                || self.bump() != Some(b'u')
                            {
                                return Err(
                                    self.err("unpaired high surrogate")
                                );
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000
                                + ((cp - 0xD800) << 10)
                                + (lo - 0xDC00);
                            s.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => {
                    return Err(self.err("control char in string"))
                }
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk =
                            std::str::from_utf8(&self.bytes[start..end])
                                .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self
                .bump()
                .and_then(|b| (b as char).to_digit(16))
                .ok_or_else(|| self.err("bad \\u escape"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        text.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("2.5").unwrap(), Json::Float(2.5));
        assert_eq!(parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = parse(r#"{"a": [1, {"b": null}, "x"], "c": 2.5}"#).unwrap();
        assert_eq!(j.at(&["c"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("a").unwrap().as_array().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"k":[1,2.5,"s\n",true,null],"m":{"n":-3}}"#;
        let j = parse(src).unwrap();
        let again = parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
        let pretty = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn escapes_and_unicode() {
        let j = parse(r#""a\"b\\cé 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\"b\\cé 😀");
        let re = parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, re);
    }

    #[test]
    fn utf8_passthrough() {
        let j = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn big_ints_preserved() {
        assert_eq!(
            parse("9007199254740993").unwrap().as_i64(),
            Some(9007199254740993)
        );
    }

    #[test]
    fn object_builder() {
        let mut o = Json::object();
        o.set("x", Json::Int(1)).set("y", Json::Str("z".into()));
        assert_eq!(o.to_string_compact(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Array(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::object());
        assert_eq!(Json::Array(vec![]).to_string_pretty(), "[]");
    }
}
