//! Minimal benchmarking harness (criterion is unavailable offline).
//!
//! Usage from a `harness = false` bench target:
//!
//! ```no_run
//! use csmaafl::util::bench::Bencher;
//! let mut b = Bencher::new("aggregation");
//! b.bench("native lerp 5k params", || { /* work */ });
//! b.report();
//! ```
//!
//! Each case is warmed up, then timed over enough iterations to exceed a
//! minimum measurement window; mean / p50 / p95 / min are reported.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Case label.
    pub name: String,
    /// Measured iterations (after warmup).
    pub iters: u64,
    /// Mean per-iteration time in nanoseconds.
    pub mean_ns: f64,
    /// Median per-iteration time in nanoseconds.
    pub p50_ns: f64,
    /// 95th-percentile per-iteration time in nanoseconds.
    pub p95_ns: f64,
    /// Fastest observed iteration in nanoseconds.
    pub min_ns: f64,
}

impl CaseResult {
    /// Items per second at the mean iteration time.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.mean_ns / 1e9)
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Collects benchmark cases and prints a table.
pub struct Bencher {
    group: String,
    warmup: u32,
    min_window: Duration,
    max_iters: u64,
    results: Vec<CaseResult>,
}

impl Bencher {
    /// A bencher for one named group with the default window.
    pub fn new(group: &str) -> Self {
        Bencher {
            group: group.to_string(),
            warmup: 2,
            min_window: Duration::from_millis(300),
            max_iters: 10_000,
            results: Vec::new(),
        }
    }

    /// Lower the measurement window for very slow cases (whole-run benches).
    pub fn with_window(mut self, window: Duration, max_iters: u64) -> Self {
        self.min_window = window;
        self.max_iters = max_iters;
        self
    }

    /// Time `f`, recording per-iteration samples.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &CaseResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let window_start = Instant::now();
        while window_start.elapsed() < self.min_window
            && (samples.len() as u64) < self.max_iters
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let result = CaseResult {
            name: name.to_string(),
            iters: n as u64,
            mean_ns: mean,
            p50_ns: samples[n / 2],
            p95_ns: samples[(n * 95 / 100).min(n - 1)],
            min_ns: samples[0],
        };
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Print the group's table to stdout.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        println!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
            "case", "iters", "mean", "p50", "p95", "min"
        );
        for r in &self.results {
            println!(
                "{:<44} {:>8} {:>12} {:>12} {:>12} {:>12}",
                r.name,
                r.iters,
                fmt_ns(r.mean_ns),
                fmt_ns(r.p50_ns),
                fmt_ns(r.p95_ns),
                fmt_ns(r.min_ns)
            );
        }
    }

    /// All case results recorded so far.
    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::new("t").with_window(Duration::from_millis(20), 100);
        let r = b.bench("noop-ish", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.p95_ns);
    }

    #[test]
    fn formats_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
