//! Shared registry-spelling parser.
//!
//! All three run-time registries (`aggregation=`, `scenario=`, and any
//! future ones) use the same spelling `name[:p1[,p2...]]` with numeric
//! parameters; this is the one place that grammar is parsed so error
//! wording and whitespace handling cannot drift between registries.

use anyhow::{anyhow, Result};

/// Split a registry spelling into its name and parsed numeric
/// parameters: `"fedasync:0.5,0.9"` → `("fedasync", vec![0.5, 0.9])`,
/// `"naive"` → `("naive", vec![])`. A malformed number is an error
/// naming the offending token and the full spec; whether the *count*
/// of parameters is legal is the caller's (per-entry) decision.
pub fn parse_spec(spec: &str) -> Result<(&str, Vec<f64>)> {
    let (name, args) = match spec.split_once(':') {
        Some((n, a)) => (n, Some(a)),
        None => (spec, None),
    };
    let params = match args {
        None => Vec::new(),
        Some(a) => a
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("invalid numeric parameter {p:?} in spec {spec:?}"))
            })
            .collect::<Result<_>>()?,
    };
    Ok((name, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_name_has_no_params() {
        assert_eq!(parse_spec("naive").unwrap(), ("naive", vec![]));
    }

    #[test]
    fn params_parse_with_whitespace() {
        assert_eq!(
            parse_spec("fedasync:0.5, 0.9").unwrap(),
            ("fedasync", vec![0.5, 0.9])
        );
        assert_eq!(parse_spec("drift:8").unwrap(), ("drift", vec![8.0]));
    }

    #[test]
    fn malformed_numbers_name_the_token() {
        let err = parse_spec("fedasync:x").unwrap_err().to_string();
        assert!(err.contains("\"x\""), "{err}");
        assert!(parse_spec("staleness:").is_err(), "empty parameter");
    }
}
