//! Seeded socket-layer fault injection for the deployment runtime.
//!
//! A [`FaultPlan`] is a *pure function* `(seed, worker, decision index)
//! → action`, built on the simulator's deterministic PRNG
//! (`util::rng`). Workers consult it once per received global model to
//! decide whether this round's upload proceeds, is dropped, dies
//! mid-frame, or the worker churns away — and because the plan is
//! stateless, an in-process `ServerCore` replay (`net::leader::
//! run_reference`) can re-derive the exact same fault sequence without
//! sockets, which is what makes the bit-identity assertions of
//! `tests/net_integration.rs` possible under fault injection.

use anyhow::{bail, ensure, Result};

use crate::util::rng::Rng;

/// What happens to one worker round under fault injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// Train and upload normally.
    None,
    /// Train, then report the upload lost in-band (a clean `Lost`
    /// frame — the transport survives, the payload does not).
    Drop,
    /// Train, write half the upload frame, then sever the connection —
    /// the leader sees a mid-frame close and must account the loss from
    /// the socket error alone. The worker reconnects afterwards.
    Cut,
    /// Churn: announce departure, disconnect for `rounds` leader
    /// rounds, then reconnect and upload the (now stale) held update.
    Churn {
        /// Leader rounds the worker sits out (≥ 1).
        rounds: u64,
    },
}

/// A deterministic fault schedule shared by workers and the replay.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    seed: u64,
    p_drop: f64,
    p_cut: f64,
    p_churn: f64,
    churn_rounds: u64,
}

impl FaultPlan {
    /// A plan drawing Drop/Cut/Churn with the given per-round
    /// probabilities (each in [0, 1], summing to at most 1); churn
    /// keeps a worker away for `churn_rounds` (≥ 1) leader rounds.
    pub fn new(
        seed: u64,
        p_drop: f64,
        p_cut: f64,
        p_churn: f64,
        churn_rounds: u64,
    ) -> Result<FaultPlan> {
        for (name, p) in [("drop", p_drop), ("cut", p_cut), ("churn", p_churn)] {
            ensure!(
                (0.0..=1.0).contains(&p),
                "fault probability {name}={p} outside [0, 1]"
            );
        }
        ensure!(
            p_drop + p_cut + p_churn <= 1.0,
            "fault probabilities sum to {} > 1",
            p_drop + p_cut + p_churn
        );
        ensure!(churn_rounds >= 1, "churn rounds must be >= 1");
        Ok(FaultPlan {
            seed,
            p_drop,
            p_cut,
            p_churn,
            churn_rounds,
        })
    }

    /// Parse a spec like `drop=0.1,cut=0.05,churn=0.1x3` (each key
    /// optional; `x3` on churn sets the away-rounds, default 2).
    pub fn parse(spec: &str, seed: u64) -> Result<FaultPlan> {
        let (mut p_drop, mut p_cut, mut p_churn, mut churn_rounds) = (0.0, 0.0, 0.0, 2u64);
        for part in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = match part.split_once('=') {
                Some(kv) => kv,
                None => bail!("fault spec part {part:?} is not key=value"),
            };
            match key {
                "drop" => p_drop = parse_prob(key, val)?,
                "cut" => p_cut = parse_prob(key, val)?,
                "churn" => {
                    let (p, rounds) = match val.split_once('x') {
                        Some((p, r)) => {
                            let rounds: u64 = r.parse().map_err(|_| {
                                anyhow::anyhow!("churn rounds {r:?} is not an integer")
                            })?;
                            (parse_prob(key, p)?, rounds)
                        }
                        None => (parse_prob(key, val)?, churn_rounds),
                    };
                    p_churn = p;
                    churn_rounds = rounds;
                }
                other => bail!("unknown fault kind {other:?} (drop|cut|churn)"),
            }
        }
        FaultPlan::new(seed, p_drop, p_cut, p_churn, churn_rounds)
    }

    /// The action for `worker`'s `index`-th decision. Pure and stable:
    /// any process (worker, leader test, replay) computes the same
    /// answer from the same `(seed, worker, index)`.
    pub fn action(&self, worker: usize, index: u64) -> FaultAction {
        let mut rng = Rng::new(self.seed).fork(worker as u64 + 1).fork(index + 1);
        let u = rng.f64();
        if u < self.p_drop {
            FaultAction::Drop
        } else if u < self.p_drop + self.p_cut {
            FaultAction::Cut
        } else if u < self.p_drop + self.p_cut + self.p_churn {
            FaultAction::Churn {
                rounds: self.churn_rounds,
            }
        } else {
            FaultAction::None
        }
    }

    /// The canonical spec string (for run JSON / logging).
    pub fn label(&self) -> String {
        format!(
            "drop={},cut={},churn={}x{}",
            self.p_drop, self.p_cut, self.p_churn, self.churn_rounds
        )
    }

    /// The seed the plan draws from.
    pub fn seed(&self) -> u64 {
        self.seed
    }
}

fn parse_prob(name: &str, s: &str) -> Result<f64> {
    let p: f64 = s
        .parse()
        .map_err(|_| anyhow::anyhow!("fault {name} probability {s:?} is not a number"))?;
    ensure!(
        (0.0..=1.0).contains(&p),
        "fault {name} probability {p} outside [0, 1]"
    );
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn actions_are_deterministic_and_worker_independent() {
        let plan = FaultPlan::parse("drop=0.3,cut=0.2,churn=0.2x3", 7).unwrap();
        let again = FaultPlan::parse("drop=0.3,cut=0.2,churn=0.2x3", 7).unwrap();
        let mut kinds = [0usize; 4];
        for w in 0..8 {
            for i in 0..64 {
                let a = plan.action(w, i);
                assert_eq!(a, again.action(w, i));
                match a {
                    FaultAction::None => kinds[0] += 1,
                    FaultAction::Drop => kinds[1] += 1,
                    FaultAction::Cut => kinds[2] += 1,
                    FaultAction::Churn { rounds } => {
                        assert_eq!(rounds, 3);
                        kinds[3] += 1;
                    }
                }
            }
        }
        // With 512 draws at these rates every kind appears.
        assert!(kinds.iter().all(|&k| k > 0), "{kinds:?}");
        // Different seeds give different schedules.
        let other = FaultPlan::parse("drop=0.3,cut=0.2,churn=0.2x3", 8).unwrap();
        assert!(
            (0..64).any(|i| plan.action(0, i) != other.action(0, i)),
            "seed had no effect"
        );
    }

    #[test]
    fn parse_accepts_partial_specs_and_defaults() {
        let plan = FaultPlan::parse("drop=0.25", 1).unwrap();
        assert_eq!(plan.label(), "drop=0.25,cut=0,churn=0x2");
        let churn = FaultPlan::parse("churn=0.5", 1).unwrap();
        assert_eq!(churn.label(), "drop=0,cut=0,churn=0.5x2");
        let empty = FaultPlan::parse("", 1).unwrap();
        assert_eq!(empty.action(0, 0), FaultAction::None);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("drop=1.5", 0).is_err());
        assert!(FaultPlan::parse("drop=x", 0).is_err());
        assert!(FaultPlan::parse("explode=0.1", 0).is_err());
        assert!(FaultPlan::parse("drop", 0).is_err());
        assert!(FaultPlan::parse("churn=0.1x0", 0).is_err());
        assert!(FaultPlan::parse("drop=0.6,cut=0.6", 0).is_err());
    }
}
