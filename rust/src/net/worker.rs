//! The deployment worker: Algorithm 1's client over real TCP.
//!
//! Connects, says Hello, then loops: receive the (fresh) global model,
//! run local SGD on its own shard, upload the update stamped with the
//! iteration it started from. Terminates on Shutdown.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::learner::{BatchCursor, Learner};
use crate::log_debug;
use crate::net::wire::{self, Message};

/// Worker-side configuration.
pub struct WorkerConfig<'a> {
    /// Leader address to connect to, e.g. `127.0.0.1:7070`.
    pub connect: String,
    /// Name announced in the Hello frame (logging only).
    pub name: String,
    /// Local trainer for this worker.
    pub learner: &'a dyn Learner,
    /// This worker's training shard.
    pub data: &'a Dataset,
    /// Sample indices of the shard within `data`.
    pub indices: Vec<usize>,
    /// Local SGD steps per upload.
    pub local_steps: usize,
}

/// Run until the leader sends Shutdown. Returns the number of uploads.
pub fn run_worker(cfg: &WorkerConfig<'_>) -> Result<u64> {
    let specs = cfg.learner.specs();
    let stream = TcpStream::connect(&cfg.connect)
        .with_context(|| format!("connecting {}", cfg.connect))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    wire::send(&mut writer, &Message::Hello {
        name: cfg.name.clone(),
    })?;

    let img = cfg.data.x.len() / cfg.data.len();
    let batch = cfg.learner.batch();
    let mut cursor = BatchCursor::new(cfg.indices.clone());
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut uploads = 0u64;

    loop {
        match wire::recv(&mut reader, &specs)? {
            Message::Global { iteration, params } => {
                cursor.fill(cfg.data, cfg.local_steps * batch, img, &mut xs, &mut ys);
                let (local, loss) =
                    cfg.learner.train(&params, &xs, &ys, cfg.local_steps)?;
                log_debug!(
                    "worker {}: iter {iteration} loss {loss:.4}",
                    cfg.name
                );
                wire::send(&mut writer, &Message::Update {
                    start_iteration: iteration,
                    steps: cfg.local_steps as u32,
                    params: local,
                })?;
                uploads += 1;
            }
            Message::Shutdown => return Ok(uploads),
            other => bail!("worker: unexpected message {other:?}"),
        }
    }
}
