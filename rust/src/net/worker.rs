//! The deployment worker: Algorithm 1's client over real TCP.
//!
//! Connects, says Hello, then loops: receive the (fresh) global model,
//! run local SGD on its own shard, upload the update stamped with the
//! iteration it started from. Terminates on Shutdown.
//!
//! The worker is *session-structured*: a broken connection (its own
//! fault injection, a leader-side stall drop, a flaky network) ends the
//! session, and the worker redials and re-Hellos — the leader treats
//! that as a rejoin. Under churn ([`FaultAction::Churn`]) the worker
//! announces its departure, keeps the locally-trained update across the
//! gap, and uploads it — now stale — on return, exactly like the
//! simulator's `churn` scenario. All fault decisions come from a seeded
//! [`FaultPlan`], so an in-process replay (`net::leader::run_reference`)
//! reproduces the same schedule without sockets.

use std::io::{BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::data::Dataset;
use crate::learner::{BatchCursor, Learner};
use crate::log_debug;
use crate::net::fault::{FaultAction, FaultPlan};
use crate::net::wire::{self, Message};

/// Worker-side configuration.
pub struct WorkerConfig<'a> {
    /// Leader address to connect to, e.g. `127.0.0.1:7070`.
    pub connect: String,
    /// This worker's id (must be `< clients` on the leader).
    pub worker: u32,
    /// Name announced in the Hello frame (logging only).
    pub name: String,
    /// Local trainer for this worker.
    pub learner: &'a dyn Learner,
    /// This worker's training shard.
    pub data: &'a Dataset,
    /// Sample indices of the shard within `data`.
    pub indices: Vec<usize>,
    /// Local SGD steps per upload.
    pub local_steps: usize,
    /// Seeded socket-fault schedule (`None` = fault-free).
    pub faults: Option<FaultPlan>,
    /// Upload XOR-bitpattern deltas against the received global
    /// (`DeltaUpdate` frames) instead of full models. The leader
    /// reconstructs bit-exactly, so results are identical either way;
    /// frame size is identical too — the win is downstream
    /// compressibility, and the frame type is what the wire meter and
    /// the version negotiation exercise.
    pub delta_uploads: bool,
    /// Delay between reconnect attempts (and the churn gap).
    pub reconnect_delay_ms: u64,
    /// Give up after this many consecutive failed dials.
    pub max_connect_attempts: u32,
}

impl<'a> WorkerConfig<'a> {
    /// A fault-free config with the production reconnect defaults.
    pub fn new(
        connect: impl Into<String>,
        worker: u32,
        name: impl Into<String>,
        learner: &'a dyn Learner,
        data: &'a Dataset,
        indices: Vec<usize>,
        local_steps: usize,
    ) -> WorkerConfig<'a> {
        WorkerConfig {
            connect: connect.into(),
            worker,
            name: name.into(),
            learner,
            data,
            indices,
            local_steps,
            faults: None,
            delta_uploads: false,
            reconnect_delay_ms: 50,
            max_connect_attempts: 100,
        }
    }
}

/// How a session ended, seen from the inner receive loop.
enum SessionEnd {
    /// Leader said Shutdown: the federation is over.
    Done,
    /// The connection is gone (injected fault or transport error);
    /// redial and resume.
    Reconnect,
}

/// Run until the leader sends Shutdown. Returns the number of uploads
/// (held churn updates count when delivered).
pub fn run_worker(cfg: &WorkerConfig<'_>) -> Result<u64> {
    let specs = cfg.learner.specs();
    let model_frame = wire::model_frame_len(&specs);
    anyhow::ensure!(
        model_frame <= wire::MAX_FRAME as u64,
        "model frames would be {model_frame} bytes on the wire, over the \
         {}-byte protocol limit (MAX_FRAME)",
        wire::MAX_FRAME
    );
    let img = cfg.data.x.len() / cfg.data.len();
    let batch = cfg.learner.batch();
    let mut cursor = BatchCursor::new(cfg.indices.clone());
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut uploads = 0u64;
    // Fault-decision index: one decision per global model received,
    // across all sessions — the replay counts the same way.
    let mut move_idx = 0u64;
    // An update trained before a churn gap, delivered on return.
    let mut held: Option<Message> = None;

    loop {
        let stream = connect_retry(cfg)?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        wire::send(&mut writer, &Message::Hello {
            worker: cfg.worker,
            name: cfg.name.clone(),
        })?;
        if let Some(msg) = held.take() {
            wire::send(&mut writer, &msg)?;
            uploads += 1;
            log_debug!("worker {}: delivered held update after churn", cfg.name);
        }
        match session(cfg, &specs, &mut reader, &mut writer, &mut cursor, img, batch,
            &mut xs, &mut ys, &mut uploads, &mut move_idx, &mut held)?
        {
            SessionEnd::Done => return Ok(uploads),
            SessionEnd::Reconnect => {
                drop(writer);
                drop(reader);
                std::thread::sleep(Duration::from_millis(cfg.reconnect_delay_ms));
            }
        }
    }
}

fn connect_retry(cfg: &WorkerConfig<'_>) -> Result<TcpStream> {
    let mut last = None;
    for _ in 0..cfg.max_connect_attempts.max(1) {
        match TcpStream::connect(&cfg.connect) {
            Ok(s) => return Ok(s),
            Err(e) => {
                last = Some(e);
                std::thread::sleep(Duration::from_millis(cfg.reconnect_delay_ms));
            }
        }
    }
    Err(last.expect("at least one attempt"))
        .with_context(|| format!("connecting {}", cfg.connect))
}

#[allow(clippy::too_many_arguments)]
fn session(
    cfg: &WorkerConfig<'_>,
    specs: &[crate::model::TensorSpec],
    reader: &mut BufReader<TcpStream>,
    writer: &mut BufWriter<TcpStream>,
    cursor: &mut BatchCursor,
    img: usize,
    batch: usize,
    xs: &mut Vec<f32>,
    ys: &mut Vec<i32>,
    uploads: &mut u64,
    move_idx: &mut u64,
    held: &mut Option<Message>,
) -> Result<SessionEnd> {
    loop {
        let msg = match wire::recv(reader, specs) {
            Ok(msg) => msg,
            Err(e) => {
                log_debug!("worker {}: connection lost ({e}); redialing", cfg.name);
                return Ok(SessionEnd::Reconnect);
            }
        };
        match msg {
            Message::Global { iteration, params } => {
                cursor.fill(cfg.data, cfg.local_steps * batch, img, xs, ys);
                let (local, loss) = cfg.learner.train(&params, xs, ys, cfg.local_steps)?;
                log_debug!("worker {}: iter {iteration} loss {loss:.4}", cfg.name);
                let action = match cfg.faults {
                    Some(plan) => plan.action(cfg.worker as usize, *move_idx),
                    None => FaultAction::None,
                };
                *move_idx += 1;
                let update = if cfg.delta_uploads {
                    Message::DeltaUpdate {
                        start_iteration: iteration,
                        steps: cfg.local_steps as u32,
                        params: wire::delta_params(&local, &params),
                    }
                } else {
                    Message::Update {
                        start_iteration: iteration,
                        steps: cfg.local_steps as u32,
                        params: local,
                    }
                };
                match action {
                    FaultAction::None => {
                        wire::send(writer, &update)?;
                        *uploads += 1;
                    }
                    FaultAction::Drop => {
                        // Train, then report the upload lost in-band.
                        wire::send(writer, &Message::Lost {
                            start_iteration: iteration,
                        })?;
                    }
                    FaultAction::Cut => {
                        // Die mid-frame: the leader must account this
                        // loss from the socket error alone.
                        let frame = wire::encode(&update);
                        writer.write_all(&frame[..frame.len() / 2])?;
                        writer.flush()?;
                        log_debug!("worker {}: injected mid-frame cut", cfg.name);
                        return Ok(SessionEnd::Reconnect);
                    }
                    FaultAction::Churn { rounds } => {
                        wire::send(writer, &Message::Leave {
                            start_iteration: iteration,
                            rounds,
                        })?;
                        *held = Some(update);
                        log_debug!("worker {}: churning away for {rounds} rounds", cfg.name);
                        return Ok(SessionEnd::Reconnect);
                    }
                }
            }
            Message::Shutdown => return Ok(SessionEnd::Done),
            other => bail!("worker: unexpected message {other:?}"),
        }
    }
}
