//! The deployment leader: Algorithm 1's server over real TCP, sharded
//! the same way as the PR-5 simulator pipeline.
//!
//! Three kinds of thread cooperate (all scoped, all joined on exit):
//!
//! * **one acceptor** owns the listener for the whole run — initial
//!   joins and later *re*-joins (churn, cut-upload recovery) go through
//!   the same Hello handshake, each with a deadline — and routes every
//!   connection to the ingest shard owning its worker id
//!   (`sim::partition::ClientPartition`, the simulator's split);
//! * **K ingest shards** each multiplex their connections with
//!   nonblocking [`FrameReader`]s: frame-decode uploads concurrently,
//!   enforce the per-connection mid-frame stall deadline, and feed a
//!   single **bounded** queue (`mpsc::sync_channel`) — when the
//!   aggregation stage falls behind, shards stop reading and TCP
//!   backpressure reaches the workers;
//! * **one aggregation stage** (the calling thread) drives the same
//!   sans-IO `coordinator::core::ServerCore` as the simulator. Bursts
//!   are staged through `sim::partition::OrderedMerge`, so socket races
//!   within a burst can never reorder aggregation.
//!
//! With `lockstep` set, the stage additionally gates on *rounds*: it
//! waits for exactly one move (update, in-band loss, mid-frame break,
//! or churn announcement) from every expected worker, then applies the
//! round in ascending `(start iteration, worker)` order. Round
//! membership is then a pure function of the fault schedule, which is
//! what makes `--net-shards N` bit-identical to `--net-shards 1` *and*
//! to the sans-IO [`run_reference`] replay — the deployment analogue of
//! `tests/sharded.rs`. Without `lockstep`, the leader keeps the paper's
//! fully asynchronous semantics (aggregate whenever any upload lands)
//! and the ordering discipline is per-burst only.
//!
//! A worker that disconnects while an upload is owed is accounted a
//! lost upload (`ServerCore::on_lost_upload`) and its fresh global is
//! *deferred* until it re-Hellos; a churning worker keeps its stale
//! model across the gap and resumes exactly like the simulator's
//! `churn` scenario — downtime accrues as staleness.
//!
//! Two robustness invariants the tests hold the stage to:
//!
//! * **Backpressure is not peer death.** The per-worker write handle
//!   shares its socket's nonblocking flag with the ingest shard's read
//!   half, so every leader→worker send goes through
//!   [`wire::send_retrying`]: `WouldBlock` parks and resumes from the
//!   same offset (no mid-frame abandonment), and only a real I/O error
//!   or a write frozen past the stall deadline defers the model for
//!   the rejoin path.
//! * **Absent workers cannot wedge the run.** If the event stream goes
//!   silent for `rejoin_timeout_ms` while a *disconnected* worker still
//!   owes a move (in lockstep, one dead worker blocks every round),
//!   the leader aborts with an error naming the absent workers rather
//!   than waiting forever for a rejoin.

use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use crate::coordinator::core::{NativeAggregator, ServerCore};
use crate::coordinator::policy::{AggregationPolicy, PolicyParams, StalenessEq11};
use crate::data::Dataset;
use crate::learner::{BatchCursor, Learner};
use crate::log_info;
use crate::model::{ParamSet, TensorSpec};
use crate::net::fault::{FaultAction, FaultPlan};
use crate::net::wire::{self, FrameReader, Message, WireError};
use crate::sim::{ClientPartition, OrderedMerge};
use crate::telemetry::{serve_stats, LiveStats, LossCause, Telemetry};
use crate::util::json::Json;

/// Leader-side configuration.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    /// Listen address, e.g. `0.0.0.0:7070`.
    pub bind: String,
    /// Number of workers to wait for before starting.
    pub clients: usize,
    /// Stop after this many global aggregations.
    pub max_iterations: u64,
    /// Eq. (11) γ (the default policy's hyper-parameter).
    pub gamma: f64,
    /// μ EMA rate.
    pub mu_rho: f64,
    /// Aggregation-policy registry spelling; `None` = eq.-(11)
    /// staleness weighting with `gamma` (the paper's deployment).
    pub aggregation: Option<String>,
    /// Ingest shard count (clamped to `[1, clients]`, like the
    /// simulator's shard workers). Affects only which thread decodes a
    /// worker's frames, never the result.
    pub net_shards: usize,
    /// Per-connection deadline in ms for a frame that started arriving
    /// but stalled (and for the Hello handshake, and for an outbound
    /// send frozen by a peer that stopped draining). 0 disables.
    pub read_timeout_ms: u64,
    /// Capacity of the bounded ingest→aggregation queue (≥ 1). A full
    /// queue blocks the ingest shards, which stops socket reads —
    /// backpressure all the way to the workers.
    pub queue_capacity: usize,
    /// Round-gated deterministic mode (see module docs).
    pub lockstep: bool,
    /// How long (ms) the aggregation stage tolerates total event
    /// silence while a *disconnected* worker still owes a move, before
    /// aborting the run with an error instead of waiting forever for a
    /// rejoin that may never come. Must exceed the longest expected
    /// churn gap. 0 disables (wait forever — the pre-PR-6 behavior).
    pub rejoin_timeout_ms: u64,
    /// Serve a Prometheus text-format stats snapshot on this address
    /// (`repro serve --stats-addr`). `None` disables the endpoint and
    /// the periodic stderr digest; neither ever touches the
    /// deterministic aggregation order.
    pub stats_addr: Option<String>,
    /// Write ordered trace events (JSONL) to this path
    /// (`repro serve --trace`). Emission happens on the aggregation
    /// stage only, in apply order.
    pub trace: Option<String>,
}

impl LeaderConfig {
    /// A config with the production defaults for every robustness knob
    /// (single ingest shard, 5 s stall deadline, 1024-slot queue,
    /// asynchronous semantics).
    pub fn new(bind: impl Into<String>, clients: usize, max_iterations: u64) -> LeaderConfig {
        LeaderConfig {
            bind: bind.into(),
            clients,
            max_iterations,
            gamma: 0.2,
            mu_rho: 0.1,
            aggregation: None,
            net_shards: 1,
            read_timeout_ms: 5_000,
            queue_capacity: 1024,
            lockstep: false,
            rejoin_timeout_ms: 30_000,
            stats_addr: None,
            trace: None,
        }
    }
}

/// What the leader observed during a run.
#[derive(Debug, Clone)]
pub struct LeaderReport {
    /// Total global aggregations performed.
    pub aggregations: u64,
    /// Updates delivered per worker (fairness accounting).
    pub updates_per_client: Vec<u64>,
    /// Uploads lost in transit (socket breaks, stalls, in-band drops).
    pub lost_uploads: u64,
    /// Lost uploads per worker (dropout-bias accounting).
    pub lost_per_client: Vec<u64>,
    /// Mean observed staleness across aggregations.
    pub mean_staleness: f64,
    /// Real time from first broadcast to shutdown (0 for the replay).
    pub wallclock_secs: f64,
    /// The aggregation policy's canonical label.
    pub policy: String,
    /// The final global model.
    pub final_model: ParamSet,
}

impl LeaderReport {
    /// The deterministic results of the run: every field is a pure
    /// function of the inputs (model, data, seeds, fault schedule) in
    /// lockstep mode — wall-clock never appears here, so two equivalent
    /// runs serialize byte-identically (the `tests/sharded.rs`
    /// discipline).
    pub fn summary_json(&self) -> Json {
        let ints = |xs: &[u64]| Json::Array(xs.iter().map(|&u| Json::Int(u as i64)).collect());
        let mut j = Json::object();
        j.set("aggregations", Json::Int(self.aggregations as i64))
            .set("lost_uploads", Json::Int(self.lost_uploads as i64))
            .set("lost_per_client", ints(&self.lost_per_client))
            .set("updates_per_client", ints(&self.updates_per_client))
            .set("mean_staleness", Json::Float(self.mean_staleness))
            .set("model_digest", Json::Str(format!("{:016x}", self.final_model.digest())))
            .set("policy", Json::Str(self.policy.clone()));
        j
    }
}

/// One worker's pending contribution, keyed for the ordered merge by
/// the iteration stamp it trained from.
enum Move {
    /// A completed upload.
    Update { stamp: u64, params: ParamSet },
    /// An in-band loss report (`Lost` frame): the transport survived,
    /// the payload did not.
    Lost { stamp: u64 },
    /// A churn announcement: away for `rounds`, holding a stale model.
    Leave { stamp: u64, rounds: u64 },
    /// The connection broke while this upload was owed.
    Broken { stamp: u64 },
}

impl Move {
    fn stamp(&self) -> u64 {
        match self {
            Move::Update { stamp, .. }
            | Move::Lost { stamp }
            | Move::Leave { stamp, .. }
            | Move::Broken { stamp } => *stamp,
        }
    }
}

/// Events the ingest side feeds the aggregation stage.
enum Inbound {
    /// A worker completed the Hello handshake (join or rejoin); the
    /// write half of its connection travels with the event. The handle
    /// shares the socket (and its nonblocking flag) with the ingest
    /// shard's read half, so all sends on it go through
    /// [`wire::send_retrying`].
    Joined {
        worker: usize,
        name: String,
        writer: TcpStream,
    },
    /// A decoded worker→leader frame.
    Frame { worker: usize, msg: Message },
    /// The connection died (close, mid-frame break, stall deadline, or
    /// protocol violation).
    ConnLost {
        worker: usize,
        mid_frame: bool,
        timed_out: bool,
    },
}

/// Aggregation-stage bookkeeping for one worker.
struct Peer {
    writer: Option<TcpStream>,
    joined: bool,
    /// A global model has been issued and its move not yet applied.
    outstanding: bool,
    /// A Leave frame was seen; the following ConnLost is expected.
    leaving: bool,
    /// Moves received but not yet applied.
    pending: VecDeque<Move>,
    /// Lockstep: earliest round this worker's next move may apply.
    due: u64,
    /// A global issued while the worker had no live connection.
    deferred: Option<(u64, ParamSet)>,
    /// The last global issued to this worker — the base a DeltaUpdate
    /// frame XORs against. Kept until the next issue overwrites it (a
    /// rejoining worker may still answer the old base).
    issued: Option<ParamSet>,
}

impl Peer {
    fn new() -> Peer {
        Peer {
            writer: None,
            joined: false,
            outstanding: false,
            leaving: false,
            pending: VecDeque::new(),
            due: 0,
            deferred: None,
            issued: None,
        }
    }

    /// Hand this worker the current global model: stamp it via the
    /// core, then ship it now or defer until the worker reconnects.
    ///
    /// The write handle shares its socket's nonblocking flag with the
    /// ingest shard, so the send retries through `WouldBlock`
    /// (backpressure is not peer death); only a real I/O failure or a
    /// `stall`-long write freeze defers the model for the rejoin path.
    fn issue(&mut self, worker: usize, core: &mut ServerCore, stall: Option<Duration>) {
        let iteration = core.issue_to(worker);
        let params = core.global().clone();
        self.outstanding = true;
        self.issued = Some(params.clone());
        self.ship(worker, iteration, params, stall);
    }

    /// Try to deliver a stamped global now; on failure park it in
    /// `deferred` for the next rejoin.
    fn ship(&mut self, worker: usize, iteration: u64, params: ParamSet, stall: Option<Duration>) {
        let sent = match self.writer.as_mut() {
            Some(w) => match wire::send_retrying(
                w,
                &Message::Global {
                    iteration,
                    params: params.clone(),
                },
                stall,
            ) {
                Ok(()) => true,
                Err(e) => {
                    log_info!("leader: sending global to worker {worker} failed ({e}); deferring");
                    false
                }
            },
            None => false,
        };
        if !sent {
            self.writer = None;
            self.deferred = Some((iteration, params));
        }
    }
}

fn parse_policy(
    aggregation: &Option<String>,
    clients: usize,
    gamma: f64,
) -> Result<Box<dyn AggregationPolicy>> {
    let params = PolicyParams { clients, gamma };
    match aggregation {
        Some(spec) => <dyn AggregationPolicy>::parse(spec, &params)
            .with_context(|| format!("leader aggregation policy {spec:?}")),
        None => Ok(Box::new(StalenessEq11::new(gamma)?)),
    }
}

// --------------------------------------------------------- ingest side

struct Conn {
    worker: usize,
    stream: TcpStream,
    reader: FrameReader,
    last_progress: Instant,
}

enum PollOutcome {
    Keep { progressed: bool },
    Drop,
    Shutdown,
}

/// Relay one decoded frame to the aggregation queue, metering the
/// shard's ingest counter and the queue-depth gauge (popped by
/// `handle` when the frame leaves the queue).
fn forward(
    out: &mpsc::SyncSender<Inbound>,
    worker: usize,
    msg: Message,
    shard: usize,
    stats: &LiveStats,
) -> bool {
    stats.frame_ingested(shard);
    let ok = out.send(Inbound::Frame { worker, msg }).is_ok();
    if ok {
        stats.queue_push();
    }
    ok
}

/// Pull everything currently available from one connection.
fn poll_conn(
    conn: &mut Conn,
    out: &mpsc::SyncSender<Inbound>,
    specs: &[TensorSpec],
    stall: Option<Duration>,
    shard: usize,
    stats: &LiveStats,
) -> PollOutcome {
    let mut progressed = false;
    loop {
        let before = conn.reader.buffered();
        match conn.reader.poll(&mut conn.stream) {
            Ok(Some(body)) => {
                progressed = true;
                conn.last_progress = Instant::now();
                match wire::decode(&body, specs) {
                    Ok(msg @ (Message::Update { .. } | Message::DeltaUpdate { .. }
                    | Message::Lost { .. } | Message::Leave { .. })) => {
                        stats.wire_bytes(body.len() as u64);
                        if !forward(out, conn.worker, msg, shard, stats) {
                            return PollOutcome::Shutdown;
                        }
                    }
                    Ok(other) => {
                        log_info!(
                            "leader: worker {} sent unexpected {other:?}; dropping connection",
                            conn.worker
                        );
                        let _ = out.send(Inbound::ConnLost {
                            worker: conn.worker,
                            mid_frame: false,
                            timed_out: false,
                        });
                        return PollOutcome::Drop;
                    }
                    Err(e) => {
                        log_info!("leader: worker {} protocol error: {e}", conn.worker);
                        let _ = out.send(Inbound::ConnLost {
                            worker: conn.worker,
                            mid_frame: true,
                            timed_out: false,
                        });
                        return PollOutcome::Drop;
                    }
                }
            }
            Ok(None) => {
                if conn.reader.buffered() > before {
                    progressed = true;
                    conn.last_progress = Instant::now();
                } else if let Some(limit) = stall {
                    if conn.reader.mid_frame() && conn.last_progress.elapsed() >= limit {
                        log_info!(
                            "leader: worker {} stalled mid-frame past {limit:?}; dropping",
                            conn.worker
                        );
                        let _ = out.send(Inbound::ConnLost {
                            worker: conn.worker,
                            mid_frame: true,
                            timed_out: true,
                        });
                        return PollOutcome::Drop;
                    }
                }
                return PollOutcome::Keep { progressed };
            }
            Err(WireError::Closed { mid_frame }) => {
                let _ = out.send(Inbound::ConnLost {
                    worker: conn.worker,
                    mid_frame,
                    timed_out: false,
                });
                return PollOutcome::Drop;
            }
            Err(e) => {
                log_info!("leader: worker {} read error: {e}", conn.worker);
                let _ = out.send(Inbound::ConnLost {
                    worker: conn.worker,
                    mid_frame: true,
                    timed_out: false,
                });
                return PollOutcome::Drop;
            }
        }
    }
}

/// A replaced connection may still hold the worker's final frames (a
/// Leave announcement racing its own reconnect). Read them out before
/// the replacement takes over, so the per-worker frame order the
/// aggregation stage sees matches the order the worker sent.
///
/// The drain stays *nonblocking* — an empty poll sleeps 1 ms, bounded
/// by a 200 ms overall deadline — so one replaced connection can stall
/// the other connections on this shard only while bytes are genuinely
/// trickling in, and exits on the first quiet poll once no frame is in
/// progress. Every exit emits `ConnLost` (with the reader's mid-frame
/// state), exactly like `poll_conn`'s paths: the old connection is dead
/// either way, and an owed upload that died with it must be accounted —
/// swallowing the event here would strand a lockstep round.
fn drain_replaced(
    mut conn: Conn,
    out: &mpsc::SyncSender<Inbound>,
    specs: &[TensorSpec],
    shard: usize,
    stats: &LiveStats,
) {
    let deadline = Instant::now() + Duration::from_millis(200);
    let worker = conn.worker;
    let conn_lost = move |mid_frame: bool, timed_out: bool| Inbound::ConnLost {
        worker,
        mid_frame,
        timed_out,
    };
    loop {
        match conn.reader.poll(&mut conn.stream) {
            Ok(Some(body)) => match wire::decode(&body, specs) {
                Ok(msg @ (Message::Update { .. } | Message::DeltaUpdate { .. }
                | Message::Lost { .. } | Message::Leave { .. })) => {
                    stats.wire_bytes(body.len() as u64);
                    if !forward(out, conn.worker, msg, shard, stats) {
                        return;
                    }
                }
                // Protocol violation on the dying connection: same as
                // poll_conn's decode-error path.
                _ => {
                    let _ = out.send(conn_lost(true, false));
                    return;
                }
            },
            Ok(None) => {
                if !conn.reader.mid_frame() {
                    // Quiet and between frames: everything the worker
                    // sent before redialing has been relayed.
                    let _ = out.send(conn_lost(false, false));
                    return;
                }
                if Instant::now() >= deadline {
                    let _ = out.send(conn_lost(true, true));
                    return;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(WireError::Closed { mid_frame }) => {
                let _ = out.send(conn_lost(mid_frame, false));
                return;
            }
            Err(_) => {
                let _ = out.send(conn_lost(conn.reader.mid_frame(), false));
                return;
            }
        }
    }
}

/// One ingest shard: admit the connections routed here, poll them all
/// nonblockingly, decode frames, feed the bounded aggregation queue.
fn run_shard(
    joins: &mpsc::Receiver<(usize, String, TcpStream)>,
    out: &mpsc::SyncSender<Inbound>,
    specs: &[TensorSpec],
    stall: Option<Duration>,
    done: &AtomicBool,
    shard: usize,
    stats: &LiveStats,
) {
    let mut conns: Vec<Conn> = Vec::new();
    while !done.load(Ordering::Relaxed) {
        let mut activity = false;
        while let Ok((worker, name, stream)) = joins.try_recv() {
            activity = true;
            if let Some(i) = conns.iter().position(|c| c.worker == worker) {
                drain_replaced(conns.swap_remove(i), out, specs, shard, stats);
            }
            let writer = match stream.try_clone() {
                Ok(s) => s,
                Err(_) => continue,
            };
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            if out.send(Inbound::Joined { worker, name, writer }).is_err() {
                return;
            }
            conns.push(Conn {
                worker,
                stream,
                reader: FrameReader::new(),
                last_progress: Instant::now(),
            });
        }
        let mut i = 0;
        while i < conns.len() {
            match poll_conn(&mut conns[i], out, specs, stall, shard, stats) {
                PollOutcome::Keep { progressed } => {
                    activity |= progressed;
                    i += 1;
                }
                PollOutcome::Drop => {
                    conns.swap_remove(i);
                    activity = true;
                }
                PollOutcome::Shutdown => return,
            }
        }
        if !activity {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
}

/// The acceptor: handshake every incoming connection (with a deadline)
/// and route it to the ingest shard owning its worker id.
fn run_acceptor(
    listener: &TcpListener,
    shard_txs: &[mpsc::Sender<(usize, String, TcpStream)>],
    partition: ClientPartition,
    specs: &[TensorSpec],
    hello_timeout: Option<Duration>,
    done: &AtomicBool,
) {
    while !done.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, addr)) => {
                let outcome = admit(stream, shard_txs, partition, specs, hello_timeout);
                if let Err(e) = outcome {
                    log_info!("leader: rejected connection from {addr}: {e:#}");
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                log_info!("leader: accept error: {e}");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

fn admit(
    stream: TcpStream,
    shard_txs: &[mpsc::Sender<(usize, String, TcpStream)>],
    partition: ClientPartition,
    specs: &[TensorSpec],
    hello_timeout: Option<Duration>,
) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(hello_timeout)?;
    stream.set_nodelay(true).ok();
    let hello = wire::recv(&mut (&stream), specs)?;
    match hello {
        Message::Hello { worker, name } => {
            let worker = worker as usize;
            ensure!(
                worker < partition.clients(),
                "worker id {worker} out of range (clients = {})",
                partition.clients()
            );
            stream.set_read_timeout(None)?;
            shard_txs[partition.shard_of(worker)]
                .send((worker, name, stream))
                .map_err(|_| anyhow::anyhow!("ingest shard is gone"))?;
            Ok(())
        }
        other => bail!("expected Hello, got {other:?}"),
    }
}

// ---------------------------------------------------- aggregation side

/// Run the leader until `max_iterations` aggregations, then shut workers
/// down. `w0` is the initial global model (its specs define the wire
/// schema).
pub fn run_leader(cfg: &LeaderConfig, w0: ParamSet) -> Result<LeaderReport> {
    ensure!(cfg.clients >= 1, "leader needs at least one client");
    ensure!(cfg.queue_capacity >= 1, "queue capacity must be >= 1");
    let specs: Vec<TensorSpec> = w0.specs();
    let model_frame = wire::model_frame_len(&specs);
    ensure!(
        model_frame <= wire::MAX_FRAME as u64,
        "model frames would be {model_frame} bytes on the wire, over the \
         {}-byte protocol limit (MAX_FRAME); shrink the model or raise the limit",
        wire::MAX_FRAME
    );
    let policy = parse_policy(&cfg.aggregation, cfg.clients, cfg.gamma)?;
    log_info!("leader: aggregation policy {}", policy.label());
    let core = ServerCore::new(w0, cfg.clients, policy, cfg.mu_rho);

    let listener =
        TcpListener::bind(&cfg.bind).with_context(|| format!("binding {}", cfg.bind))?;
    listener.set_nonblocking(true)?;
    log_info!("leader: listening on {}", listener.local_addr()?);

    let partition = ClientPartition::new(cfg.clients, cfg.net_shards);
    let timeout = (cfg.read_timeout_ms > 0).then(|| Duration::from_millis(cfg.read_timeout_ms));
    let done = AtomicBool::new(false);
    let (agg_tx, agg_rx) = mpsc::sync_channel::<Inbound>(cfg.queue_capacity);
    let mut shard_txs = Vec::with_capacity(partition.shards());
    let mut shard_rxs = Vec::with_capacity(partition.shards());
    for _ in 0..partition.shards() {
        let (tx, rx) = mpsc::channel();
        shard_txs.push(tx);
        shard_rxs.push(rx);
    }

    // Telemetry: trace emission lives on the aggregation stage only
    // (apply order), so it can never be perturbed by socket races; the
    // live counters are relaxed atomics the other threads bump freely.
    let mut tel = match &cfg.trace {
        Some(p) => Telemetry::to_file(Path::new(p))?,
        None => Telemetry::off(),
    };
    tel.bind(cfg.clients);
    let stats = LiveStats::new(partition.shards());
    let stats_listener = match &cfg.stats_addr {
        Some(addr) => {
            let l = TcpListener::bind(addr).with_context(|| format!("binding stats {addr}"))?;
            log_info!("leader: stats endpoint on {}", l.local_addr()?);
            Some(l)
        }
        None => None,
    };

    let out = std::thread::scope(|scope| {
        let done = &done;
        let specs = &specs;
        let listener = &listener;
        let shard_txs_ref = &shard_txs;
        let stats = &stats;
        scope.spawn(move || {
            run_acceptor(listener, shard_txs_ref, partition, specs, timeout, done)
        });
        if let Some(sl) = stats_listener {
            scope.spawn(move || serve_stats(sl, stats, done));
        }
        for (shard, rx) in shard_rxs.into_iter().enumerate() {
            let tx = agg_tx.clone();
            scope.spawn(move || run_shard(&rx, &tx, specs, timeout, done, shard, stats));
        }
        drop(agg_tx);
        let out = aggregate(cfg, core, &agg_rx, &mut tel, stats);
        done.store(true, Ordering::Relaxed);
        // Drop the receiver so shards blocked sending into a full queue
        // error out instead of wedging the scope join.
        drop(agg_rx);
        out
    });
    tel.finish()?;
    out
}

/// Receive one ingest event: `Ok(Some)` on an event, `Ok(None)` when
/// `timeout` elapsed with no event at all, `Err` when the ingest side
/// hung up (shutdown).
fn recv_event(rx: &mpsc::Receiver<Inbound>, timeout: Option<Duration>) -> Result<Option<Inbound>> {
    match timeout {
        None => rx
            .recv()
            .map(Some)
            .map_err(|_| anyhow::anyhow!("ingest pipeline exited")),
        Some(limit) => match rx.recv_timeout(limit) {
            Ok(ev) => Ok(Some(ev)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(anyhow::anyhow!("ingest pipeline exited"))
            }
        },
    }
}

/// The aggregation stage. Runs on the caller's thread; everything the
/// core sees flows through here in a deterministic per-burst (or, in
/// lockstep, per-round) order.
fn aggregate(
    cfg: &LeaderConfig,
    mut core: ServerCore,
    rx: &mpsc::Receiver<Inbound>,
    tel: &mut Telemetry,
    stats: &LiveStats,
) -> Result<LeaderReport> {
    let stall = (cfg.read_timeout_ms > 0).then(|| Duration::from_millis(cfg.read_timeout_ms));
    let rejoin = (cfg.rejoin_timeout_ms > 0).then(|| Duration::from_millis(cfg.rejoin_timeout_ms));
    let mut peers: Vec<Peer> = (0..cfg.clients).map(|_| Peer::new()).collect();
    let mut joined = 0usize;

    // Join barrier: wait for every worker's first Hello. `rejoin`
    // bounds the silence *between* joins, so a worker that never shows
    // up fails the run instead of wedging it.
    while joined < cfg.clients {
        let ev = match recv_event(rx, rejoin)? {
            Some(ev) => ev,
            None => bail!(
                "leader: only {joined} of {} workers joined within {:?}; aborting",
                cfg.clients,
                rejoin.expect("timeout fired only when set")
            ),
        };
        if let Inbound::Joined { worker, .. } = &ev {
            if !peers[*worker].joined {
                joined += 1;
            }
        }
        handle(&mut peers, &mut core, ev, stall, stats);
    }
    log_info!("leader: all {} workers joined; broadcasting w0", cfg.clients);

    let started = Instant::now();
    let mut last_digest = Instant::now();
    for worker in 0..cfg.clients {
        peers[worker].issue(worker, &mut core, stall);
    }

    let mut staged: OrderedMerge<Move> = OrderedMerge::new();
    let mut round = 0u64;
    'serve: while core.iteration() < cfg.max_iterations {
        if cfg.stats_addr.is_some() && last_digest.elapsed() >= Duration::from_secs(10) {
            log_info!("leader: {}", stats.digest_line());
            last_digest = Instant::now();
        }
        match recv_event(rx, rejoin) {
            Ok(Some(ev)) => handle(&mut peers, &mut core, ev, stall, stats),
            Ok(None) => {
                // Event silence for the whole rejoin window. If some
                // disconnected worker still owes a move, no rejoin is
                // coming to unwedge it — abort loudly (the recoverable
                // paths all produce events well inside the window). A
                // quiet-but-connected federation just keeps waiting.
                let absent: Vec<usize> = (0..cfg.clients)
                    .filter(|&w| {
                        peers[w].outstanding
                            && peers[w].pending.is_empty()
                            && peers[w].writer.is_none()
                    })
                    .collect();
                if absent.is_empty() {
                    continue;
                }
                bail!(
                    "leader: no events for {:?} while disconnected worker(s) {absent:?} \
                     still owe a move; treating them as permanently lost and aborting \
                     (raise --net-rejoin-ms if churn gaps can legitimately exceed it)",
                    rejoin.expect("timeout fired only when set")
                );
            }
            Err(_) => break,
        }
        while let Ok(ev) = rx.try_recv() {
            handle(&mut peers, &mut core, ev, stall, stats);
        }
        if cfg.lockstep {
            // Apply every round whose full move set has arrived.
            loop {
                if !peers.iter().any(|p| p.outstanding) {
                    break;
                }
                let min_due = peers
                    .iter()
                    .filter(|p| p.outstanding)
                    .map(|p| p.due)
                    .min()
                    .unwrap_or(round);
                if min_due > round {
                    round = min_due;
                }
                let expected: Vec<usize> = (0..cfg.clients)
                    .filter(|&w| peers[w].outstanding && peers[w].due <= round)
                    .collect();
                if expected.iter().any(|&w| peers[w].pending.is_empty()) {
                    break;
                }
                let mut batch: OrderedMerge<Move> = OrderedMerge::new();
                for &w in &expected {
                    let mv = peers[w].pending.pop_front().expect("checked nonempty");
                    batch.push(mv.stamp(), w, mv);
                }
                while let Some((_, w, mv)) = batch.pop() {
                    apply(&mut peers, &mut core, w, mv, Some(round), stall, tel, stats)?;
                    if core.iteration() >= cfg.max_iterations {
                        break 'serve;
                    }
                }
                round += 1;
            }
        } else {
            // Asynchronous burst discipline: stage everything that has
            // arrived, apply in (start iteration, worker) order.
            for w in 0..cfg.clients {
                while let Some(mv) = peers[w].pending.pop_front() {
                    staged.push(mv.stamp(), w, mv);
                }
            }
            while let Some((_, w, mv)) = staged.pop() {
                apply(&mut peers, &mut core, w, mv, None, stall, tel, stats)?;
                if core.iteration() >= cfg.max_iterations {
                    break 'serve;
                }
            }
        }
    }

    // Shut down every connected worker, then keep answering late
    // re-joiners (churn/cut reconnects in flight) with Shutdown for a
    // grace window so none is left dialing a dead address.
    for p in peers.iter_mut() {
        if let Some(w) = p.writer.as_mut() {
            let _ = wire::send_retrying(w, &Message::Shutdown, stall);
        }
    }
    let deadline = Instant::now() + Duration::from_millis(600);
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(Inbound::Joined { mut writer, .. }) => {
                let _ = wire::send_retrying(&mut writer, &Message::Shutdown, stall);
            }
            Ok(_) => {}
            Err(_) => break,
        }
    }

    Ok(LeaderReport {
        aggregations: core.iteration(),
        updates_per_client: core.updates_per_client().to_vec(),
        lost_uploads: core.lost_uploads(),
        lost_per_client: core.lost_per_client().to_vec(),
        mean_staleness: core.mean_staleness(),
        wallclock_secs: started.elapsed().as_secs_f64(),
        policy: core.policy_label(),
        final_model: core.into_global(),
    })
}

/// Fold one ingest event into the peer table.
fn handle(
    peers: &mut [Peer],
    core: &mut ServerCore,
    ev: Inbound,
    stall: Option<Duration>,
    stats: &LiveStats,
) {
    match ev {
        Inbound::Joined { worker, name, writer } => {
            let p = &mut peers[worker];
            let rejoin = p.joined;
            p.joined = true;
            p.leaving = false;
            p.writer = Some(writer);
            if rejoin {
                stats.reconnect();
                log_info!("leader: worker {worker} ({name}) rejoined");
            } else {
                log_info!("leader: worker {worker} ({name}) joined");
            }
            if let Some((iteration, params)) = p.deferred.take() {
                p.ship(worker, iteration, params, stall);
            }
        }
        Inbound::Frame { worker, msg } => {
            stats.queue_pop();
            let p = &mut peers[worker];
            match msg {
                Message::Update {
                    start_iteration,
                    params,
                    ..
                } => p.pending.push_back(Move::Update {
                    stamp: start_iteration,
                    params,
                }),
                Message::DeltaUpdate {
                    start_iteration,
                    params: delta,
                    ..
                } => match p.issued.as_ref() {
                    // XOR the bitpattern delta back onto the base this
                    // worker was issued: reconstructs the local model
                    // bit-for-bit, then takes the ordinary Update path.
                    Some(base) => p.pending.push_back(Move::Update {
                        stamp: start_iteration,
                        params: wire::apply_delta(&delta, base),
                    }),
                    None => log_info!(
                        "leader: delta update from worker {worker} with no \
                         issued base; ignoring"
                    ),
                },
                Message::Lost { start_iteration } => p.pending.push_back(Move::Lost {
                    stamp: start_iteration,
                }),
                Message::Leave {
                    start_iteration,
                    rounds,
                } => {
                    p.leaving = true;
                    p.pending.push_back(Move::Leave {
                        stamp: start_iteration,
                        rounds: rounds.max(1),
                    });
                }
                other => log_info!("leader: ignoring unexpected {other:?} from {worker}"),
            }
        }
        Inbound::ConnLost {
            worker,
            mid_frame,
            timed_out,
        } => {
            let p = &mut peers[worker];
            p.writer = None;
            if p.leaving {
                // The close a Leave announced; not a loss.
                p.leaving = false;
            } else if p.outstanding && p.pending.is_empty() {
                log_info!(
                    "leader: worker {worker} gone with an upload owed \
                     (mid_frame={mid_frame}, timed_out={timed_out}); counting it lost"
                );
                p.pending.push_back(Move::Broken {
                    stamp: core.model_version(worker),
                });
            } else {
                log_info!("leader: worker {worker} disconnected");
            }
        }
    }
}

/// Apply one move to the core, then (for anything but a Leave) hand the
/// worker a fresh global. `round` is Some in lockstep mode. Trace events
/// are emitted here — the single ordered aggregation point — so a traced
/// deployment run records the exact apply order the core saw.
#[allow(clippy::too_many_arguments)]
fn apply(
    peers: &mut [Peer],
    core: &mut ServerCore,
    worker: usize,
    mv: Move,
    round: Option<u64>,
    stall: Option<Duration>,
    tel: &mut Telemetry,
    stats: &LiveStats,
) -> Result<()> {
    match mv {
        Move::Update { stamp, params } => {
            let t = core.iteration();
            let out = core.on_update(worker, stamp, &params, &NativeAggregator)?;
            tel.upload_applied(
                t,
                worker,
                out.iteration,
                out.staleness,
                out.beta,
                out.weight,
            );
            stats.aggregated();
            peers[worker].outstanding = false;
            peers[worker].issue(worker, core, stall);
            if let Some(r) = round {
                peers[worker].due = r + 1;
            }
        }
        Move::Lost { .. } | Move::Broken { .. } => {
            let t = core.iteration();
            tel.upload_lost(t, worker, LossCause::Disconnect);
            stats.upload_lost();
            core.on_lost_upload(worker);
            peers[worker].outstanding = false;
            peers[worker].issue(worker, core, stall);
            if let Some(r) = round {
                peers[worker].due = r + 1;
            }
        }
        Move::Leave { rounds, .. } => {
            if let Some(r) = round {
                peers[worker].due = r + rounds;
            }
        }
    }
    Ok(())
}

// ------------------------------------------------------ sans-IO replay

/// Inputs for [`run_reference`]: the same federation a lockstep
/// deployment run would execute, minus the sockets.
pub struct ReferenceConfig<'a> {
    /// Worker count.
    pub clients: usize,
    /// Stop after this many global aggregations.
    pub max_iterations: u64,
    /// Eq. (11) γ.
    pub gamma: f64,
    /// μ EMA rate.
    pub mu_rho: f64,
    /// Aggregation-policy registry spelling (`None` = eq. 11).
    pub aggregation: Option<String>,
    /// The local trainer every worker runs.
    pub learner: &'a dyn Learner,
    /// The shared training set.
    pub data: &'a Dataset,
    /// Per-worker sample indices into `data`.
    pub shards: &'a [Vec<usize>],
    /// Local SGD steps per upload.
    pub local_steps: usize,
    /// The fault schedule the workers follow (`None` = fault-free).
    pub faults: Option<FaultPlan>,
}

struct SimWorker {
    cursor: BatchCursor,
    move_idx: u64,
    pending: VecDeque<Move>,
    outstanding: bool,
    due: u64,
}

/// The in-process `ServerCore` reference: replays, without sockets, the
/// exact event order a lockstep `run_leader` produces for the same
/// inputs — the acceptance oracle for the TCP pipeline. Bit-identity
/// (final model and [`LeaderReport::summary_json`]) against the real
/// deployment at any `net_shards` is asserted in
/// `tests/net_integration.rs`.
pub fn run_reference(cfg: &ReferenceConfig<'_>, w0: ParamSet) -> Result<LeaderReport> {
    ensure!(cfg.clients >= 1, "reference needs at least one client");
    ensure!(
        cfg.shards.len() == cfg.clients,
        "reference: {} shards for {} clients",
        cfg.shards.len(),
        cfg.clients
    );
    let policy = parse_policy(&cfg.aggregation, cfg.clients, cfg.gamma)?;
    let mut core = ServerCore::new(w0, cfg.clients, policy, cfg.mu_rho);
    let img = cfg.data.x.len() / cfg.data.len();
    let batch = cfg.learner.batch();
    let mut xs: Vec<f32> = Vec::new();
    let mut ys: Vec<i32> = Vec::new();
    let mut workers: Vec<SimWorker> = cfg
        .shards
        .iter()
        .map(|idx| SimWorker {
            cursor: BatchCursor::new(idx.clone()),
            move_idx: 0,
            pending: VecDeque::new(),
            outstanding: false,
            due: 0,
        })
        .collect();

    // What a worker does upon receiving a stamped global: train, then
    // queue the move(s) its fault schedule dictates. Mirrors
    // `net::worker::run_worker` decision for decision.
    let respond = |sim: &mut SimWorker,
                   w: usize,
                   stamp: u64,
                   params: &ParamSet,
                   xs: &mut Vec<f32>,
                   ys: &mut Vec<i32>|
     -> Result<()> {
        sim.cursor.fill(cfg.data, cfg.local_steps * batch, img, xs, ys);
        let (local, _) = cfg.learner.train(params, xs, ys, cfg.local_steps)?;
        let action = match cfg.faults {
            Some(plan) => plan.action(w, sim.move_idx),
            None => FaultAction::None,
        };
        sim.move_idx += 1;
        match action {
            FaultAction::None => sim.pending.push_back(Move::Update {
                stamp,
                params: local,
            }),
            FaultAction::Drop => sim.pending.push_back(Move::Lost { stamp }),
            FaultAction::Cut => sim.pending.push_back(Move::Broken { stamp }),
            FaultAction::Churn { rounds } => {
                sim.pending.push_back(Move::Leave { stamp, rounds });
                sim.pending.push_back(Move::Update {
                    stamp,
                    params: local,
                });
            }
        }
        Ok(())
    };

    // w0 broadcast, in worker order — exactly like the leader.
    for w in 0..cfg.clients {
        let stamp = core.issue_to(w);
        let params = core.global().clone();
        workers[w].outstanding = true;
        respond(&mut workers[w], w, stamp, &params, &mut xs, &mut ys)?;
    }

    let mut round = 0u64;
    'serve: while core.iteration() < cfg.max_iterations {
        if !workers.iter().any(|p| p.outstanding) {
            break;
        }
        let min_due = workers
            .iter()
            .filter(|p| p.outstanding)
            .map(|p| p.due)
            .min()
            .unwrap_or(round);
        if min_due > round {
            round = min_due;
        }
        let mut batch_moves: OrderedMerge<Move> = OrderedMerge::new();
        for (w, sim) in workers.iter_mut().enumerate() {
            if sim.outstanding && sim.due <= round {
                let mv = sim.pending.pop_front().expect("worker owes a move");
                batch_moves.push(mv.stamp(), w, mv);
            }
        }
        if batch_moves.is_empty() {
            break;
        }
        while let Some((_, w, mv)) = batch_moves.pop() {
            match mv {
                Move::Update { stamp, params } => {
                    core.on_update(w, stamp, &params, &NativeAggregator)?;
                    let fresh = core.issue_to(w);
                    let snapshot = core.global().clone();
                    workers[w].due = round + 1;
                    respond(&mut workers[w], w, fresh, &snapshot, &mut xs, &mut ys)?;
                }
                Move::Lost { .. } | Move::Broken { .. } => {
                    core.on_lost_upload(w);
                    let fresh = core.issue_to(w);
                    let snapshot = core.global().clone();
                    workers[w].due = round + 1;
                    respond(&mut workers[w], w, fresh, &snapshot, &mut xs, &mut ys)?;
                }
                Move::Leave { rounds, .. } => {
                    workers[w].due = round + rounds;
                }
            }
            if core.iteration() >= cfg.max_iterations {
                break 'serve;
            }
        }
        round += 1;
    }

    Ok(LeaderReport {
        aggregations: core.iteration(),
        updates_per_client: core.updates_per_client().to_vec(),
        lost_uploads: core.lost_uploads(),
        lost_per_client: core.lost_per_client().to_vec(),
        mean_staleness: core.mean_staleness(),
        wallclock_secs: 0.0,
        policy: core.policy_label(),
        final_model: core.into_global(),
    })
}
