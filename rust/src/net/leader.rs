//! The deployment leader: Algorithm 1's server over real TCP.
//!
//! Accepts `clients` workers, broadcasts w_0, then serves Update frames
//! as they arrive, feeding each into the same sans-IO
//! `coordinator::core::ServerCore` that drives the simulator — the
//! leader computes no aggregation weight of its own. The fresh global is
//! unicast back to the uploading worker only. The TCP accept/read loop
//! *is* the TDMA channel (one frame at a time per connection read).

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::core::{NativeAggregator, ServerCore};
use crate::coordinator::policy::{AggregationPolicy, PolicyParams, StalenessEq11};
use crate::log_info;
use crate::model::{ParamSet, TensorSpec};
use crate::net::wire::{self, Message};
use crate::sim::OrderedMerge;

/// Leader-side configuration.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    /// Listen address, e.g. `0.0.0.0:7070`.
    pub bind: String,
    /// Number of workers to wait for before starting.
    pub clients: usize,
    /// Stop after this many global aggregations.
    pub max_iterations: u64,
    /// Eq. (11) γ (the default policy's hyper-parameter).
    pub gamma: f64,
    /// μ EMA rate.
    pub mu_rho: f64,
    /// Aggregation-policy registry spelling; `None` = eq.-(11)
    /// staleness weighting with `gamma` (the paper's deployment).
    pub aggregation: Option<String>,
}

/// What the leader observed during a run.
#[derive(Debug, Clone)]
pub struct LeaderReport {
    /// Total global aggregations performed.
    pub aggregations: u64,
    /// Updates delivered per worker (fairness accounting).
    pub updates_per_client: Vec<u64>,
    /// Mean observed staleness across aggregations.
    pub mean_staleness: f64,
    /// Real time from first broadcast to shutdown.
    pub wallclock_secs: f64,
    /// The final global model.
    pub final_model: ParamSet,
}

enum Inbound {
    Update {
        worker: usize,
        start_iteration: u64,
        params: ParamSet,
    },
    Gone(usize),
}

/// Run the leader until `max_iterations` aggregations, then shut workers
/// down. `w0` is the initial global model (its specs define the wire
/// schema).
pub fn run_leader(cfg: &LeaderConfig, w0: ParamSet) -> Result<LeaderReport> {
    let specs: Vec<TensorSpec> = w0.specs();
    let params = PolicyParams {
        clients: cfg.clients,
        gamma: cfg.gamma,
    };
    let policy: Box<dyn AggregationPolicy> = match &cfg.aggregation {
        Some(spec) => <dyn AggregationPolicy>::parse(spec, &params)
            .with_context(|| format!("leader aggregation policy {spec:?}"))?,
        None => Box::new(StalenessEq11::new(cfg.gamma)?),
    };
    log_info!("leader: aggregation policy {}", policy.label());
    let mut core = ServerCore::new(w0, cfg.clients, policy, cfg.mu_rho);

    let listener = TcpListener::bind(&cfg.bind)
        .with_context(|| format!("binding {}", cfg.bind))?;
    log_info!("leader: listening on {}", listener.local_addr()?);

    // Accept phase: wait for exactly `clients` Hellos.
    let mut writers: Vec<BufWriter<TcpStream>> = Vec::new();
    let (tx, rx) = mpsc::channel::<Inbound>();
    for worker_id in 0..cfg.clients {
        let (stream, addr) = listener.accept()?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        let hello = wire::recv(&mut reader, &specs)?;
        match hello {
            Message::Hello { name } => {
                log_info!("leader: worker {worker_id} ({name}) from {addr}");
            }
            other => bail!("expected Hello, got {other:?}"),
        }
        writers.push(writer);
        // Reader thread: pump frames into the aggregation loop.
        let tx = tx.clone();
        let specs_c = specs.clone();
        std::thread::spawn(move || loop {
            match wire::recv(&mut reader, &specs_c) {
                Ok(Message::Update {
                    start_iteration,
                    params,
                    ..
                }) => {
                    if tx
                        .send(Inbound::Update {
                            worker: worker_id,
                            start_iteration,
                            params,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
                Ok(_) | Err(_) => {
                    let _ = tx.send(Inbound::Gone(worker_id));
                    break;
                }
            }
        });
    }
    drop(tx);

    // Broadcast w_0.
    for (worker, writer) in writers.iter_mut().enumerate() {
        let iteration = core.issue_to(worker);
        wire::send(writer, &Message::Global {
            iteration,
            params: core.global().clone(),
        })?;
    }

    // Aggregation loop (Algorithm 1, server side): every weight decision
    // happens inside ServerCore, shared bit-for-bit with the simulator.
    // Concurrent uploads are staged through the simulator's ordered
    // fan-in type (`sim::partition::OrderedMerge`): block for one
    // inbound frame, drain whatever else has already arrived, then
    // apply the burst in ascending (start iteration, worker id) order.
    // Within a drained burst, socket arrival order therefore no longer
    // decides aggregation order; burst *membership* still depends on
    // real-world timing, so this is a tie-break discipline, not the
    // sharded simulator's full determinism (which needs virtual time).
    fn stage(inbound: Inbound, staged: &mut OrderedMerge<ParamSet>, alive: &mut usize) {
        match inbound {
            Inbound::Update {
                worker,
                start_iteration,
                params,
            } => staged.push(start_iteration, worker, params),
            Inbound::Gone(worker) => {
                log_info!("leader: worker {worker} disconnected");
                *alive -= 1;
            }
        }
    }

    let started = Instant::now();
    let mut alive = cfg.clients;
    let mut staged: OrderedMerge<ParamSet> = OrderedMerge::new();
    'serve: while core.iteration() < cfg.max_iterations && alive > 0 {
        match rx.recv() {
            Ok(inbound) => stage(inbound, &mut staged, &mut alive),
            Err(_) => break,
        }
        while let Ok(inbound) = rx.try_recv() {
            stage(inbound, &mut staged, &mut alive);
        }
        while let Some((start_iteration, worker, params)) = staged.pop() {
            core.on_update(worker, start_iteration, &params, &NativeAggregator)?;
            // Fresh global back to this worker only.
            let iteration = core.issue_to(worker);
            wire::send(&mut writers[worker], &Message::Global {
                iteration,
                params: core.global().clone(),
            })?;
            if core.iteration() >= cfg.max_iterations {
                break 'serve;
            }
        }
    }

    // Shut everyone down (ignore errors from already-gone workers).
    for writer in writers.iter_mut() {
        let _ = wire::send(writer, &Message::Shutdown);
    }
    Ok(LeaderReport {
        aggregations: core.iteration(),
        updates_per_client: core.updates_per_client().to_vec(),
        mean_staleness: core.mean_staleness(),
        wallclock_secs: started.elapsed().as_secs_f64(),
        final_model: core.into_global(),
    })
}
