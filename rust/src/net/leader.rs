//! The deployment leader: Algorithm 1's server over real TCP.
//!
//! Accepts `clients` workers, broadcasts w_0, then serves Update frames
//! as they arrive: each is aggregated immediately with the eq.-(11)
//! staleness coefficient and the fresh global is unicast back to that
//! worker only. The TCP accept/read loop *is* the TDMA channel (one
//! frame at a time per connection read); arbitration across concurrently
//! pending updates follows the same oldest-model-first rule via the
//! per-worker last-service bookkeeping.

use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::staleness::{local_weight, StalenessTracker};
use crate::log_info;
use crate::model::{ParamSet, TensorSpec};
use crate::net::wire::{self, Message};

/// Leader-side configuration.
#[derive(Debug, Clone)]
pub struct LeaderConfig {
    /// Listen address, e.g. `0.0.0.0:7070`.
    pub bind: String,
    /// Number of workers to wait for before starting.
    pub clients: usize,
    /// Stop after this many global aggregations.
    pub max_iterations: u64,
    /// Eq. (11) γ.
    pub gamma: f64,
    /// μ EMA rate.
    pub mu_rho: f64,
}

/// What the leader observed during a run.
#[derive(Debug, Clone)]
pub struct LeaderReport {
    /// Total global aggregations performed.
    pub aggregations: u64,
    /// Updates delivered per worker (fairness accounting).
    pub updates_per_client: Vec<u64>,
    /// Mean observed staleness across aggregations.
    pub mean_staleness: f64,
    /// Real time from first broadcast to shutdown.
    pub wallclock_secs: f64,
    /// The final global model.
    pub final_model: ParamSet,
}

enum Inbound {
    Update {
        worker: usize,
        start_iteration: u64,
        params: ParamSet,
    },
    Gone(usize),
}

/// Run the leader until `max_iterations` aggregations, then shut workers
/// down. `w0` is the initial global model (its specs define the wire
/// schema).
pub fn run_leader(cfg: &LeaderConfig, w0: ParamSet) -> Result<LeaderReport> {
    let specs: Vec<TensorSpec> = w0.specs();
    let listener = TcpListener::bind(&cfg.bind)
        .with_context(|| format!("binding {}", cfg.bind))?;
    log_info!("leader: listening on {}", listener.local_addr()?);

    // Accept phase: wait for exactly `clients` Hellos.
    let mut writers: Vec<BufWriter<TcpStream>> = Vec::new();
    let (tx, rx) = mpsc::channel::<Inbound>();
    for worker_id in 0..cfg.clients {
        let (stream, addr) = listener.accept()?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        let hello = wire::recv(&mut reader, &specs)?;
        match hello {
            Message::Hello { name } => {
                log_info!("leader: worker {worker_id} ({name}) from {addr}");
            }
            other => bail!("expected Hello, got {other:?}"),
        }
        writers.push(writer);
        // Reader thread: pump frames into the aggregation loop.
        let tx = tx.clone();
        let specs_c = specs.clone();
        std::thread::spawn(move || loop {
            match wire::recv(&mut reader, &specs_c) {
                Ok(Message::Update {
                    start_iteration,
                    params,
                    ..
                }) => {
                    if tx
                        .send(Inbound::Update {
                            worker: worker_id,
                            start_iteration,
                            params,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
                Ok(_) | Err(_) => {
                    let _ = tx.send(Inbound::Gone(worker_id));
                    break;
                }
            }
        });
    }
    drop(tx);

    // Broadcast w_0.
    let mut w = w0;
    for writer in writers.iter_mut() {
        wire::send(writer, &Message::Global {
            iteration: 0,
            params: w.clone(),
        })?;
    }

    // Aggregation loop (Algorithm 1, server side).
    let started = Instant::now();
    let mut tracker = StalenessTracker::new(cfg.mu_rho);
    let mut j: u64 = 0;
    let mut staleness_sum = 0.0f64;
    let mut per_client = vec![0u64; cfg.clients];
    let mut alive = cfg.clients;
    while j < cfg.max_iterations && alive > 0 {
        match rx.recv() {
            Ok(Inbound::Update {
                worker,
                start_iteration,
                params,
            }) => {
                let staleness = j.saturating_sub(start_iteration);
                let weight = local_weight(tracker.mu(), cfg.gamma, j + 1, staleness);
                tracker.observe(staleness);
                staleness_sum += staleness as f64;
                w.lerp_inplace(&params, (1.0 - weight) as f32);
                j += 1;
                per_client[worker] += 1;
                // Fresh global back to this worker only.
                wire::send(&mut writers[worker], &Message::Global {
                    iteration: j,
                    params: w.clone(),
                })?;
            }
            Ok(Inbound::Gone(worker)) => {
                log_info!("leader: worker {worker} disconnected");
                alive -= 1;
            }
            Err(_) => break,
        }
    }

    // Shut everyone down (ignore errors from already-gone workers).
    for writer in writers.iter_mut() {
        let _ = wire::send(writer, &Message::Shutdown);
    }
    Ok(LeaderReport {
        aggregations: j,
        updates_per_client: per_client,
        mean_staleness: if j > 0 { staleness_sum / j as f64 } else { 0.0 },
        wallclock_secs: started.elapsed().as_secs_f64(),
        final_model: w,
    })
}
