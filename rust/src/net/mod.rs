//! Distributed deployment runtime: the CSMAAFL server and clients as real
//! processes talking length-prefixed binary frames over TCP.
//!
//! The simulator (`sim/`) reproduces the paper's *virtual-time* results;
//! this module is the deployment face of the same coordinator logic:
//! a leader owns the global model, grants upload slots with the same
//! oldest-model-first policy, aggregates with the same eq.-(11) staleness
//! rule, and unicasts the fresh global back to the uploading client —
//! Algorithm 1 over real sockets. Workers run the PJRT CNN (or the linear
//! learner) on their own shard.
//!
//! Protocol (`wire.rs`): hand-rolled frames (the dependency-minimal
//! build has no serde): `[u32 len][u8 tag][payload]`, tensors as raw
//! little-endian
//! f32 runs validated against the manifest's shapes.

pub mod leader;
pub mod wire;
pub mod worker;

pub use leader::{run_leader, LeaderConfig, LeaderReport};
pub use worker::{run_worker, WorkerConfig};
