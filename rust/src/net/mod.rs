//! Distributed deployment runtime: the CSMAAFL server and clients as real
//! processes talking length-prefixed binary frames over TCP.
//!
//! The simulator (`sim/`) reproduces the paper's *virtual-time* results;
//! this module is the deployment face of the same coordinator logic:
//! a leader owns the global model, grants upload slots with the same
//! oldest-model-first policy, aggregates with the same eq.-(11) staleness
//! rule, and unicasts the fresh global back to the uploading client —
//! Algorithm 1 over real sockets. Workers run the PJRT CNN (or the linear
//! learner) on their own shard.
//!
//! The leader ingests through K shard threads reusing the simulator's
//! `ClientPartition`/`OrderedMerge` split (see `leader`), absorbs
//! disconnects, stalls, and churn as first-class events, and — in
//! lockstep mode — is bit-identical across shard counts and to the
//! sans-IO [`run_reference`] replay. Fault schedules come from the
//! seeded, replayable [`FaultPlan`] (see `fault`).
//!
//! Protocol (`wire.rs`): hand-rolled frames (the dependency-minimal
//! build has no serde): `[u32 len][u8 version][u8 tag][payload]` with an
//! explicit version byte and a hard frame-length cap, tensors as raw
//! little-endian f32 runs validated against the manifest's shapes.
//! Malformed input surfaces as typed [`wire::WireError`]s, never a
//! panic — `tests/wire_proptest.rs` throws 100k+ adversarial frames at
//! the parser to keep it that way.

pub mod fault;
pub mod leader;
pub mod wire;
pub mod worker;

pub use fault::{FaultAction, FaultPlan};
pub use leader::{run_leader, run_reference, LeaderConfig, LeaderReport, ReferenceConfig};
pub use worker::{run_worker, WorkerConfig};
