//! Wire protocol for the TCP deployment runtime.
//!
//! Frames: `[u32 LE frame-len][u8 version][u8 tag][payload]`, where
//! `frame-len` counts the version byte, the tag byte and the payload.
//! Parameter sets travel as a u32 tensor count followed by, per tensor,
//! a u32 element count and that many little-endian f32s; shapes are
//! validated against the receiver's expected specs (the manifest is the
//! schema — the wire carries no redundant metadata).
//!
//! Every way a frame can be refused is a typed [`WireError`] variant:
//! the length prefix is checked against [`MAX_FRAME`] before any
//! allocation, the version byte is checked before the tag, and the
//! parser never panics on arbitrary bytes (`tests/wire_proptest.rs`
//! throws ≥100k adversarial frames at it to keep that true).
//!
//! Two readers share one decoder:
//! * [`recv`] — blocking, for the worker's simple request/response loop;
//! * [`FrameReader`] — incremental, for the leader's ingest shards,
//!   which multiplex many nonblocking connections and need to resume a
//!   partially-read frame on the next poll (and to notice a connection
//!   that stalls *mid-frame*, the server-side timeout path).

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use crate::model::{ParamSet, Tensor, TensorSpec};

/// The protocol version this build speaks. A peer announcing any other
/// version is rejected with [`WireError::UnsupportedVersion`] before its
/// tag byte is even looked at.
pub const WIRE_VERSION: u8 = 1;

/// Hard cap on frame size (128 MiB) — hostile or corrupt length
/// prefixes are refused with [`WireError::FrameTooLarge`] before any
/// buffer is allocated.
pub const MAX_FRAME: u32 = 128 << 20;

/// Everything that can go wrong reading or decoding a frame.
#[derive(Debug)]
pub enum WireError {
    /// The length prefix exceeds [`MAX_FRAME`].
    FrameTooLarge {
        /// The announced frame length.
        len: u32,
        /// The enforced cap ([`MAX_FRAME`]).
        max: u32,
    },
    /// The length prefix was zero (no room for version + tag).
    EmptyFrame,
    /// The version byte is not [`WIRE_VERSION`].
    UnsupportedVersion {
        /// The version the peer announced.
        version: u8,
    },
    /// The tag byte maps to no known [`Tag`].
    UnknownTag {
        /// The unrecognized tag byte.
        tag: u8,
    },
    /// The payload ended before the message's fixed fields did.
    Truncated,
    /// The payload continued past the message's last field.
    TrailingBytes {
        /// Bytes consumed by the decoder.
        used: usize,
        /// Bytes the frame actually carried.
        len: usize,
    },
    /// A parameter block's tensor count disagrees with the schema.
    TensorCountMismatch {
        /// Tensor count announced on the wire.
        got: u32,
        /// Tensor count the receiver's specs expect.
        expected: usize,
    },
    /// One tensor's element count disagrees with the schema.
    TensorLenMismatch {
        /// Name of the offending tensor (from the receiver's specs).
        name: String,
        /// Element count announced on the wire.
        got: u32,
        /// Element count the spec expects.
        expected: usize,
    },
    /// A Hello name was not valid UTF-8.
    BadUtf8,
    /// The peer closed the connection.
    Closed {
        /// True when the close landed in the middle of a frame (a lost
        /// in-flight upload rather than a clean between-frames exit).
        mid_frame: bool,
    },
    /// An underlying I/O failure other than close/timeout.
    Io(std::io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::FrameTooLarge { len, max } => {
                write!(f, "frame length {len} exceeds the {max}-byte limit")
            }
            WireError::EmptyFrame => write!(f, "zero-length frame"),
            WireError::UnsupportedVersion { version } => write!(
                f,
                "unsupported wire protocol version {version} (this build speaks {WIRE_VERSION})"
            ),
            WireError::UnknownTag { tag } => write!(f, "unknown wire tag {tag}"),
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::TrailingBytes { used, len } => {
                write!(f, "trailing bytes in frame ({used} of {len} consumed)")
            }
            WireError::TensorCountMismatch { got, expected } => {
                write!(f, "wire params: {got} tensors, expected {expected}")
            }
            WireError::TensorLenMismatch { name, got, expected } => {
                write!(f, "wire tensor {name}: {got} elems, expected {expected}")
            }
            WireError::BadUtf8 => write!(f, "hello name is not valid utf-8"),
            WireError::Closed { mid_frame: true } => write!(f, "connection closed mid-frame"),
            WireError::Closed { mid_frame: false } => write!(f, "connection closed"),
            WireError::Io(e) => write!(f, "wire i/o: {e}"),
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WireError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// Message tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    /// worker -> leader: join (or rejoin) the federation.
    Hello = 1,
    /// leader -> worker: initial/fresh global model + iteration stamp.
    Global = 2,
    /// worker -> leader: trained local model + the iteration it started
    /// from + local step count.
    Update = 3,
    /// leader -> worker: training is over; final stats follow.
    Shutdown = 4,
    /// worker -> leader: an upload was lost in transit (socket-layer
    /// fault injection reporting in-band, so accounting stays exact).
    Lost = 5,
    /// worker -> leader: churn announcement — the worker is
    /// disconnecting and will return with its (now stale) model.
    Leave = 6,
    /// worker -> leader: delta-encoded trained local model — the
    /// payload carries `local ⊕ base` XOR bitpatterns against the
    /// global the leader issued at `start_iteration` (see
    /// [`delta_params`]). A build that predates this tag rejects it
    /// with the usual typed [`WireError::UnknownTag`], which is the
    /// version negotiation: delta senders are only spawned against
    /// leaders that advertise the same [`WIRE_VERSION`].
    DeltaUpdate = 7,
}

impl Tag {
    /// Decode a frame's tag byte; unknown tags are a typed error.
    pub fn from_u8(b: u8) -> Result<Tag, WireError> {
        Ok(match b {
            1 => Tag::Hello,
            2 => Tag::Global,
            3 => Tag::Update,
            4 => Tag::Shutdown,
            5 => Tag::Lost,
            6 => Tag::Leave,
            7 => Tag::DeltaUpdate,
            tag => return Err(WireError::UnknownTag { tag }),
        })
    }
}

/// A decoded message.
#[derive(Debug)]
pub enum Message {
    /// worker → leader: join (or rejoin) the federation.
    Hello {
        /// Stable worker id — the leader keys all per-client state on
        /// it, so a reconnecting worker resumes its own bookkeeping.
        worker: u32,
        /// Human-readable worker name (logging only).
        name: String,
    },
    /// leader → worker: a global model stamped with its iteration.
    Global {
        /// Global aggregation count when this model was sent.
        iteration: u64,
        /// The global model parameters.
        params: ParamSet,
    },
    /// worker → leader: a trained local model.
    Update {
        /// The global iteration the worker trained from (staleness base).
        start_iteration: u64,
        /// Local SGD steps the worker ran.
        steps: u32,
        /// The updated local model parameters.
        params: ParamSet,
    },
    /// leader → worker: training is over, disconnect.
    Shutdown,
    /// worker → leader: the upload for this round was lost in transit.
    Lost {
        /// The iteration stamp the lost upload trained from.
        start_iteration: u64,
    },
    /// worker → leader: churn — going away, returning with a stale model.
    Leave {
        /// The iteration stamp of the model the worker still holds.
        start_iteration: u64,
        /// How many leader rounds the worker will sit out (≥ 1).
        rounds: u64,
    },
    /// worker → leader: a trained local model, delta-encoded against
    /// the issued global. The leader reconstructs the local model with
    /// [`apply_delta`] over its retained copy of the `start_iteration`
    /// global it shipped to this worker.
    DeltaUpdate {
        /// The global iteration the worker trained from — both the
        /// staleness base and the delta base.
        start_iteration: u64,
        /// Local SGD steps the worker ran.
        steps: u32,
        /// XOR bitpatterns `local ⊕ base`, shaped like the model.
        params: ParamSet,
    },
}

/// XOR-bitpattern delta `local ⊕ base`, per f32 on the raw bits.
/// Unlike an arithmetic difference (where `(l - b) + b ≠ l` in f32),
/// XOR reconstruction is *exact*: [`apply_delta`] returns `local` bit
/// for bit, so a delta-encoded upload aggregates identically to a full
/// one. Panics on layout mismatch — the sender deltas against its own
/// download, so the shapes agree by construction.
pub fn delta_params(local: &ParamSet, base: &ParamSet) -> ParamSet {
    xor_params(local, base)
}

/// Invert [`delta_params`]: `delta ⊕ base` = the original local model,
/// exactly (XOR is its own inverse).
pub fn apply_delta(delta: &ParamSet, base: &ParamSet) -> ParamSet {
    xor_params(delta, base)
}

fn xor_params(a: &ParamSet, b: &ParamSet) -> ParamSet {
    assert_eq!(a.tensors.len(), b.tensors.len(), "delta layout mismatch");
    let tensors = a
        .tensors
        .iter()
        .zip(&b.tensors)
        .map(|(ta, tb)| {
            assert_eq!(
                ta.data.len(),
                tb.data.len(),
                "delta tensor {} length mismatch",
                ta.spec.name
            );
            let data = ta
                .data
                .iter()
                .zip(&tb.data)
                .map(|(x, y)| f32::from_bits(x.to_bits() ^ y.to_bits()))
                .collect();
            Tensor::from_data(ta.spec.clone(), data)
        })
        .collect();
    ParamSet { tensors }
}

// ------------------------------------------------------------ encoding

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_params(buf: &mut Vec<u8>, p: &ParamSet) {
    put_u32(buf, p.tensors.len() as u32);
    for t in &p.tensors {
        put_u32(buf, t.data.len() as u32);
        for v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Exact frame-body length (version byte + tag byte + payload) of the
/// largest model-carrying message — an [`Message::Update`] — for a
/// given tensor layout. Senders check this against [`MAX_FRAME`] once,
/// up front, so an oversized model fails fast with a clear error
/// instead of a per-send failure the receiver would only see as a
/// rejected frame.
pub fn model_frame_len(specs: &[TensorSpec]) -> u64 {
    let params: u64 = 4 + specs
        .iter()
        .map(|s| 4 + 4 * s.numel() as u64)
        .sum::<u64>();
    // version + tag + start_iteration (u64) + steps (u32) + params.
    2 + 8 + 4 + params
}

/// Total bytes on the wire — the 4-byte length prefix included — of an
/// upload frame ([`Message::Update`] or the same-sized
/// [`Message::DeltaUpdate`]) carrying one flat tensor of `numel` f32s.
/// The scale simulators' `bytes_on_wire` meter: their synthetic model
/// is a single flat tensor, and this pins the metric to the real frame
/// format instead of a made-up `4·numel`.
pub fn flat_update_wire_bytes(numel: usize) -> u64 {
    // prefix + version + tag + start_iteration (u64) + steps (u32)
    // + tensor count (u32) + element count (u32) + data.
    4 + 2 + 8 + 4 + 4 + 4 + 4 * numel as u64
}

/// Encode a message into a ready-to-send frame (length prefix,
/// [`WIRE_VERSION`], tag, payload).
///
/// Panics if the frame body would exceed [`MAX_FRAME`]: a receiver
/// would reject such a frame anyway (and a length over `u32::MAX`
/// could not even be framed), so the sender fails fast here rather
/// than emitting a stream every peer tears down. Runtime paths guard
/// this with [`model_frame_len`] before any socket work starts.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut payload = Vec::new();
    let tag = match msg {
        Message::Hello { worker, name } => {
            put_u32(&mut payload, *worker);
            payload.extend_from_slice(name.as_bytes());
            Tag::Hello
        }
        Message::Global { iteration, params } => {
            put_u64(&mut payload, *iteration);
            put_params(&mut payload, params);
            Tag::Global
        }
        Message::Update {
            start_iteration,
            steps,
            params,
        } => {
            put_u64(&mut payload, *start_iteration);
            put_u32(&mut payload, *steps);
            put_params(&mut payload, params);
            Tag::Update
        }
        Message::Shutdown => Tag::Shutdown,
        Message::Lost { start_iteration } => {
            put_u64(&mut payload, *start_iteration);
            Tag::Lost
        }
        Message::Leave {
            start_iteration,
            rounds,
        } => {
            put_u64(&mut payload, *start_iteration);
            put_u64(&mut payload, *rounds);
            Tag::Leave
        }
        Message::DeltaUpdate {
            start_iteration,
            steps,
            params,
        } => {
            put_u64(&mut payload, *start_iteration);
            put_u32(&mut payload, *steps);
            put_params(&mut payload, params);
            Tag::DeltaUpdate
        }
    };
    // Length arithmetic in usize: `as u32` on a >4 GiB payload would
    // silently truncate the prefix and mis-frame the whole stream.
    let body_len = payload.len() + 2;
    assert!(
        body_len <= MAX_FRAME as usize,
        "wire: refusing to encode a {tag:?} frame of {body_len} bytes \
         (MAX_FRAME is {MAX_FRAME}); every receiver would reject it"
    );
    let mut frame = Vec::with_capacity(payload.len() + 6);
    put_u32(&mut frame, body_len as u32);
    frame.push(WIRE_VERSION);
    frame.push(tag as u8);
    frame.extend_from_slice(&payload);
    frame
}

// ------------------------------------------------------------ decoding

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if n > self.buf.len() - self.pos {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn params(&mut self, specs: &[TensorSpec]) -> Result<ParamSet, WireError> {
        let n = self.u32()?;
        if n as usize != specs.len() {
            return Err(WireError::TensorCountMismatch {
                got: n,
                expected: specs.len(),
            });
        }
        let mut tensors = Vec::with_capacity(n as usize);
        for spec in specs {
            let len = self.u32()?;
            if len as usize != spec.numel() {
                return Err(WireError::TensorLenMismatch {
                    name: spec.name.clone(),
                    got: len,
                    expected: spec.numel(),
                });
            }
            let raw = self.take(len as usize * 4)?;
            let mut data = Vec::with_capacity(len as usize);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            tensors.push(Tensor::from_data(spec.clone(), data));
        }
        Ok(ParamSet { tensors })
    }
}

/// Decode one frame body (version byte + tag byte + payload). `specs`
/// is the expected tensor layout for messages that carry parameters.
pub fn decode(payload: &[u8], specs: &[TensorSpec]) -> Result<Message, WireError> {
    if payload.is_empty() {
        return Err(WireError::EmptyFrame);
    }
    let version = payload[0];
    if version != WIRE_VERSION {
        return Err(WireError::UnsupportedVersion { version });
    }
    if payload.len() < 2 {
        return Err(WireError::Truncated);
    }
    let tag = Tag::from_u8(payload[1])?;
    let mut c = Cursor {
        buf: payload,
        pos: 2,
    };
    let msg = match tag {
        Tag::Hello => {
            let worker = c.u32()?;
            let name = String::from_utf8(c.rest().to_vec()).map_err(|_| WireError::BadUtf8)?;
            Message::Hello { worker, name }
        }
        Tag::Global => Message::Global {
            iteration: c.u64()?,
            params: c.params(specs)?,
        },
        Tag::Update => Message::Update {
            start_iteration: c.u64()?,
            steps: c.u32()?,
            params: c.params(specs)?,
        },
        Tag::Shutdown => Message::Shutdown,
        Tag::Lost => Message::Lost {
            start_iteration: c.u64()?,
        },
        Tag::Leave => Message::Leave {
            start_iteration: c.u64()?,
            rounds: c.u64()?,
        },
        Tag::DeltaUpdate => Message::DeltaUpdate {
            start_iteration: c.u64()?,
            steps: c.u32()?,
            params: c.params(specs)?,
        },
    };
    if c.pos != payload.len() {
        return Err(WireError::TrailingBytes {
            used: c.pos,
            len: payload.len(),
        });
    }
    Ok(msg)
}

// ------------------------------------------------------- stream access

/// Write one frame to a stream.
pub fn send(stream: &mut impl Write, msg: &Message) -> Result<(), WireError> {
    let frame = encode(msg);
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(())
}

/// Write one frame to a stream that may be nonblocking (the leader's
/// per-worker write handles share their socket — and therefore its
/// nonblocking flag — with the ingest shard's read handle).
///
/// `WouldBlock` is *not* an error here: it means the socket buffer is
/// full, so the writer parks briefly and resumes from the same offset —
/// partial progress is kept, never abandoned mid-frame. Only a real I/O
/// failure, a closed peer, or `stall` elapsing with zero forward
/// progress (a peer that stopped draining) is reported, as
/// [`WireError::Io`]; callers may then treat the connection as dead.
/// `stall == None` retries indefinitely.
pub fn send_retrying(
    stream: &mut impl Write,
    msg: &Message,
    stall: Option<Duration>,
) -> Result<(), WireError> {
    let frame = encode(msg);
    let mut off = 0usize;
    let mut last_progress = Instant::now();
    while off < frame.len() {
        match stream.write(&frame[off..]) {
            Ok(0) => {
                return Err(WireError::Io(std::io::ErrorKind::WriteZero.into()));
            }
            Ok(n) => {
                off += n;
                last_progress = Instant::now();
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stall.is_some_and(|limit| last_progress.elapsed() >= limit) {
                    return Err(WireError::Io(std::io::ErrorKind::TimedOut.into()));
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    stream.flush()?;
    Ok(())
}

fn read_exact_wire(
    stream: &mut impl Read,
    buf: &mut [u8],
    mid_frame: bool,
) -> Result<(), WireError> {
    match stream.read_exact(buf) {
        Ok(()) => Ok(()),
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => {
            Err(WireError::Closed { mid_frame })
        }
        Err(e) => Err(WireError::Io(e)),
    }
}

/// Check a frame's announced length against the protocol limits.
fn check_len(len: u32) -> Result<(), WireError> {
    if len == 0 {
        return Err(WireError::EmptyFrame);
    }
    if len > MAX_FRAME {
        return Err(WireError::FrameTooLarge {
            len,
            max: MAX_FRAME,
        });
    }
    Ok(())
}

/// Blocking read of one raw frame body (version + tag + payload).
pub fn recv_frame(stream: &mut impl Read) -> Result<Vec<u8>, WireError> {
    let mut len_buf = [0u8; 4];
    read_exact_wire(stream, &mut len_buf, false)?;
    let len = u32::from_le_bytes(len_buf);
    check_len(len)?;
    let mut payload = vec![0u8; len as usize];
    read_exact_wire(stream, &mut payload, true)?;
    Ok(payload)
}

/// Blocking read of one frame from a stream.
pub fn recv(stream: &mut impl Read, specs: &[TensorSpec]) -> Result<Message, WireError> {
    let payload = recv_frame(stream)?;
    decode(&payload, specs)
}

/// Incremental frame reader for nonblocking / read-timeout sockets.
///
/// [`FrameReader::poll`] pulls whatever bytes the stream has,
/// accumulating a frame across calls: `Ok(Some(body))` when a complete
/// frame body is buffered, `Ok(None)` when the stream would block (or
/// its read timeout expired) before one completed. The length prefix is
/// validated against [`MAX_FRAME`] the moment its 4 bytes are in, so a
/// hostile length never allocates. The leader's ingest shards keep one
/// reader per connection and use [`FrameReader::mid_frame`] +
/// [`FrameReader::buffered`] to detect connections stalling in the
/// middle of an upload (the per-connection deadline path).
#[derive(Debug)]
pub struct FrameReader {
    buf: Vec<u8>,
    filled: usize,
}

impl Default for FrameReader {
    fn default() -> Self {
        Self::new()
    }
}

impl FrameReader {
    /// A reader with no buffered bytes.
    pub fn new() -> FrameReader {
        FrameReader {
            buf: vec![0; 4],
            filled: 0,
        }
    }

    /// True when a frame has started arriving but is not complete.
    pub fn mid_frame(&self) -> bool {
        self.filled > 0
    }

    /// Bytes of the in-progress frame buffered so far (progress signal
    /// for stall deadlines).
    pub fn buffered(&self) -> usize {
        self.filled
    }

    /// Total bytes the in-progress frame needs (4 until the length
    /// prefix is complete).
    fn target(&self) -> Result<usize, WireError> {
        if self.filled < 4 {
            return Ok(4);
        }
        let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap());
        check_len(len)?;
        Ok(4 + len as usize)
    }

    /// Pull available bytes from `stream`; yield a complete frame body
    /// if one finished. See the type docs for the contract.
    pub fn poll(&mut self, stream: &mut impl Read) -> Result<Option<Vec<u8>>, WireError> {
        loop {
            let target = self.target()?;
            if self.buf.len() < target {
                self.buf.resize(target, 0);
            }
            if self.filled == target && target > 4 {
                let body = self.buf[4..target].to_vec();
                self.buf.clear();
                self.buf.resize(4, 0);
                self.filled = 0;
                return Ok(Some(body));
            }
            match stream.read(&mut self.buf[self.filled..target]) {
                Ok(0) => {
                    return Err(WireError::Closed {
                        mid_frame: self.filled > 0,
                    })
                }
                Ok(n) => self.filled += n,
                Err(e) => {
                    return match e.kind() {
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => Ok(None),
                        std::io::ErrorKind::Interrupted => continue,
                        _ => Err(WireError::Io(e)),
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![2, 3],
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![4],
            },
        ]
    }

    fn pset() -> ParamSet {
        ParamSet {
            tensors: vec![
                Tensor::from_data(specs()[0].clone(), vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25]),
                Tensor::from_data(specs()[1].clone(), vec![0.1, 0.2, 0.3, 0.4]),
            ],
        }
    }

    fn roundtrip(msg: &Message) -> Message {
        let frame = encode(msg);
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        assert_eq!(frame[4], WIRE_VERSION);
        let decoded = decode(&frame[4..], &specs()).unwrap();
        // Byte-for-byte: re-encoding a decoded frame reproduces it.
        assert_eq!(encode(&decoded), frame);
        decoded
    }

    #[test]
    fn hello_roundtrip() {
        match roundtrip(&Message::Hello {
            worker: 7,
            name: "client-7 ü".into(),
        }) {
            Message::Hello { worker, name } => {
                assert_eq!(worker, 7);
                assert_eq!(name, "client-7 ü");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_roundtrip_bitexact() {
        match roundtrip(&Message::Global {
            iteration: 12345678901,
            params: pset(),
        }) {
            Message::Global { iteration, params } => {
                assert_eq!(iteration, 12345678901);
                assert_eq!(params, pset());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn update_roundtrip() {
        match roundtrip(&Message::Update {
            start_iteration: 42,
            steps: 16,
            params: pset(),
        }) {
            Message::Update {
                start_iteration,
                steps,
                params,
            } => {
                assert_eq!((start_iteration, steps), (42, 16));
                assert_eq!(params, pset());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shutdown_lost_leave_roundtrip() {
        assert!(matches!(roundtrip(&Message::Shutdown), Message::Shutdown));
        assert!(matches!(
            roundtrip(&Message::Lost { start_iteration: 9 }),
            Message::Lost { start_iteration: 9 }
        ));
        assert!(matches!(
            roundtrip(&Message::Leave {
                start_iteration: 5,
                rounds: 3
            }),
            Message::Leave {
                start_iteration: 5,
                rounds: 3
            }
        ));
    }

    #[test]
    fn delta_update_roundtrip() {
        let base = pset();
        let mut local = pset();
        local.tensors[0].data[2] = 7.25;
        local.tensors[1].data[0] = -0.75;
        let delta = delta_params(&local, &base);
        match roundtrip(&Message::DeltaUpdate {
            start_iteration: 42,
            steps: 16,
            params: delta.clone(),
        }) {
            Message::DeltaUpdate {
                start_iteration,
                steps,
                params,
            } => {
                assert_eq!((start_iteration, steps), (42, 16));
                // The decoded delta reconstructs the local model bit
                // for bit — the property f32 subtraction cannot give.
                let rebuilt = apply_delta(&params, &base);
                for (a, b) in rebuilt
                    .tensors
                    .iter()
                    .flat_map(|t| &t.data)
                    .zip(local.tensors.iter().flat_map(|t| &t.data))
                {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn delta_of_identical_models_is_all_zero_bits() {
        let d = delta_params(&pset(), &pset());
        assert!(d.tensors.iter().flat_map(|t| &t.data).all(|v| v.to_bits() == 0));
        // ...and applying it is the identity.
        let back = apply_delta(&d, &pset());
        assert_eq!(back, pset());
    }

    #[test]
    fn delta_survives_non_finite_and_negative_zero_values() {
        let mut local = pset();
        local.tensors[0].data[0] = f32::NAN;
        local.tensors[0].data[1] = f32::INFINITY;
        local.tensors[1].data[3] = -0.0;
        let rebuilt = apply_delta(&delta_params(&local, &pset()), &pset());
        for (a, b) in rebuilt
            .tensors
            .iter()
            .flat_map(|t| &t.data)
            .zip(local.tensors.iter().flat_map(|t| &t.data))
        {
            assert_eq!(a.to_bits(), b.to_bits(), "NaN/Inf/-0.0 must survive");
        }
    }

    #[test]
    fn flat_update_wire_bytes_matches_encoded_frames() {
        for numel in [1usize, 64, 5370] {
            let spec = TensorSpec {
                name: "w".into(),
                shape: vec![numel],
            };
            let params = ParamSet {
                tensors: vec![Tensor::from_data(spec, vec![0.5; numel])],
            };
            let full = encode(&Message::Update {
                start_iteration: 3,
                steps: 2,
                params: params.clone(),
            });
            let delta = encode(&Message::DeltaUpdate {
                start_iteration: 3,
                steps: 2,
                params,
            });
            assert_eq!(flat_update_wire_bytes(numel), full.len() as u64, "{numel}");
            assert_eq!(full.len(), delta.len(), "delta frames are the same size");
        }
    }

    #[test]
    fn rejects_wrong_shape() {
        let frame = encode(&Message::Global {
            iteration: 1,
            params: pset(),
        });
        let bad_specs = vec![TensorSpec {
            name: "w".into(),
            shape: vec![7],
        }];
        assert!(matches!(
            decode(&frame[4..], &bad_specs),
            Err(WireError::TensorCountMismatch { got: 2, expected: 1 })
        ));
    }

    #[test]
    fn rejects_garbage_with_typed_errors() {
        assert!(matches!(decode(&[], &specs()), Err(WireError::EmptyFrame)));
        assert!(matches!(
            decode(&[WIRE_VERSION], &specs()),
            Err(WireError::Truncated)
        ));
        assert!(matches!(
            decode(&[WIRE_VERSION, 99, 0, 0], &specs()),
            Err(WireError::UnknownTag { tag: 99 })
        ));
        // Truncated Global.
        assert!(matches!(
            decode(&[WIRE_VERSION, 2, 1, 2, 3], &specs()),
            Err(WireError::Truncated)
        ));
        // Trailing bytes after a Shutdown.
        assert!(matches!(
            decode(&[WIRE_VERSION, 4, 0], &specs()),
            Err(WireError::TrailingBytes { used: 2, len: 3 })
        ));
    }

    #[test]
    fn rejects_unknown_version_before_tag() {
        // Even a frame whose tag byte is garbage reports the version
        // mismatch first: version negotiation precedes interpretation.
        assert!(matches!(
            decode(&[9, 255, 1, 2], &specs()),
            Err(WireError::UnsupportedVersion { version: 9 })
        ));
        assert!(matches!(
            decode(&[0], &specs()),
            Err(WireError::UnsupportedVersion { version: 0 })
        ));
    }

    #[test]
    fn recv_rejects_oversized_and_empty_lengths() {
        let mut over = std::io::Cursor::new((MAX_FRAME + 1).to_le_bytes().to_vec());
        assert!(matches!(
            recv(&mut over, &specs()),
            Err(WireError::FrameTooLarge { .. })
        ));
        let mut zero = std::io::Cursor::new(0u32.to_le_bytes().to_vec());
        assert!(matches!(recv(&mut zero, &specs()), Err(WireError::EmptyFrame)));
    }

    #[test]
    fn stream_send_recv() {
        let mut buf: Vec<u8> = Vec::new();
        send(&mut buf, &Message::Update {
            start_iteration: 9,
            steps: 3,
            params: pset(),
        })
        .unwrap();
        send(&mut buf, &Message::Shutdown).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(
            recv(&mut r, &specs()).unwrap(),
            Message::Update { steps: 3, .. }
        ));
        assert!(matches!(recv(&mut r, &specs()).unwrap(), Message::Shutdown));
        // A clean EOF between frames is Closed { mid_frame: false }.
        assert!(matches!(
            recv(&mut r, &specs()),
            Err(WireError::Closed { mid_frame: false })
        ));
    }

    /// A reader that hands out one byte per call, then WouldBlock, to
    /// force the FrameReader through every resumption point.
    struct Trickle {
        data: Vec<u8>,
        pos: usize,
        ready: bool,
    }

    impl Read for Trickle {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            if !self.ready {
                self.ready = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.ready = false;
            buf[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn frame_reader_resumes_across_would_block() {
        let mut bytes = encode(&Message::Update {
            start_iteration: 4,
            steps: 2,
            params: pset(),
        });
        bytes.extend_from_slice(&encode(&Message::Shutdown));
        let total = bytes.len();
        let mut stream = Trickle {
            data: bytes,
            pos: 0,
            ready: false,
        };
        let mut reader = FrameReader::new();
        let mut frames = Vec::new();
        let mut polls = 0usize;
        while frames.len() < 2 {
            polls += 1;
            assert!(polls < 8 * total, "reader made no progress");
            if let Some(body) = reader.poll(&mut stream).unwrap() {
                frames.push(decode(&body, &specs()).unwrap());
            }
        }
        assert!(matches!(frames[0], Message::Update { steps: 2, .. }));
        assert!(matches!(frames[1], Message::Shutdown));
        assert!(!reader.mid_frame());
    }

    #[test]
    fn frame_reader_reports_mid_frame_close_and_stall() {
        let full = encode(&Message::Lost { start_iteration: 3 });
        // Close after half the frame: Closed { mid_frame: true }.
        let mut half = std::io::Cursor::new(full[..full.len() / 2].to_vec());
        let mut reader = FrameReader::new();
        loop {
            match reader.poll(&mut half) {
                Ok(Some(_)) => panic!("frame cannot complete"),
                Ok(None) => continue,
                Err(e) => {
                    assert!(matches!(e, WireError::Closed { mid_frame: true }), "{e}");
                    break;
                }
            }
        }
        // A stalled (WouldBlock) half-frame is visible via mid_frame().
        let mut stream = Trickle {
            data: full[..full.len() / 2].to_vec(),
            pos: 0,
            ready: false,
        };
        let mut reader = FrameReader::new();
        for _ in 0..full.len() * 4 {
            match reader.poll(&mut stream) {
                Ok(None) => {}
                other => {
                    let _ = other;
                }
            }
            if stream.pos >= stream.data.len() {
                break;
            }
        }
        assert!(reader.mid_frame());
        assert_eq!(reader.buffered(), full.len() / 2);
    }

    #[test]
    fn model_frame_len_matches_encoded_update() {
        let frame = encode(&Message::Update {
            start_iteration: 1,
            steps: 1,
            params: pset(),
        });
        // Frame body = everything after the 4-byte length prefix.
        assert_eq!(model_frame_len(&specs()), (frame.len() - 4) as u64);
    }

    #[test]
    #[should_panic(expected = "refusing to encode")]
    fn encode_refuses_bodies_over_max_frame() {
        // A Hello whose name alone busts MAX_FRAME: the sender must
        // fail fast, not emit a frame every receiver rejects.
        encode(&Message::Hello {
            worker: 0,
            name: "x".repeat(MAX_FRAME as usize),
        });
    }

    /// A writer that accepts one byte per call and interleaves
    /// WouldBlock, mimicking a nonblocking socket under backpressure.
    struct TrickleWriter {
        data: Vec<u8>,
        ready: bool,
    }

    impl Write for TrickleWriter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if !self.ready {
                self.ready = true;
                return Err(std::io::ErrorKind::WouldBlock.into());
            }
            self.ready = false;
            self.data.push(buf[0]);
            Ok(1)
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn send_retrying_survives_would_block_without_corruption() {
        let msg = Message::Update {
            start_iteration: 7,
            steps: 2,
            params: pset(),
        };
        let mut w = TrickleWriter {
            data: Vec::new(),
            ready: false,
        };
        send_retrying(&mut w, &msg, Some(Duration::from_secs(5))).unwrap();
        // The byte-at-a-time, WouldBlock-riddled write still lands the
        // exact frame: resume from the same offset, never restart.
        assert_eq!(w.data, encode(&msg));
    }

    /// A writer whose peer never drains: every call is WouldBlock.
    struct StuckWriter;

    impl Write for StuckWriter {
        fn write(&mut self, _buf: &[u8]) -> std::io::Result<usize> {
            Err(std::io::ErrorKind::WouldBlock.into())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn send_retrying_times_out_only_on_sustained_stall() {
        let err = send_retrying(
            &mut StuckWriter,
            &Message::Shutdown,
            Some(Duration::from_millis(20)),
        )
        .unwrap_err();
        match err {
            WireError::Io(e) => assert_eq!(e.kind(), std::io::ErrorKind::TimedOut),
            other => panic!("expected Io(TimedOut), got {other}"),
        }
    }

    #[test]
    fn frame_reader_rejects_hostile_length_before_allocating() {
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.push(1);
        let mut stream = std::io::Cursor::new(bytes);
        let mut reader = FrameReader::new();
        let err = loop {
            match reader.poll(&mut stream) {
                Ok(None) => continue,
                Ok(Some(_)) => panic!("hostile frame accepted"),
                Err(e) => break e,
            }
        };
        assert!(matches!(err, WireError::FrameTooLarge { len: u32::MAX, .. }));
    }
}
