//! Wire protocol for the TCP deployment runtime.
//!
//! Frames: `[u32 LE total-payload-len][u8 tag][payload]`. Parameter sets
//! travel as a u32 tensor count followed by, per tensor, a u32 element
//! count and that many little-endian f32s; shapes are validated against
//! the receiver's expected specs (the manifest is the schema — the wire
//! carries no redundant metadata).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::model::{ParamSet, Tensor, TensorSpec};

/// Message tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Tag {
    /// worker -> leader: join the federation (payload: client name utf8).
    Hello = 1,
    /// leader -> worker: initial/fresh global model + iteration stamp.
    Global = 2,
    /// worker -> leader: trained local model + the iteration it started
    /// from + local step count.
    Update = 3,
    /// leader -> worker: training is over; final stats follow.
    Shutdown = 4,
}

impl Tag {
    /// Decode a frame's tag byte; fails on unknown tags.
    pub fn from_u8(b: u8) -> Result<Tag> {
        Ok(match b {
            1 => Tag::Hello,
            2 => Tag::Global,
            3 => Tag::Update,
            4 => Tag::Shutdown,
            other => bail!("unknown wire tag {other}"),
        })
    }
}

/// A decoded message.
#[derive(Debug)]
pub enum Message {
    /// worker → leader: join the federation under the given name.
    Hello {
        /// Human-readable worker name (logging only).
        name: String,
    },
    /// leader → worker: a global model stamped with its iteration.
    Global {
        /// Global aggregation count when this model was sent.
        iteration: u64,
        /// The global model parameters.
        params: ParamSet,
    },
    /// worker → leader: a trained local model.
    Update {
        /// The global iteration the worker trained from (staleness base).
        start_iteration: u64,
        /// Local SGD steps the worker ran.
        steps: u32,
        /// The updated local model parameters.
        params: ParamSet,
    },
    /// leader → worker: training is over, disconnect.
    Shutdown,
}

// ------------------------------------------------------------ encoding

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_params(buf: &mut Vec<u8>, p: &ParamSet) {
    put_u32(buf, p.tensors.len() as u32);
    for t in &p.tensors {
        put_u32(buf, t.data.len() as u32);
        for v in &t.data {
            buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

/// Encode a message into a ready-to-send frame.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut payload = Vec::new();
    let tag = match msg {
        Message::Hello { name } => {
            payload.extend_from_slice(name.as_bytes());
            Tag::Hello
        }
        Message::Global { iteration, params } => {
            put_u64(&mut payload, *iteration);
            put_params(&mut payload, params);
            Tag::Global
        }
        Message::Update {
            start_iteration,
            steps,
            params,
        } => {
            put_u64(&mut payload, *start_iteration);
            put_u32(&mut payload, *steps);
            put_params(&mut payload, params);
            Tag::Update
        }
        Message::Shutdown => Tag::Shutdown,
    };
    let mut frame = Vec::with_capacity(payload.len() + 5);
    put_u32(&mut frame, payload.len() as u32 + 1);
    frame.push(tag as u8);
    frame.extend_from_slice(&payload);
    frame
}

// ------------------------------------------------------------ decoding

/// Hard cap on frame size (128 MiB) — refuse hostile/corrupt lengths.
const MAX_FRAME: u32 = 128 << 20;

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated frame");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn params(&mut self, specs: &[TensorSpec]) -> Result<ParamSet> {
        let n = self.u32()? as usize;
        if n != specs.len() {
            bail!("wire params: {n} tensors, expected {}", specs.len());
        }
        let mut tensors = Vec::with_capacity(n);
        for spec in specs {
            let len = self.u32()? as usize;
            if len != spec.numel() {
                bail!(
                    "wire tensor {}: {len} elems, expected {}",
                    spec.name,
                    spec.numel()
                );
            }
            let raw = self.take(len * 4)?;
            let mut data = Vec::with_capacity(len);
            for chunk in raw.chunks_exact(4) {
                data.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            tensors.push(Tensor::from_data(spec.clone(), data));
        }
        Ok(ParamSet { tensors })
    }
}

/// Decode one payload (tag byte + body). `specs` is the expected tensor
/// layout for messages that carry parameters.
pub fn decode(payload: &[u8], specs: &[TensorSpec]) -> Result<Message> {
    if payload.is_empty() {
        bail!("empty frame");
    }
    let tag = Tag::from_u8(payload[0])?;
    let mut c = Cursor {
        buf: payload,
        pos: 1,
    };
    let msg = match tag {
        Tag::Hello => Message::Hello {
            name: String::from_utf8(c.take(payload.len() - 1)?.to_vec())
                .context("hello name not utf8")?,
        },
        Tag::Global => Message::Global {
            iteration: c.u64()?,
            params: c.params(specs)?,
        },
        Tag::Update => Message::Update {
            start_iteration: c.u64()?,
            steps: c.u32()?,
            params: c.params(specs)?,
        },
        Tag::Shutdown => Message::Shutdown,
    };
    if c.pos != payload.len() && tag != Tag::Hello {
        bail!("trailing bytes in frame ({} of {})", c.pos, payload.len());
    }
    Ok(msg)
}

/// Write one frame to a stream.
pub fn send(stream: &mut impl Write, msg: &Message) -> Result<()> {
    let frame = encode(msg);
    stream.write_all(&frame)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame from a stream.
pub fn recv(stream: &mut impl Read, specs: &[TensorSpec]) -> Result<Message> {
    let mut len_buf = [0u8; 4];
    stream.read_exact(&mut len_buf).context("reading frame length")?;
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME {
        bail!("bad frame length {len}");
    }
    let mut payload = vec![0u8; len as usize];
    stream.read_exact(&mut payload).context("reading frame body")?;
    decode(&payload, specs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![2, 3],
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![4],
            },
        ]
    }

    fn pset() -> ParamSet {
        ParamSet {
            tensors: vec![
                Tensor::from_data(specs()[0].clone(), vec![1.0, -2.5, 3.0, 0.0, 5.5, -6.25]),
                Tensor::from_data(specs()[1].clone(), vec![0.1, 0.2, 0.3, 0.4]),
            ],
        }
    }

    fn roundtrip(msg: &Message) -> Message {
        let frame = encode(msg);
        let len = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
        assert_eq!(len, frame.len() - 4);
        decode(&frame[4..], &specs()).unwrap()
    }

    #[test]
    fn hello_roundtrip() {
        match roundtrip(&Message::Hello {
            name: "client-7 ü".into(),
        }) {
            Message::Hello { name } => assert_eq!(name, "client-7 ü"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn global_roundtrip_bitexact() {
        match roundtrip(&Message::Global {
            iteration: 12345678901,
            params: pset(),
        }) {
            Message::Global { iteration, params } => {
                assert_eq!(iteration, 12345678901);
                assert_eq!(params, pset());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn update_roundtrip() {
        match roundtrip(&Message::Update {
            start_iteration: 42,
            steps: 16,
            params: pset(),
        }) {
            Message::Update {
                start_iteration,
                steps,
                params,
            } => {
                assert_eq!((start_iteration, steps), (42, 16));
                assert_eq!(params, pset());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn shutdown_roundtrip() {
        assert!(matches!(roundtrip(&Message::Shutdown), Message::Shutdown));
    }

    #[test]
    fn rejects_wrong_shape() {
        let frame = encode(&Message::Global {
            iteration: 1,
            params: pset(),
        });
        let bad_specs = vec![TensorSpec {
            name: "w".into(),
            shape: vec![7],
        }];
        assert!(decode(&frame[4..], &bad_specs).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(&[], &specs()).is_err());
        assert!(decode(&[99, 0, 0], &specs()).is_err());
        assert!(decode(&[2, 1, 2, 3], &specs()).is_err()); // truncated Global
    }

    #[test]
    fn stream_send_recv() {
        let mut buf: Vec<u8> = Vec::new();
        send(&mut buf, &Message::Update {
            start_iteration: 9,
            steps: 3,
            params: pset(),
        })
        .unwrap();
        send(&mut buf, &Message::Shutdown).unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert!(matches!(
            recv(&mut r, &specs()).unwrap(),
            Message::Update { steps: 3, .. }
        ));
        assert!(matches!(recv(&mut r, &specs()).unwrap(), Message::Shutdown));
    }
}
