//! PJRT runtime: load + execute the AOT artifacts (`artifacts/*.hlo.txt`).
//!
//! Flow (with the `pjrt` cargo feature): `xla::PjRtClient::cpu` →
//! `xla::HloModuleProto::from_text_file` → `client.compile` →
//! `execute`. Python runs only at build time (`python/compile/aot.py`
//! writes the artifacts and [`Manifest`]).
//!
//! Without the feature (the default, offline-friendly build) the
//! [`Manifest`] machinery is still fully available — it is pure Rust —
//! while [`Engine`] is an API-compatible stub that fails at load time
//! with a message pointing at `--features pjrt` and the `linear`
//! learner fallback.

mod engine;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod xla;

pub use engine::Engine;
pub use manifest::{ArtifactMeta, InputSpec, Manifest, ModelManifest};
