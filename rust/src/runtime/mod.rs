//! PJRT runtime: load + execute the AOT artifacts (`artifacts/*.hlo.txt`).
//!
//! `xla` crate flow: `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//! → `client.compile` → `execute`. Python runs only at build time.

mod engine;
pub mod manifest;

pub use engine::Engine;
pub use manifest::{ArtifactMeta, InputSpec, Manifest, ModelManifest};
