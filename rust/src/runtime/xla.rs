//! Typed seam to the XLA/PJRT runtime (compiled only with `--features pjrt`).
//!
//! This module mirrors the slice of the `xla-rs` API surface that
//! [`super::Engine`] drives — `PjRtClient::cpu()` → `compile` →
//! `execute` → `to_literal_sync` — so the engine is written once against
//! the real interface. The crate itself links no native code: the
//! host-side types ([`Literal`], [`HloModuleProto`], [`XlaComputation`])
//! are fully implemented in Rust, while the three device-backed types
//! ([`PjRtClient`], [`PjRtLoadedExecutable`], [`PjRtBuffer`]) are
//! uninhabited — creating a client fails with an actionable error rather
//! than silently computing wrong results. Binding the real PJRT C API
//! (or vendoring `xla-rs`) replaces only this module; every call site in
//! `engine.rs` stays unchanged.

use std::borrow::Borrow;
use std::path::Path;

use anyhow::{anyhow, bail, ensure, Context, Result};

/// XLA element types representable by this seam.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float (`f32`).
    F32,
    /// 32-bit signed integer (`i32`).
    S32,
    /// 32-bit unsigned integer (`u32`).
    U32,
}

/// Storage for one literal: a typed flat buffer or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
    Tuple(Vec<Literal>),
}

mod sealed {
    /// Seals [`super::NativeType`] to the scalar types XLA understands.
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
    impl Sealed for u32 {}
}

/// Rust scalar types that map onto an XLA [`ElementType`].
pub trait NativeType: Copy + Sized + sealed::Sealed {
    /// The XLA element type corresponding to `Self`.
    const TY: ElementType;

    /// Build a literal of the given shape from a flat slice.
    fn literal_from_slice(data: &[Self], shape: Vec<i64>) -> Literal;

    /// Extract the flat buffer if the literal holds this element type.
    fn extract(lit: &Literal) -> Option<Vec<Self>>;
}

macro_rules! native_type {
    ($t:ty, $ty:expr, $variant:ident) => {
        impl NativeType for $t {
            const TY: ElementType = $ty;

            fn literal_from_slice(data: &[Self], shape: Vec<i64>) -> Literal {
                Literal {
                    shape,
                    payload: Payload::$variant(data.to_vec()),
                }
            }

            fn extract(lit: &Literal) -> Option<Vec<Self>> {
                match &lit.payload {
                    Payload::$variant(v) => Some(v.clone()),
                    _ => None,
                }
            }
        }
    };
}

native_type!(f32, ElementType::F32, F32);
native_type!(i32, ElementType::S32, I32);
native_type!(u32, ElementType::U32, U32);

/// A host-side XLA literal: a shaped, typed value (or tuple of values).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    shape: Vec<i64>,
    payload: Payload,
}

impl Literal {
    /// Rank-1 literal over a flat slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_from_slice(data, vec![data.len() as i64])
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        T::literal_from_slice(&[v], Vec::new())
    }

    /// Reinterpret the literal under a new shape with the same element
    /// count, reusing the storage (this is the hot path: every parameter
    /// tensor and batch goes through vec1-then-reshape per dispatch).
    /// Fails on element-count mismatch or on tuple literals.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        if matches!(self.payload, Payload::Tuple(_)) {
            bail!("cannot reshape a tuple literal");
        }
        let want: i64 = dims.iter().product();
        let have = self.element_count() as i64;
        ensure!(
            want == have,
            "reshape to {dims:?} ({want} elems) from {} elems",
            have
        );
        Ok(Literal {
            shape: dims.to_vec(),
            payload: self.payload,
        })
    }

    /// The literal's array dimensions (empty for scalars and for
    /// tuples, which have parts rather than a shape).
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Number of scalar elements: 1 for scalars, the flat length for
    /// arrays, and the sum over parts for tuples.
    pub fn element_count(&self) -> usize {
        match &self.payload {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
            Payload::U32(v) => v.len(),
            Payload::Tuple(v) => v.iter().map(Literal::element_count).sum(),
        }
    }

    /// Copy the flat buffer out as `Vec<T>`; fails on element-type
    /// mismatch.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self).ok_or_else(|| {
            anyhow!("literal does not hold {:?} elements", T::TY)
        })
    }

    /// Decompose a tuple literal into its parts (AOT programs are lowered
    /// with `return_tuple=True`, so every program output is a tuple).
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.payload {
            Payload::Tuple(parts) => Ok(parts),
            _ => bail!("literal is not a tuple"),
        }
    }

    /// Assemble a tuple literal from parts. Tuples carry no array
    /// shape of their own — query the parts after [`Literal::to_tuple`].
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            shape: Vec::new(),
            payload: Payload::Tuple(parts),
        }
    }
}

/// An HLO module in its text form (the artifact interchange format).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Read and sanity-check an `.hlo.txt` artifact.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {}", path.display()))?;
        ensure!(
            text.contains("HloModule"),
            "{} does not look like HLO text (no HloModule header)",
            path.display()
        );
        Ok(HloModuleProto { text })
    }

    /// The raw HLO text.
    pub fn text(&self) -> &str {
        &self.text
    }
}

/// A computation ready for compilation (wraps the parsed HLO module).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    module: HloModuleProto,
}

impl XlaComputation {
    /// Wrap an HLO module as a compilable computation.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: proto.clone(),
        }
    }

    /// The underlying HLO module.
    pub fn module(&self) -> &HloModuleProto {
        &self.module
    }
}

/// Handle to a PJRT device client. Uninhabited in this build: the native
/// PJRT plugin is not linked, so [`PjRtClient::cpu`] returns an error and
/// no value of this type can exist.
pub enum PjRtClient {}

impl PjRtClient {
    /// Create the CPU PJRT client. Always fails in this build with an
    /// actionable message; a future PR binds this to the PJRT C API.
    pub fn cpu() -> Result<PjRtClient> {
        bail!(
            "the native PJRT runtime is not linked into this build; the \
             `pjrt` cargo feature compiles the typed execution path only. \
             Use `--learner linear` (pure Rust), or bind runtime::xla to \
             the XLA PJRT plugin (see docs/ARCHITECTURE.md)"
        )
    }

    /// Compile a computation for this client's devices.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        match *self {}
    }
}

/// A compiled, device-loaded executable. Uninhabited in this build (it
/// can only be produced by a [`PjRtClient`]).
pub enum PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    /// Execute with one argument list on the default device; returns
    /// per-device, per-output buffers.
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        match *self {}
    }
}

/// A device-resident buffer. Uninhabited in this build.
pub enum PjRtBuffer {}

impl PjRtBuffer {
    /// Copy the buffer to the host as a [`Literal`], blocking until the
    /// device computation that produced it completes.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        match *self {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.shape(), &[6]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.to_vec::<i32>().is_err(), "element type is checked");
        let m = l.clone().reshape(&[2, 3]).unwrap();
        assert_eq!(m.shape(), &[2, 3]);
        assert_eq!(m.element_count(), 6);
        assert!(l.reshape(&[4, 2]).is_err(), "element count is checked");
    }

    #[test]
    fn scalar_and_tuple() {
        let s = Literal::scalar(7u32);
        assert!(s.shape().is_empty());
        assert_eq!(s.to_vec::<u32>().unwrap(), vec![7]);
        let t = Literal::tuple(vec![Literal::scalar(1i32), Literal::vec1(&[0.5f32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
        assert!(Literal::scalar(1.0f32).to_tuple().is_err());
    }

    #[test]
    fn hlo_text_is_validated() {
        let dir = std::env::temp_dir().join(format!("csmaafl_xla_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.hlo.txt");
        std::fs::write(&good, "HloModule train_step\nENTRY main {}").unwrap();
        let proto = HloModuleProto::from_text_file(&good).unwrap();
        assert!(proto.text().starts_with("HloModule"));
        let comp = XlaComputation::from_proto(&proto);
        assert!(comp.module().text().contains("train_step"));
        let bad = dir.join("bad.hlo.txt");
        std::fs::write(&bad, "not hlo at all").unwrap();
        assert!(HloModuleProto::from_text_file(&bad).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn client_fails_loudly_without_native_runtime() {
        let err = PjRtClient::cpu().unwrap_err().to_string();
        assert!(err.contains("linear"), "error names the fallback: {err}");
    }
}
