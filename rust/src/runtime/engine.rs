//! PJRT execution engine: loads the AOT HLO-text artifacts and runs them.
//!
//! One [`Engine`] per model config. All five entry points are compiled
//! once at load time; the request path is pure Rust + PJRT (Python is
//! never invoked). HLO *text* is the interchange format — serialized
//! protos are rejected (see `python/compile/aot.py`).
//!
//! The whole execution path sits behind the `pjrt` cargo feature. The
//! default build ships an API-compatible stub whose constructors fail
//! with an actionable error, so every caller (`session`, `coordinator`,
//! benches, the `repro smoke` command) compiles unchanged and the
//! pure-Rust [`crate::learner::LinearLearner`] remains the offline
//! fallback.

#[cfg(feature = "pjrt")]
use std::path::Path;

#[cfg(feature = "pjrt")]
use anyhow::{bail, Context, Result};

#[cfg(feature = "pjrt")]
use super::manifest::{Manifest, ModelManifest};
#[cfg(feature = "pjrt")]
use super::xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};
#[cfg(feature = "pjrt")]
use crate::log_info;
#[cfg(feature = "pjrt")]
use crate::model::{ParamSet, Tensor};

/// Compiled executables for one model config.
#[cfg(feature = "pjrt")]
pub struct Engine {
    model: ModelManifest,
    init_exe: PjRtLoadedExecutable,
    train_step_exe: PjRtLoadedExecutable,
    train_chunk_exe: PjRtLoadedExecutable,
    eval_chunk_exe: PjRtLoadedExecutable,
    aggregate_exe: PjRtLoadedExecutable,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load artifacts for `config` from `dir` and compile on the CPU PJRT
    /// client.
    pub fn load(dir: impl AsRef<Path>, config: &str) -> Result<Engine> {
        let manifest = Manifest::load(&dir)?;
        Self::from_manifest(&manifest, config)
    }

    /// Compile every required artifact of `config` from a parsed manifest.
    pub fn from_manifest(manifest: &Manifest, config: &str) -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let model = manifest.config(config)?.clone();
        let t0 = std::time::Instant::now();
        let compile = |name: &str| -> Result<PjRtLoadedExecutable> {
            let meta = model.artifact(name)?;
            let path = meta.file.to_str().context("non-utf8 artifact path")?;
            let proto = HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {path}"))?;
            let comp = XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))
        };
        let e = Engine {
            init_exe: compile("init")?,
            train_step_exe: compile("train_step")?,
            train_chunk_exe: compile("train_chunk")?,
            eval_chunk_exe: compile("eval_chunk")?,
            aggregate_exe: compile("aggregate")?,
            model,
        };
        log_info!(
            "engine[{}]: compiled 5 artifacts in {:.2}s ({} params)",
            e.model.name,
            t0.elapsed().as_secs_f64(),
            e.model.numel()
        );
        Ok(e)
    }

    /// The manifest entry this engine was compiled from.
    pub fn model(&self) -> &ModelManifest {
        &self.model
    }

    // ------------------------------------------------------------ helpers

    fn tensor_literal(t: &Tensor) -> Result<Literal> {
        let lit = Literal::vec1(&t.data);
        if t.spec.shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = t.spec.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn f32_literal(data: &[f32], shape: &[usize]) -> Result<Literal> {
        let expected: usize = shape.iter().product();
        if data.len() != expected {
            bail!("literal data len {} != shape {:?}", data.len(), shape);
        }
        let lit = Literal::vec1(data);
        if shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn i32_literal(data: &[i32], shape: &[usize]) -> Result<Literal> {
        let expected: usize = shape.iter().product();
        if data.len() != expected {
            bail!("literal data len {} != shape {:?}", data.len(), shape);
        }
        let lit = Literal::vec1(data);
        if shape.len() == 1 {
            return Ok(lit);
        }
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    fn params_to_literals(&self, p: &ParamSet) -> Result<Vec<Literal>> {
        if p.tensors.len() != self.model.params.len() {
            bail!(
                "param set has {} tensors, manifest expects {}",
                p.tensors.len(),
                self.model.params.len()
            );
        }
        p.tensors.iter().map(Self::tensor_literal).collect()
    }

    fn literals_to_params(&self, lits: &[Literal]) -> Result<ParamSet> {
        let n = self.model.params.len();
        if lits.len() < n {
            bail!("expected >= {n} output literals, got {}", lits.len());
        }
        let mut tensors = Vec::with_capacity(n);
        for (spec, lit) in self.model.params.iter().zip(lits) {
            let data = lit.to_vec::<f32>()?;
            if data.len() != spec.numel() {
                bail!(
                    "output tensor {}: got {} elems, want {}",
                    spec.name,
                    data.len(),
                    spec.numel()
                );
            }
            tensors.push(Tensor::from_data(spec.clone(), data));
        }
        Ok(ParamSet { tensors })
    }

    fn run(&self, exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
        let result = exe.execute::<Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        Ok(result.to_tuple()?)
    }

    // ------------------------------------------------------- entry points

    /// Initialize parameters from a seed (the lowered He init).
    pub fn init(&self, seed: u32) -> Result<ParamSet> {
        let out = self.run(&self.init_exe, &[Literal::scalar(seed)])?;
        self.literals_to_params(&out)
    }

    /// One SGD step. `x`: flattened (batch, 28, 28, 1); `y`: (batch,).
    pub fn train_step(&self, p: &ParamSet, x: &[f32], y: &[i32]) -> Result<(ParamSet, f32)> {
        let m = &self.model;
        let mut args = self.params_to_literals(p)?;
        let mut xshape = vec![m.batch];
        xshape.extend_from_slice(&m.input_shape);
        args.push(Self::f32_literal(x, &xshape)?);
        args.push(Self::i32_literal(y, &[m.batch])?);
        let out = self.run(&self.train_step_exe, &args)?;
        let new_p = self.literals_to_params(&out)?;
        let loss = out[m.params.len()].to_vec::<f32>()?[0];
        Ok((new_p, loss))
    }

    /// `chunk_steps` SGD steps under one dispatch.
    /// `xs`: flattened (S, batch, 28, 28, 1); `ys`: (S, batch).
    pub fn train_chunk(&self, p: &ParamSet, xs: &[f32], ys: &[i32]) -> Result<(ParamSet, f32)> {
        let m = &self.model;
        let mut args = self.params_to_literals(p)?;
        let mut xshape = vec![m.chunk_steps, m.batch];
        xshape.extend_from_slice(&m.input_shape);
        args.push(Self::f32_literal(xs, &xshape)?);
        args.push(Self::i32_literal(ys, &[m.chunk_steps, m.batch])?);
        let out = self.run(&self.train_chunk_exe, &args)?;
        let new_p = self.literals_to_params(&out)?;
        let loss = out[m.params.len()].to_vec::<f32>()?[0];
        Ok((new_p, loss))
    }

    /// Evaluate one eval batch: returns (correct_count, loss_sum).
    pub fn eval_chunk(&self, p: &ParamSet, x: &[f32], y: &[i32]) -> Result<(u32, f32)> {
        let m = &self.model;
        let mut args = self.params_to_literals(p)?;
        let mut xshape = vec![m.eval_batch];
        xshape.extend_from_slice(&m.input_shape);
        args.push(Self::f32_literal(x, &xshape)?);
        args.push(Self::i32_literal(y, &[m.eval_batch])?);
        let out = self.run(&self.eval_chunk_exe, &args)?;
        let correct = out[0].to_vec::<i32>()?[0];
        let loss_sum = out[1].to_vec::<f32>()?[0];
        Ok((correct.max(0) as u32, loss_sum))
    }

    /// Eq.(3) aggregation via the L1 Pallas axpy artifact:
    /// `beta*global + (1-beta)*local`.
    pub fn aggregate(&self, global: &ParamSet, local: &ParamSet, beta: f32) -> Result<ParamSet> {
        let mut args = self.params_to_literals(global)?;
        args.extend(self.params_to_literals(local)?);
        args.push(Literal::scalar(beta));
        let out = self.run(&self.aggregate_exe, &args)?;
        self.literals_to_params(&out)
    }

    /// Evaluate a whole test set by batching through `eval_chunk`.
    /// Trailing examples that do not fill a batch are dropped (the test
    /// sets generated by `data::` are sized as multiples of eval_batch).
    pub fn evaluate_set(&self, p: &ParamSet, x: &[f32], y: &[i32]) -> Result<(f64, f64)> {
        let m = &self.model;
        let img = m.image_numel();
        let total = y.len();
        let nb = total / m.eval_batch;
        if nb == 0 {
            bail!("test set smaller than eval_batch ({})", m.eval_batch);
        }
        let mut correct = 0u64;
        let mut loss_sum = 0.0f64;
        for b in 0..nb {
            let xs = &x[b * m.eval_batch * img..(b + 1) * m.eval_batch * img];
            let ys = &y[b * m.eval_batch..(b + 1) * m.eval_batch];
            let (c, l) = self.eval_chunk(p, xs, ys)?;
            correct += c as u64;
            loss_sum += l as f64;
        }
        let n = (nb * m.eval_batch) as f64;
        Ok((correct as f64 / n, loss_sum / n))
    }
}

// --------------------------------------------------------------- stub

/// Stub engine for builds without the `pjrt` feature.
///
/// The type is uninhabited: [`Engine::load`] and [`Engine::from_manifest`]
/// fail with a message naming the feature and the `linear` fallback, so
/// no value of this type can ever exist and the per-value methods are
/// statically unreachable. Everything that *types against* `Engine`
/// (`session`, `coordinator::runner`, the benches) compiles identically
/// in both build modes.
#[cfg(not(feature = "pjrt"))]
pub enum Engine {}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    fn unavailable() -> anyhow::Error {
        anyhow::anyhow!(
            "this build has no PJRT backend (compiled without the `pjrt` \
             cargo feature); rebuild with `cargo build --features pjrt` \
             or use the pure-Rust learner (--learner linear)"
        )
    }

    /// Always fails: the PJRT path is not compiled into this build.
    pub fn load(
        _dir: impl AsRef<std::path::Path>,
        _config: &str,
    ) -> anyhow::Result<Engine> {
        Err(Self::unavailable())
    }

    /// Always fails: the PJRT path is not compiled into this build.
    pub fn from_manifest(
        _manifest: &super::manifest::Manifest,
        _config: &str,
    ) -> anyhow::Result<Engine> {
        Err(Self::unavailable())
    }

    /// The manifest entry this engine was compiled from.
    pub fn model(&self) -> &super::manifest::ModelManifest {
        match *self {}
    }

    /// Initialize parameters from a seed (the lowered He init).
    pub fn init(&self, _seed: u32) -> anyhow::Result<crate::model::ParamSet> {
        match *self {}
    }

    /// One SGD step.
    pub fn train_step(
        &self,
        _p: &crate::model::ParamSet,
        _x: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<(crate::model::ParamSet, f32)> {
        match *self {}
    }

    /// `chunk_steps` SGD steps under one dispatch.
    pub fn train_chunk(
        &self,
        _p: &crate::model::ParamSet,
        _xs: &[f32],
        _ys: &[i32],
    ) -> anyhow::Result<(crate::model::ParamSet, f32)> {
        match *self {}
    }

    /// Evaluate one eval batch: returns (correct_count, loss_sum).
    pub fn eval_chunk(
        &self,
        _p: &crate::model::ParamSet,
        _x: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<(u32, f32)> {
        match *self {}
    }

    /// Eq.(3) aggregation: `beta*global + (1-beta)*local`.
    pub fn aggregate(
        &self,
        _global: &crate::model::ParamSet,
        _local: &crate::model::ParamSet,
        _beta: f32,
    ) -> anyhow::Result<crate::model::ParamSet> {
        match *self {}
    }

    /// Evaluate a whole test set by batching through `eval_chunk`.
    pub fn evaluate_set(
        &self,
        _p: &crate::model::ParamSet,
        _x: &[f32],
        _y: &[i32],
    ) -> anyhow::Result<(f64, f64)> {
        match *self {}
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::Engine;

    #[test]
    fn stub_engine_fails_with_actionable_error() {
        let err = Engine::load("artifacts", "mnist_small").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("linear"), "{msg}");
    }
}
