//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `make artifacts` writes `artifacts/manifest.json` describing every AOT
//! model config: the ordered parameter tensor list, training
//! hyper-parameters baked at lowering, and the HLO text file for each entry
//! point. The runtime refuses to execute artifacts whose manifest does not
//! parse or whose files are missing — failing loudly beats shape garbage.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::model::TensorSpec;
use crate::util::json::{self, Json};

/// The entry points every model config must export.
pub const REQUIRED_ARTIFACTS: [&str; 5] =
    ["init", "train_step", "train_chunk", "eval_chunk", "aggregate"];

/// One input of an exported program (shape + dtype, as lowered).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InputSpec {
    /// Dimension sizes as lowered.
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. `float32`, `int32`).
    pub dtype: String,
}

/// One exported HLO program.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    /// Absolute path of the `.hlo.txt` file.
    pub file: PathBuf,
    /// Content hash recorded at lowering time (provenance).
    pub sha256: String,
    /// The program's input signature.
    pub inputs: Vec<InputSpec>,
}

/// One AOT-lowered model configuration (e.g. `mnist_small`).
#[derive(Debug, Clone)]
pub struct ModelManifest {
    /// The config's manifest key (e.g. `mnist_small`).
    pub name: String,
    /// Ordered parameter tensor specs (the wire/runtime contract).
    pub params: Vec<TensorSpec>,
    /// Learning rate baked into the train artifacts at lowering.
    pub lr: f64,
    /// Mini-batch size of `train_step`.
    pub batch: usize,
    /// Scan-fused SGD steps per `train_chunk` dispatch.
    pub chunk_steps: usize,
    /// Batch size of `eval_chunk`.
    pub eval_batch: usize,
    /// Number of label classes.
    pub num_classes: usize,
    /// Per-image input shape (e.g. `[28, 28, 1]`).
    pub input_shape: Vec<usize>,
    /// Entry-point name → artifact metadata.
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

impl ModelManifest {
    /// Total scalar parameter count.
    pub fn numel(&self) -> usize {
        self.params.iter().map(|s| s.numel()).sum()
    }

    /// Flattened pixels per image.
    pub fn image_numel(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Look up one required entry point's artifact metadata.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("config {}: missing artifact {name}", self.name))
    }
}

/// The parsed manifest for an artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Config name → per-model manifest.
    pub configs: BTreeMap<String, ModelManifest>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}; run `make artifacts`", path.display()))?;
        let root = json::parse(&text)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        Self::from_json(dir, &root)
    }

    fn from_json(dir: PathBuf, root: &Json) -> Result<Manifest> {
        let version = root
            .get("version")
            .and_then(Json::as_i64)
            .ok_or_else(|| anyhow!("manifest: missing version"))?;
        if version != 1 {
            bail!("manifest: unsupported version {version}");
        }
        let cfgs = root
            .get("configs")
            .and_then(Json::as_object)
            .ok_or_else(|| anyhow!("manifest: missing configs object"))?;
        let mut configs = BTreeMap::new();
        for (name, body) in cfgs {
            let mm = parse_model(&dir, name, body)
                .with_context(|| format!("manifest config {name}"))?;
            configs.insert(name.clone(), mm);
        }
        if configs.is_empty() {
            bail!("manifest: no configs");
        }
        Ok(Manifest { dir, configs })
    }

    /// Look up a model config by name, listing alternatives on miss.
    pub fn config(&self, name: &str) -> Result<&ModelManifest> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!(
                "model config {name:?} not in manifest (have: {:?}); \
                 re-run `make artifacts` with --configs including it",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_i64)
        .filter(|v| *v >= 0)
        .map(|v| v as usize)
        .ok_or_else(|| anyhow!("missing/invalid field {key}"))
}

fn parse_model(dir: &Path, name: &str, j: &Json) -> Result<ModelManifest> {
    let params_json = j
        .get("params")
        .and_then(Json::as_array)
        .ok_or_else(|| anyhow!("missing params"))?;
    let mut params = Vec::with_capacity(params_json.len());
    for p in params_json {
        let pname = p
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("param missing name"))?;
        let shape = parse_shape(p.get("shape"))?;
        params.push(TensorSpec {
            name: pname.to_string(),
            shape,
        });
    }

    let arts_json = j
        .get("artifacts")
        .and_then(Json::as_object)
        .ok_or_else(|| anyhow!("missing artifacts"))?;
    let mut artifacts = BTreeMap::new();
    for (aname, a) in arts_json {
        let file = a
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact {aname}: missing file"))?;
        let full = dir.join(file);
        if !full.exists() {
            bail!(
                "artifact {aname}: file {} missing; re-run `make artifacts`",
                full.display()
            );
        }
        let mut inputs = Vec::new();
        for i in a.get("inputs").and_then(Json::as_array).unwrap_or(&[]) {
            inputs.push(InputSpec {
                shape: parse_shape(i.get("shape"))?,
                dtype: i
                    .get("dtype")
                    .and_then(Json::as_str)
                    .unwrap_or("float32")
                    .to_string(),
            });
        }
        artifacts.insert(
            aname.clone(),
            ArtifactMeta {
                file: full,
                sha256: a
                    .get("sha256")
                    .and_then(Json::as_str)
                    .unwrap_or_default()
                    .to_string(),
                inputs,
            },
        );
    }
    for required in REQUIRED_ARTIFACTS {
        if !artifacts.contains_key(required) {
            bail!("missing required artifact {required}");
        }
    }

    Ok(ModelManifest {
        name: name.to_string(),
        params,
        lr: j.get("lr").and_then(Json::as_f64).unwrap_or(0.01),
        batch: req_usize(j, "batch")?,
        chunk_steps: req_usize(j, "chunk_steps")?,
        eval_batch: req_usize(j, "eval_batch")?,
        num_classes: req_usize(j, "num_classes")?,
        input_shape: parse_shape(j.get("input_shape"))?,
        artifacts,
    })
}

fn parse_shape(j: Option<&Json>) -> Result<Vec<usize>> {
    j.and_then(Json::as_array)
        .map(|a| {
            a.iter()
                .map(|d| {
                    d.as_i64()
                        .filter(|v| *v >= 0)
                        .map(|v| v as usize)
                        .ok_or_else(|| anyhow!("bad shape dim"))
                })
                .collect::<Result<Vec<_>>>()
        })
        .ok_or_else(|| anyhow!("missing shape"))?
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_artifacts(dir: &Path) {
        for name in REQUIRED_ARTIFACTS {
            std::fs::write(dir.join(format!("{name}_t.hlo.txt")), "HloModule t").unwrap();
        }
    }

    fn minimal_manifest_json() -> String {
        let arts: Vec<String> = REQUIRED_ARTIFACTS
            .iter()
            .map(|n| {
                format!(
                    r#""{n}": {{"file": "{n}_t.hlo.txt", "sha256": "x", "inputs": [{{"shape": [2,2], "dtype": "float32"}}]}}"#
                )
            })
            .collect();
        format!(
            r#"{{"version": 1, "configs": {{"t": {{
                "params": [{{"name": "w", "shape": [2, 3]}}, {{"name": "b", "shape": [3]}}],
                "lr": 0.01, "batch": 5, "chunk_steps": 8, "eval_batch": 100,
                "num_classes": 10, "input_shape": [28, 28, 1],
                "artifacts": {{{}}}
            }}}}}}"#,
            arts.join(",")
        )
    }

    #[test]
    fn parses_minimal_manifest() {
        let tmp = std::env::temp_dir().join(format!("csmaafl_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        write_fake_artifacts(&tmp);
        std::fs::write(tmp.join("manifest.json"), minimal_manifest_json()).unwrap();
        let m = Manifest::load(&tmp).unwrap();
        let c = m.config("t").unwrap();
        assert_eq!(c.params.len(), 2);
        assert_eq!(c.numel(), 9);
        assert_eq!(c.batch, 5);
        assert_eq!(c.image_numel(), 784);
        assert!(c.artifact("train_step").is_ok());
        assert!(m.config("nope").is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn rejects_missing_artifact_file() {
        let tmp = std::env::temp_dir().join(format!("csmaafl_manifest_miss_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        // note: artifact files NOT written
        std::fs::write(tmp.join("manifest.json"), minimal_manifest_json()).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }

    #[test]
    fn rejects_bad_version() {
        let tmp = std::env::temp_dir().join(format!("csmaafl_manifest_ver_{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(tmp.join("manifest.json"), r#"{"version": 2, "configs": {}}"#).unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
