//! Sec. III-B: solve the AFL aggregation coefficients β_1..β_M that make
//! one asynchronous sweep reproduce the synchronous FedAvg aggregate
//! exactly.
//!
//! Given FedAvg coefficients α_m (Σα = 1) and a schedule φ(1..M), the
//! sweep  w_{t+1} = β_t·w_t + (1-β_t)·w^{φ(t)}  telescopes to
//!
//! ```text
//! w_{M+1} = (Π_t β_t)·w_1 + Σ_t (1-β_t)·(Π_{s>t} β_s)·w^{φ(t)} .
//! ```
//!
//! Matching coefficients backwards (eqs. 9–10):
//!
//! ```text
//! 1-β_M     = α_{φ(M)}
//! 1-β_{t}   = α_{φ(t)} / Π_{s>t} β_s .
//! ```
//!
//! Because Σα = 1, the residual weight on the initial global model is
//! forced to zero, i.e. **β_1 = 0**: the first aggregation of a sweep
//! discards the incoming global entirely — exactly like FedAvg, which
//! also assigns the previous global no weight. The paper states
//! β ∈ (0,1); the boundary value at t=1 is the unique consistent
//! solution and is validated by the equivalence tests below.

use anyhow::{bail, ensure, Result};

/// Solve for β given FedAvg weights `alpha` (already in schedule order:
/// `alpha[t]` is the weight of the client scheduled at iteration t+1).
///
/// Returns `beta` with `beta[t]` the coefficient of iteration t+1.
pub fn solve_betas(alpha_in_schedule_order: &[f64]) -> Result<Vec<f64>> {
    let alpha = alpha_in_schedule_order;
    let m = alpha.len();
    ensure!(m >= 1, "need at least one client");
    for (i, &a) in alpha.iter().enumerate() {
        ensure!(
            a > 0.0 && a < 1.0 || (m == 1 && a == 1.0),
            "alpha[{i}] = {a} out of (0,1)"
        );
    }
    let sum: f64 = alpha.iter().sum();
    ensure!(
        (sum - 1.0).abs() < 1e-9,
        "alphas must sum to 1 (got {sum})"
    );

    let mut beta = vec![0.0f64; m];
    // Running product Π_{s>t} β_s, built backwards.
    let mut prod = 1.0f64;
    for t in (0..m).rev() {
        let one_minus = alpha[t] / prod;
        if t == 0 {
            // Forced boundary: Σα=1 ⇒ α_{φ(1)} = Π_{s>1}β_s ⇒ β_1 = 0.
            ensure!(
                (one_minus - 1.0).abs() < 1e-6,
                "inconsistent alphas: residual {one_minus}"
            );
            beta[0] = 0.0;
            break;
        }
        if one_minus >= 1.0 {
            bail!(
                "no valid beta at t={t}: alpha {} exceeds remaining product {prod}",
                alpha[t]
            );
        }
        beta[t] = 1.0 - one_minus;
        prod *= beta[t];
    }
    Ok(beta)
}

/// Reconstruct the effective per-client coefficients a sweep with `beta`
/// assigns (inverse of `solve_betas`); index t matches the schedule.
pub fn effective_coefficients(beta: &[f64]) -> Vec<f64> {
    let m = beta.len();
    let mut coeff = vec![0.0f64; m];
    let mut prod = 1.0f64; // Π_{s>t} β_s
    for t in (0..m).rev() {
        coeff[t] = (1.0 - beta[t]) * prod;
        prod *= beta[t];
    }
    coeff
}

/// Sec. III-A: effective coefficients when the *naive* SFL weights are
/// reused asynchronously (β_t = 1 - α_{φ(t)}): the early clients'
/// contribution decays geometrically. Returned in schedule order.
pub fn naive_effective_coefficients(alpha_in_schedule_order: &[f64]) -> Vec<f64> {
    let beta: Vec<f64> = alpha_in_schedule_order.iter().map(|a| 1.0 - a).collect();
    effective_coefficients(&beta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn uniform_alpha(m: usize) -> Vec<f64> {
        vec![1.0 / m as f64; m]
    }

    fn random_alpha(m: usize, seed: u64) -> Vec<f64> {
        let mut r = Rng::new(seed);
        let raw: Vec<f64> = (0..m).map(|_| 0.05 + r.f64()).collect();
        let s: f64 = raw.iter().sum();
        raw.into_iter().map(|v| v / s).collect()
    }

    #[test]
    fn uniform_roundtrip() {
        for m in [1usize, 2, 3, 10, 100] {
            let alpha = uniform_alpha(m);
            let beta = solve_betas(&alpha).unwrap();
            let coeff = effective_coefficients(&beta);
            for (a, c) in alpha.iter().zip(&coeff) {
                assert!((a - c).abs() < 1e-12, "m={m}: {a} vs {c}");
            }
            assert_eq!(beta[0], 0.0, "beta_1 must be 0");
        }
    }

    #[test]
    fn random_alphas_roundtrip() {
        for seed in 0..50u64 {
            let m = 2 + (seed % 40) as usize;
            let alpha = random_alpha(m, seed);
            let beta = solve_betas(&alpha).unwrap();
            let coeff = effective_coefficients(&beta);
            for (t, (a, c)) in alpha.iter().zip(&coeff).enumerate() {
                assert!((a - c).abs() < 1e-9, "seed={seed} t={t}: {a} vs {c}");
            }
            // β_t ∈ [0,1) with β_1 = 0 exactly.
            assert_eq!(beta[0], 0.0);
            for &b in &beta[1..] {
                assert!((0.0..1.0).contains(&b), "{b}");
            }
        }
    }

    #[test]
    fn matches_paper_recurrence() {
        // eq. (9): α_{φ(M)} = 1 - β_M ; eq. (10): α_{φ(M-1)} = β_M(1-β_{M-1}).
        let alpha = random_alpha(5, 7);
        let beta = solve_betas(&alpha).unwrap();
        let m = 5;
        assert!((alpha[m - 1] - (1.0 - beta[m - 1])).abs() < 1e-12);
        assert!((alpha[m - 2] - beta[m - 1] * (1.0 - beta[m - 2])).abs() < 1e-12);
    }

    #[test]
    fn sweep_simulation_equals_fedavg() {
        // Simulate the scalar sweep: w ← β w + (1-β) v_t must land exactly
        // on Σ α_t v_t regardless of the starting global value.
        for seed in 0..20u64 {
            let m = 3 + (seed % 20) as usize;
            let alpha = random_alpha(m, seed * 13 + 1);
            let beta = solve_betas(&alpha).unwrap();
            let mut r = Rng::new(seed);
            let vals: Vec<f64> = (0..m).map(|_| r.range_f64(-5.0, 5.0)).collect();
            let start = r.range_f64(-100.0, 100.0); // arbitrary stale global
            let mut w = start;
            for t in 0..m {
                w = beta[t] * w + (1.0 - beta[t]) * vals[t];
            }
            let fedavg: f64 = alpha.iter().zip(&vals).map(|(a, v)| a * v).sum();
            assert!((w - fedavg).abs() < 1e-9, "seed={seed}: {w} vs {fedavg}");
        }
    }

    #[test]
    fn naive_coefficients_decay_geometrically() {
        // Sec. III-A: with uniform α=1/M reused naively, the first
        // scheduled client's effective weight is α(1-α)^{M-1} — vanishing.
        let m = 20;
        let alpha = uniform_alpha(m);
        let coeff = naive_effective_coefficients(&alpha);
        let a = 1.0 / m as f64;
        let expect_first = a * (1.0 - a).powi((m - 1) as i32);
        assert!((coeff[0] - expect_first).abs() < 1e-12);
        // Monotone increasing along the schedule, and NOT summing to 1.
        for w in coeff.windows(2) {
            assert!(w[0] < w[1]);
        }
        let total: f64 = coeff.iter().sum();
        assert!(total < 1.0 - 0.3, "naive sweep keeps stale-global mass: {total}");
        // coeff[0]/coeff[M-1] = (1-α)^{M-1} ≈ 1/e for uniform α=1/M.
        assert!(coeff[0] < 0.5 * coeff[m - 1], "early client crushed");
        // Over k repeated sweeps the first upload's weight decays like
        // (1-α)^{kM-1} — vanishing geometrically, the paper's point.
        let k_sweeps = 5;
        let long: Vec<f64> = (0..k_sweeps).flat_map(|_| alpha.clone()).collect();
        let coeff_long = naive_effective_coefficients(&long);
        assert!(
            coeff_long[0] < 0.01 * coeff_long[k_sweeps * m - 1],
            "{} vs {}",
            coeff_long[0],
            coeff_long[k_sweeps * m - 1]
        );
    }

    #[test]
    fn rejects_bad_alphas() {
        assert!(solve_betas(&[]).is_err());
        assert!(solve_betas(&[0.5, 0.6]).is_err()); // sum > 1
        assert!(solve_betas(&[1.2, -0.2]).is_err()); // out of range
    }

    #[test]
    fn single_client_degenerate() {
        let beta = solve_betas(&[1.0]).unwrap();
        assert_eq!(beta, vec![0.0]);
    }
}
