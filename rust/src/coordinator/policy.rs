//! The open policy layer: how the server weighs an incoming update
//! ([`AggregationPolicy`]) and which upload-slot contender is served
//! next ([`SchedulingPolicy`]).
//!
//! The paper's contribution is exactly this seam — Sec. III studies three
//! aggregation rules over one engine, and related work (Hu et al.,
//! arXiv:2107.11415; AsyncFedED, arXiv:2205.13797) treats scheduling and
//! aggregation as independent axes. Both traits are object-safe so new
//! strategies are ~50-line plug-ins consumed by `ServerCore` and the
//! event-loop drivers, never new engines.
//!
//! Built-in aggregation policies (registry spelling → rule):
//!
//! | Spelling                 | Rule                                   | Source |
//! |--------------------------|----------------------------------------|--------|
//! | `naive`                  | constant α = 1/M                       | Sec. III-A |
//! | `solved`                 | per-sweep solved β schedule            | Sec. III-B |
//! | `staleness[:γ]`          | eq. (11) min(1, μ/(γ·j·(j-i)))         | Sec. III-C |
//! | `fedasync[:a[,mix]]`     | mix·(1+s)^(-a) polynomial decay        | Xie et al., FedAsync |
//! | `adaptive[:η[,ρ]]`       | update-norm-normalized, staleness-damped | AsyncFedED-style |
//!
//! Parse a spelling with `<dyn AggregationPolicy>::parse`.

use anyhow::{bail, ensure, Result};

use super::beta_solver::solve_betas;
use super::scheduler::UploadRequest;
use super::staleness::local_weight;
use crate::util::spec::parse_spec;

/// Everything the server knows about an incoming update at the moment it
/// must choose an aggregation weight. Built by `ServerCore`; policies
/// read from it and never touch IO or global state.
#[derive(Debug, Clone, Copy)]
pub struct UpdateObservation {
    /// Uploading client id.
    pub client: usize,
    /// 1-based global iteration j of the aggregation being performed.
    pub iteration: u64,
    /// Staleness j - i: aggregations since the client fetched its base.
    pub staleness: u64,
    /// Running mean staleness μ_ji *before* observing this update.
    pub mu: f64,
    /// Uniform data share α = 1/M (equal shards).
    pub alpha: f64,
    /// L2 norm of `local - global`; populated only when the policy
    /// declares [`AggregationPolicy::needs_update_norm`] (it costs a
    /// full pass over the parameters), else 0.
    pub update_norm: f64,
}

/// How the server picks the weight `1-β_j` given to an uploaded local
/// model (eq. 3: `w ← β_j·w + (1-β_j)·w_local`). Object-safe: engines
/// hold `Box<dyn AggregationPolicy>`.
pub trait AggregationPolicy: Send {
    /// The weight in `[0, 1]` given to the local model for this update.
    /// May mutate internal state (trackers, schedules); called exactly
    /// once per aggregation, in aggregation order.
    fn weight(&mut self, obs: &UpdateObservation) -> f64;

    /// Canonical series label, e.g. `staleness g=0.2` or `fedasync a=0.5`.
    fn label(&self) -> String;

    /// Clear mutable state so the policy can drive a fresh run. Default
    /// no-op for stateless policies; `SolvedBeta`/`AdaptiveDistance`
    /// override it. (Engines construct policies fresh per run, so this
    /// matters only when a caller reuses one across runs.)
    fn reset(&mut self) {}

    /// Whether [`UpdateObservation::update_norm`] must be populated.
    /// Defaults to false because the norm costs a pass over the model.
    fn needs_update_norm(&self) -> bool {
        false
    }

    /// The f32 β applied to the *global* model for the weight just
    /// returned. Default `1 - weight`; policies whose natural
    /// parameterization is β itself (the solved Sec. III-B schedule)
    /// override this to avoid a lossy double rounding.
    fn beta(&self, weight: f64) -> f32 {
        (1.0 - weight) as f32
    }
}

/// Context the registry needs to instantiate policies whose defaults
/// derive from the run configuration.
#[derive(Debug, Clone, Copy)]
pub struct PolicyParams {
    /// Number of clients M (α = 1/M, solved-β schedule length).
    pub clients: usize,
    /// Default eq.-(11) γ when the spelling names none.
    pub gamma: f64,
}

/// One canonical registry spelling per built-in policy (tests iterate
/// these; docs list them).
pub const POLICY_SPECS: [&str; 5] = ["naive", "solved", "staleness", "fedasync:0.5", "adaptive"];

impl dyn AggregationPolicy {
    /// Instantiate a policy from its registry spelling
    /// `name[:p1[,p2...]]` — e.g. `staleness:0.4` or `fedasync:0.5,0.9`.
    /// Unknown names and malformed parameters are errors naming the
    /// offending token.
    pub fn parse(spec: &str, params: &PolicyParams) -> Result<Box<dyn AggregationPolicy>> {
        let (name, f) = parse_spec(spec)?;
        match name.to_ascii_lowercase().as_str() {
            "naive" | "alpha" => {
                ensure!(f.is_empty(), "policy {name:?} takes no parameters");
                Ok(Box::new(NaiveAlpha))
            }
            "solved" | "solved-beta" | "baseline" => {
                ensure!(f.is_empty(), "policy {name:?} takes no parameters");
                Ok(Box::new(SolvedBeta::new(params.clients)?))
            }
            "staleness" | "csmaafl" | "eq11" => {
                ensure!(f.len() <= 1, "staleness takes at most one parameter (γ)");
                let gamma = f.first().copied().unwrap_or(params.gamma);
                Ok(Box::new(StalenessEq11::new(gamma)?))
            }
            "fedasync" => {
                ensure!(f.len() <= 2, "fedasync takes at most two parameters (a, mix)");
                let a = f.first().copied().unwrap_or(0.5);
                let mix = f.get(1).copied().unwrap_or(0.6);
                Ok(Box::new(FedAsyncPoly::new(a, mix)?))
            }
            "adaptive" | "adaptive-distance" | "asyncfeded" => {
                ensure!(f.len() <= 2, "adaptive takes at most two parameters (η, ρ)");
                let eta = f.first().copied().unwrap_or(0.5);
                let rho = f.get(1).copied().unwrap_or(0.1);
                Ok(Box::new(AdaptiveDistance::new(eta, rho)?))
            }
            other => bail!(
                "unknown aggregation policy {other:?} \
                 (naive | solved | staleness[:g] | fedasync[:a[,mix]] | adaptive[:eta[,rho]])"
            ),
        }
    }
}

/// Sec. III-A: reuse the synchronous coefficient asynchronously —
/// constant weight α = 1/M (the paper's negative result). Reads the
/// core-supplied data share, so the α definition lives in one place.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveAlpha;

impl AggregationPolicy for NaiveAlpha {
    fn weight(&mut self, obs: &UpdateObservation) -> f64 {
        obs.alpha
    }

    fn label(&self) -> String {
        "naive".into()
    }
}

/// Sec. III-B: the predetermined per-sweep β schedule solved so every
/// M-upload sweep reproduces one synchronous FedAvg round exactly
/// (eqs. 9–10). Cycles through schedule positions; `reset` rewinds to a
/// sweep boundary.
///
/// Caveat: the equivalence (and the forced β=0 at position 0, which
/// *replaces* the global with one client's model) presumes the
/// Sec. III-B driver — all M clients trained from the same broadcast,
/// one upload each per sweep, as `run_afl_baseline` schedules. Under
/// the free-running event engine or the TCP leader the schedule has no
/// such guarantee and this policy is only a diagnostic.
#[derive(Debug, Clone)]
pub struct SolvedBeta {
    betas: Vec<f64>,
    pos: usize,
    last_beta: f32,
}

impl SolvedBeta {
    /// Solve the sweep schedule for `clients` equal shards.
    pub fn new(clients: usize) -> Result<SolvedBeta> {
        ensure!(clients > 0, "solved-beta needs at least one client");
        let alpha = vec![1.0 / clients as f64; clients];
        let betas = solve_betas(&alpha)?;
        Ok(SolvedBeta {
            betas,
            pos: 0,
            last_beta: 1.0,
        })
    }
}

impl AggregationPolicy for SolvedBeta {
    fn weight(&mut self, _obs: &UpdateObservation) -> f64 {
        let t = self.pos;
        self.pos = (self.pos + 1) % self.betas.len();
        self.last_beta = self.betas[t] as f32;
        1.0 - self.betas[t]
    }

    fn label(&self) -> String {
        "solved-beta".into()
    }

    fn reset(&mut self) {
        self.pos = 0;
        self.last_beta = 1.0;
    }

    // β is the solved quantity: hand it over exactly as solved rather
    // than reconstructing it as 1-(1-β) through two roundings.
    fn beta(&self, _weight: f64) -> f32 {
        self.last_beta
    }
}

/// Sec. III-C eq. (11): `1-β_j = min(1, μ_ji / (γ·j·(j-i)))` — the
/// paper's staleness-aware rule. μ comes from the core's tracker via the
/// observation, so the simulator and the TCP leader provably share one
/// implementation.
#[derive(Debug, Clone)]
pub struct StalenessEq11 {
    gamma: f64,
}

impl StalenessEq11 {
    /// Eq.-(11) policy with hyper-parameter γ > 0.
    pub fn new(gamma: f64) -> Result<StalenessEq11> {
        ensure!(gamma > 0.0, "gamma must be > 0, got {gamma}");
        Ok(StalenessEq11 { gamma })
    }
}

impl AggregationPolicy for StalenessEq11 {
    fn weight(&mut self, obs: &UpdateObservation) -> f64 {
        local_weight(obs.mu, self.gamma, obs.iteration, obs.staleness)
    }

    fn label(&self) -> String {
        format!("staleness g={}", self.gamma)
    }
}

/// FedAsync polynomial staleness decay (Xie et al., arXiv:1903.03934, as
/// in the APPFL `FedAsyncAggregator`): weight = mix·(1+s)^(-a), with
/// `mix` the mixing rate α and `a` the decay exponent.
#[derive(Debug, Clone)]
pub struct FedAsyncPoly {
    a: f64,
    mix: f64,
}

impl FedAsyncPoly {
    /// Polynomial decay with exponent `a >= 0` and mixing rate
    /// `mix ∈ (0, 1]`.
    pub fn new(a: f64, mix: f64) -> Result<FedAsyncPoly> {
        ensure!(a >= 0.0, "fedasync exponent must be >= 0, got {a}");
        ensure!(
            mix > 0.0 && mix <= 1.0,
            "fedasync mix must be in (0,1], got {mix}"
        );
        Ok(FedAsyncPoly { a, mix })
    }
}

impl AggregationPolicy for FedAsyncPoly {
    fn weight(&mut self, obs: &UpdateObservation) -> f64 {
        self.mix * (1.0 + obs.staleness as f64).powf(-self.a)
    }

    fn label(&self) -> String {
        // Both parameters, so distinct configs never share a label (the
        // label names result files and CSV series).
        format!("fedasync a={} mix={}", self.a, self.mix)
    }
}

/// AsyncFedED-style adaptive weighting (arXiv:2205.13797): normalize by
/// the update's distance `‖w_local - w_global‖` relative to a running
/// mean of observed distances, then damp by staleness. Outlier-sized
/// updates (divergent stale clients) are shrunk; typical-sized fresh
/// updates get the base weight η.
#[derive(Debug, Clone)]
pub struct AdaptiveDistance {
    eta: f64,
    rho: f64,
    ref_norm: f64,
    seen: u64,
}

impl AdaptiveDistance {
    /// Base weight `eta ∈ (0, 1]`, reference-norm EMA rate `rho ∈ (0, 1]`.
    pub fn new(eta: f64, rho: f64) -> Result<AdaptiveDistance> {
        ensure!(
            eta > 0.0 && eta <= 1.0,
            "adaptive eta must be in (0,1], got {eta}"
        );
        ensure!(
            rho > 0.0 && rho <= 1.0,
            "adaptive rho must be in (0,1], got {rho}"
        );
        Ok(AdaptiveDistance {
            eta,
            rho,
            ref_norm: 1.0,
            seen: 0,
        })
    }
}

impl AggregationPolicy for AdaptiveDistance {
    fn weight(&mut self, obs: &UpdateObservation) -> f64 {
        let norm = obs.update_norm.max(1e-12);
        if self.seen == 0 {
            // Seed with the first real observation, like the μ tracker.
            self.ref_norm = norm;
        } else {
            self.ref_norm = (1.0 - self.rho) * self.ref_norm + self.rho * norm;
        }
        self.seen += 1;
        // Cap the amplification of unusually small updates at 2x.
        let scale = (self.ref_norm / norm).min(2.0);
        let damp = 1.0 + obs.staleness as f64;
        (self.eta * scale / damp).clamp(0.0, 1.0)
    }

    fn label(&self) -> String {
        format!("adaptive e={} r={}", self.eta, self.rho)
    }

    fn reset(&mut self) {
        self.ref_norm = 1.0;
        self.seen = 0;
    }

    fn needs_update_norm(&self) -> bool {
        true
    }
}

// --------------------------------------------------------- scheduling

/// Read-only scheduler bookkeeping a [`SchedulingPolicy`] may consult
/// when arbitrating a slot.
#[derive(Debug)]
pub struct SchedulerView<'a> {
    /// Slot index of each client's previous upload; `None` = never
    /// uploaded. Length = number of clients.
    pub last_slot: &'a [Option<u64>],
    /// Instantaneous per-client channel gain (length = clients) when
    /// the engine drives a fading channel (`sim::channel`); `None`
    /// under an ideal channel. Engines refresh only the entries of
    /// clients with a pending request; age/time policies ignore it.
    pub gains: Option<&'a [f64]>,
}

/// Upload-slot arbitration: given the pending requests, pick which one
/// is granted the TDMA slot now. Object-safe; the bookkeeping
/// (`last_slot`, grant counts) lives in `UploadScheduler`, so policies
/// stay pure arbitration rules.
pub trait SchedulingPolicy: Send + std::fmt::Debug {
    /// Canonical name (config spelling).
    fn label(&self) -> &'static str;

    /// Index into `pending` of the request to grant, or `None` to leave
    /// the slot idle (e.g. round-robin waiting for the next in cycle).
    /// A returned index is always granted immediately.
    fn pick(&mut self, pending: &[UploadRequest], view: &SchedulerView<'_>) -> Option<usize>;
}

/// First-come-first-served on request time; ties broken by client id.
#[derive(Debug, Default, Clone)]
pub struct Fifo;

impl SchedulingPolicy for Fifo {
    fn label(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, pending: &[UploadRequest], _view: &SchedulerView<'_>) -> Option<usize> {
        pending
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| (r.requested_at, r.client))
            .map(|(i, _)| i)
    }
}

/// CSMAAFL Sec. III-C: the client whose last upload is oldest wins (the
/// paper's `(k-m') > (k-n')` rule); ties by request time, then id.
/// Never-uploaded clients sort before any slot index.
#[derive(Debug, Default, Clone)]
pub struct OldestModelFirst;

impl SchedulingPolicy for OldestModelFirst {
    fn label(&self) -> &'static str {
        "oldest"
    }

    fn pick(&mut self, pending: &[UploadRequest], view: &SchedulerView<'_>) -> Option<usize> {
        pending
            .iter()
            .enumerate()
            .min_by_key(|(_, r)| {
                let last = view.last_slot[r.client].map_or(-1i64, |s| s as i64);
                (last, r.requested_at, r.client)
            })
            .map(|(i, _)| i)
    }
}

/// Strict cyclic order over client ids (the Sec. III-B requirement: a
/// client is re-scheduled only after all others uploaded). Leaves the
/// slot idle until the next client in cycle has requested.
#[derive(Debug, Default, Clone)]
pub struct RoundRobin {
    next: usize,
}

impl SchedulingPolicy for RoundRobin {
    fn label(&self) -> &'static str {
        "roundrobin"
    }

    fn pick(&mut self, pending: &[UploadRequest], view: &SchedulerView<'_>) -> Option<usize> {
        let pos = pending.iter().position(|r| r.client == self.next)?;
        self.next = (self.next + 1) % view.last_slot.len().max(1);
        Some(pos)
    }
}

/// Channel-aware arbitration (Hu et al., arXiv:2107.11415): weight model
/// age against instantaneous link quality. Among pending requests the
/// score `(last_slot + 1) / gain` is minimized — stale models push a
/// client forward, a faded channel (small gain) holds it back — with
/// ties broken by request time, then id. Never-uploaded clients score 0
/// and always win their first slot. When the view carries no gains
/// (ideal channel) every gain is 1 and the ordering degenerates to
/// exactly [`OldestModelFirst`]'s `(last, requested_at, client)` key.
#[derive(Debug, Default, Clone)]
pub struct ChannelAware;

impl ChannelAware {
    fn score(r: &UploadRequest, view: &SchedulerView<'_>) -> f64 {
        let age = view.last_slot[r.client].map_or(0.0, |s| s as f64 + 1.0);
        let gain = view.gains.map_or(1.0, |g| g[r.client]);
        age / gain
    }
}

impl SchedulingPolicy for ChannelAware {
    fn label(&self) -> &'static str {
        "channel-aware"
    }

    fn pick(&mut self, pending: &[UploadRequest], view: &SchedulerView<'_>) -> Option<usize> {
        pending
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                Self::score(a, view)
                    .total_cmp(&Self::score(b, view))
                    .then_with(|| (a.requested_at, a.client).cmp(&(b.requested_at, b.client)))
            })
            .map(|(i, _)| i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obs(staleness: u64, iteration: u64) -> UpdateObservation {
        UpdateObservation {
            client: 0,
            iteration,
            staleness,
            mu: 4.0,
            alpha: 0.1,
            update_norm: 1.0,
        }
    }

    #[test]
    fn registry_parses_every_canonical_spelling() {
        let params = PolicyParams {
            clients: 10,
            gamma: 0.2,
        };
        for spec in POLICY_SPECS {
            let p = <dyn AggregationPolicy>::parse(spec, &params).unwrap();
            assert!(!p.label().is_empty(), "{spec}");
        }
    }

    #[test]
    fn registry_rejects_unknown_and_malformed() {
        let params = PolicyParams {
            clients: 10,
            gamma: 0.2,
        };
        assert!(<dyn AggregationPolicy>::parse("bogus", &params).is_err());
        assert!(<dyn AggregationPolicy>::parse("fedasync:x", &params).is_err());
        assert!(<dyn AggregationPolicy>::parse("staleness:0.1,0.2", &params).is_err());
        assert!(<dyn AggregationPolicy>::parse("naive:1", &params).is_err());
        assert!(<dyn AggregationPolicy>::parse("staleness:-1", &params).is_err());
        assert!(<dyn AggregationPolicy>::parse("fedasync:0.5,2.0", &params).is_err());
    }

    #[test]
    fn parameterized_spellings_override_defaults() {
        let params = PolicyParams {
            clients: 10,
            gamma: 0.2,
        };
        let p = <dyn AggregationPolicy>::parse("staleness:0.4", &params).unwrap();
        assert_eq!(p.label(), "staleness g=0.4");
        let p = <dyn AggregationPolicy>::parse("fedasync:1.0,0.9", &params).unwrap();
        assert_eq!(p.label(), "fedasync a=1 mix=0.9");
        let p = <dyn AggregationPolicy>::parse("adaptive:0.8,0.2", &params).unwrap();
        assert_eq!(p.label(), "adaptive e=0.8 r=0.2");
    }

    #[test]
    fn naive_echoes_the_core_supplied_alpha() {
        let mut p = NaiveAlpha;
        assert_eq!(p.weight(&obs(0, 1)), 0.1);
        assert_eq!(p.weight(&obs(50, 900)), 0.1, "staleness-independent");
    }

    #[test]
    fn staleness_policy_matches_local_weight() {
        let mut p = StalenessEq11::new(0.2).unwrap();
        let o = obs(5, 40);
        assert_eq!(p.weight(&o), local_weight(4.0, 0.2, 40, 5));
    }

    #[test]
    fn solved_beta_cycles_and_hands_over_exact_f32() {
        for m in [1usize, 2, 5, 20, 64] {
            let alpha = vec![1.0 / m as f64; m];
            let betas = solve_betas(&alpha).unwrap();
            let mut p = SolvedBeta::new(m).unwrap();
            // Two full sweeps: position must cycle, β must be bit-exact.
            for sweep in 0..2 {
                for (t, &b) in betas.iter().enumerate() {
                    let w = p.weight(&obs(t as u64, 1 + t as u64));
                    assert_eq!(p.beta(w), b as f32, "m={m} sweep={sweep} t={t}");
                    assert!((w - (1.0 - b)).abs() < 1e-15);
                }
            }
        }
    }

    #[test]
    fn fedasync_decays_polynomially() {
        let mut p = FedAsyncPoly::new(1.0, 0.6).unwrap();
        assert!((p.weight(&obs(0, 1)) - 0.6).abs() < 1e-12);
        assert!((p.weight(&obs(1, 2)) - 0.3).abs() < 1e-12);
        assert!((p.weight(&obs(5, 6)) - 0.1).abs() < 1e-12);
        // a = 0 disables the decay entirely.
        let mut flat = FedAsyncPoly::new(0.0, 0.6).unwrap();
        assert!((flat.weight(&obs(40, 41)) - 0.6).abs() < 1e-12);
    }

    #[test]
    fn adaptive_damps_outsized_and_stale_updates() {
        let mut p = AdaptiveDistance::new(0.5, 0.1).unwrap();
        // First observation seeds the reference: typical fresh update.
        let base = p.weight(&obs(0, 1));
        assert!((base - 0.5).abs() < 1e-12);
        // A 10x-larger update is shrunk well below the base weight.
        let mut big = obs(0, 2);
        big.update_norm = 10.0;
        assert!(p.weight(&big) < base / 2.0);
        // Staleness damps hyperbolically.
        p.reset();
        let fresh = p.weight(&obs(0, 1));
        let stale = p.weight(&obs(9, 10));
        assert!(stale < fresh / 5.0);
    }

    #[test]
    fn scheduling_policies_report_labels() {
        assert_eq!(Fifo.label(), "fifo");
        assert_eq!(OldestModelFirst.label(), "oldest");
        assert_eq!(RoundRobin::default().label(), "roundrobin");
        assert_eq!(ChannelAware.label(), "channel-aware");
    }

    #[test]
    fn channel_aware_matches_oldest_without_gains() {
        // Ideal channel (no gains): the score ordering must reproduce
        // oldest-model-first exactly, including both tie-break levels.
        let last_slot = [Some(3), None, Some(1), Some(1)];
        let pending = [
            UploadRequest {
                client: 0,
                requested_at: 2,
            },
            UploadRequest {
                client: 2,
                requested_at: 9,
            },
            UploadRequest {
                client: 3,
                requested_at: 5,
            },
            UploadRequest {
                client: 1,
                requested_at: 7,
            },
        ];
        let view = SchedulerView {
            last_slot: &last_slot,
            gains: None,
        };
        let mut ca = ChannelAware;
        let mut omf = OldestModelFirst;
        let mut rest: Vec<UploadRequest> = pending.to_vec();
        while !rest.is_empty() {
            let a = ca.pick(&rest, &view).unwrap();
            let b = omf.pick(&rest, &view).unwrap();
            assert_eq!(a, b, "{rest:?}");
            rest.swap_remove(a);
        }
    }

    #[test]
    fn channel_aware_weighs_age_against_gain() {
        // Client 0 is staler (slot 1 vs 4) but deeply faded; client 1's
        // strong channel wins: 2/0.25 = 8 > 5/2 = 2.5.
        let last_slot = [Some(1), Some(4)];
        let gains = [0.25, 2.0];
        let pending = [
            UploadRequest {
                client: 0,
                requested_at: 0,
            },
            UploadRequest {
                client: 1,
                requested_at: 0,
            },
        ];
        let view = SchedulerView {
            last_slot: &last_slot,
            gains: Some(&gains),
        };
        assert_eq!(ChannelAware.pick(&pending, &view), Some(1));
        // A never-uploaded client scores 0 and beats any gain.
        let last_slot = [None, Some(4)];
        let view = SchedulerView {
            last_slot: &last_slot,
            gains: Some(&gains),
        };
        assert_eq!(ChannelAware.pick(&pending, &view), Some(0));
    }
}
