//! Sec. III-B baseline AFL: asynchronous uploads with coefficients solved
//! so each M-iteration sweep reproduces the synchronous FedAvg aggregate
//! exactly.
//!
//! Structure per the paper's requirements: (a) a client is rescheduled
//! only after all others uploaded (one upload each per sweep), (b) the
//! schedule is predetermined — fastest clients first, so uploads overlap
//! slower clients' compute, (c) the global model is broadcast to all
//! clients every M iterations.

use anyhow::Result;

use super::beta_solver::solve_betas;
use super::runner::{FlContext, Recorder};
use crate::learner::BatchCursor;
use crate::metrics::RunResult;
use crate::sim::ComputeModel;
use crate::util::rng::Rng;

/// Run the Sec. III-B baseline: predetermined fastest-first sweeps whose
/// solved β coefficients make every M-upload sweep reproduce one
/// synchronous FedAvg round exactly.
pub fn run_afl_baseline(ctx: &FlContext<'_>) -> Result<RunResult> {
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    // Predetermined schedule: fastest first (requirement b).
    let order = cm.fastest_first();
    // Equal shards ⇒ uniform α; solve the sweep coefficients once.
    let alpha = vec![1.0 / m as f64; m];
    let betas = solve_betas(&alpha)?;

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();
    let mut cursors: Vec<BatchCursor> = ctx
        .shards
        .iter()
        .map(|s| BatchCursor::new(s.indices.clone()))
        .collect();

    let mut w = ctx.learner.init(cfg.seed as u32)?;
    let mut now: u64 = 0;
    let mut j: u64 = 0;
    let mut uploads = vec![0u64; m];
    let mut staleness_sum = 0.0f64;
    let mut xs = Vec::new();
    let mut ys = Vec::new();

    while now < max_ticks {
        // Broadcast (requirement c): every client starts from this w.
        let broadcast_done = now + cfg.time.tau_down;
        // Clients compute in parallel; each is ready at a different time.
        let ready: Vec<u64> = (0..m)
            .map(|c| broadcast_done + cm.duration(&cfg.time, c, cfg.local_steps, &mut jrng))
            .collect();

        // All local models are trained from the SAME broadcast global —
        // that is what makes the solved-β sweep equal one FedAvg round.
        let locals: Vec<_> = (0..m)
            .map(|c| {
                cursors[c].fill(ctx.train, cfg.local_steps * batch, img, &mut xs, &mut ys);
                ctx.learner
                    .train(&w, &xs, &ys, cfg.local_steps)
                    .map(|(p, _)| p)
            })
            .collect::<Result<_>>()?;

        // TDMA uploads in schedule order; the channel serializes them.
        let mut channel_free = broadcast_done;
        for (t, &c) in order.iter().enumerate() {
            let start = channel_free.max(ready[c]);
            let end = start + cfg.time.tau_up;
            channel_free = end;
            rec.catch_up(end.min(max_ticks), &w, j)?;
            // Aggregation (eq. 3) with the solved coefficient.
            ctx.aggregate(&mut w, &locals[c], betas[t] as f32)?;
            j += 1;
            uploads[c] += 1;
            // Staleness bookkeeping: client scheduled at position t sees
            // t aggregations since the sweep's broadcast.
            staleness_sum += t as f64;
        }
        now = channel_free;
    }
    rec.finish(&w, j)?;

    let fairness = 1.0; // one upload per client per sweep, by construction
    let mean_staleness = if j > 0 { staleness_sum / j as f64 } else { 0.0 };
    Ok(rec.into_result(
        "afl-baseline".into(),
        uploads,
        j,
        mean_staleness,
        fairness,
        max_ticks,
    ))
}
