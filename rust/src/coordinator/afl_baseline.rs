//! Sec. III-B baseline AFL: asynchronous uploads with coefficients solved
//! so each M-iteration sweep reproduces the synchronous FedAvg aggregate
//! exactly.
//!
//! Structure per the paper's requirements: (a) a client is rescheduled
//! only after all others uploaded (one upload each per sweep), (b) the
//! schedule is predetermined — fastest clients first, so uploads overlap
//! slower clients' compute, (c) the global model is broadcast to all
//! clients every M iterations.
//!
//! The solved β schedule is the `SolvedBeta` aggregation policy; this
//! driver only simulates the sweep timing and feeds uploads through the
//! shared sans-IO `ServerCore`.
//!
//! The sweep structure presumes the static world — every client uploads
//! exactly once per broadcast — so `RunConfig::validate` rejects
//! non-`static` `scenario=` spellings for this algorithm (dropout or
//! churn would break the exact-equivalence guarantee the solved β
//! coefficients encode).

use anyhow::Result;

use super::core::ServerCore;
use super::policy::SolvedBeta;
use super::runner::{FlContext, Recorder, RunStats};
use crate::learner::BatchCursor;
use crate::metrics::RunResult;
use crate::model::ParamSet;
use crate::sim::ComputeModel;
use crate::util::rng::Rng;

/// Run the Sec. III-B baseline: predetermined fastest-first sweeps whose
/// solved β coefficients make every M-upload sweep reproduce one
/// synchronous FedAvg round exactly.
pub fn run_afl_baseline(ctx: &FlContext<'_>) -> Result<RunResult> {
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    // Predetermined schedule: fastest first (requirement b).
    let order = cm.fastest_first();
    // Equal shards ⇒ uniform α; the policy holds the solved sweep
    // coefficients and cycles them per schedule position.
    let mut core = ServerCore::new(
        ctx.learner.init(cfg.seed as u32)?,
        m,
        Box::new(SolvedBeta::new(m)?),
        cfg.mu_rho,
    );

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();
    let mut cursors: Vec<BatchCursor> = ctx
        .shards
        .iter()
        .map(|s| BatchCursor::new(s.indices.clone()))
        .collect();

    let mut now: u64 = 0;
    let mut xs = Vec::new();
    let mut ys = Vec::new();

    while now < max_ticks {
        // Broadcast (requirement c): every client starts from this w.
        // The sweep-start iteration stamps every client's base model, so
        // the core observes staleness t at schedule position t.
        let sweep_start = core.iteration();
        for c in 0..m {
            core.issue_to(c);
        }
        let broadcast_done = now + cfg.time.tau_down;
        // Clients compute in parallel; each is ready at a different time.
        let ready: Vec<u64> = (0..m)
            .map(|c| broadcast_done + cm.duration(&cfg.time, c, cfg.local_steps, &mut jrng))
            .collect();

        // All local models are trained from the SAME broadcast global —
        // that is what makes the solved-β sweep equal one FedAvg round.
        let mut locals: Vec<ParamSet> = Vec::with_capacity(m);
        let mut losses: Vec<f32> = Vec::with_capacity(m);
        {
            let w = core.global();
            for cursor in &mut cursors {
                cursor.fill(ctx.train, cfg.local_steps * batch, img, &mut xs, &mut ys);
                let (p, loss) = ctx.learner.train(w, &xs, &ys, cfg.local_steps)?;
                locals.push(p);
                losses.push(loss);
            }
        }
        for (c, &loss) in losses.iter().enumerate() {
            core.record_loss(c, loss as f64);
        }

        // TDMA uploads in schedule order; the channel serializes them.
        let mut channel_free = broadcast_done;
        for &c in order.iter() {
            let start = channel_free.max(ready[c]);
            let end = start + cfg.time.tau_up;
            channel_free = end;
            rec.catch_up(end.min(max_ticks), core.global(), core.iteration())?;
            // Aggregation (eq. 3) with the solved coefficient.
            core.on_update(c, sweep_start, &locals[c], ctx)?;
        }
        now = channel_free;
    }
    rec.finish(core.global(), core.iteration())?;

    let stats = RunStats {
        label: "afl-baseline".into(),
        uploads: core.updates_per_client().to_vec(),
        aggregations: core.iteration(),
        mean_staleness: core.mean_staleness(),
        fairness: 1.0, // one upload per client per sweep, by construction
        lost_uploads: 0,
        lost_per_client: vec![0; m],
        mean_train_loss: core.mean_train_loss(),
        classes: Vec::new(), // capacity is AFL-only (RunConfig::validate)
        channel: "ideal".into(), // and so are channel models
        bytes_on_wire: 0,
        channel_lost: 0,
        total_ticks: max_ticks,
    };
    Ok(rec.into_result(stats))
}
