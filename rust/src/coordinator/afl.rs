//! Event-driven asynchronous FL (Sec. II-B) with pluggable scheduling and
//! aggregation — runs both CSMAAFL (Sec. III-C) and the naive-coefficient
//! AFL (Sec. III-A).
//!
//! Lifecycle per client (Fig. 1 right / Fig. 2 bottom):
//!   DownloadDone(w_i) → local compute (`a_m·E'·τ_step`) → ComputeDone →
//!   upload-slot request → grant (TDMA, one at a time) → UploadDone →
//!   server aggregates w_{j+1} = β_j·w_j + (1-β_j)·w_i^m, sends the fresh
//!   global back to that client only.

use std::sync::Arc;

use anyhow::Result;

use super::runner::{FlContext, Recorder};
use super::scheduler::{SchedulerPolicy, UploadScheduler};
use super::staleness::{local_weight, StalenessTracker};
use crate::learner::BatchCursor;
use crate::metrics::RunResult;
use crate::model::ParamSet;
use crate::sim::{ComputeModel, EventQueue, UplinkChannel};
use crate::util::rng::Rng;

/// How the server picks β_j at each aggregation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BetaPolicy {
    /// Sec. III-A: reuse the SFL coefficient (β_j = 1 - α_m).
    NaiveAlpha,
    /// Sec. III-C eq. (11): staleness-aware with moving average μ.
    Staleness {
        /// The γ hyper-parameter of eq. (11).
        gamma: f64,
        /// EMA rate of the μ_ji staleness tracker.
        rho: f64,
    },
}

#[derive(Debug)]
enum Event {
    /// Client received a global model snapshot (sent at iteration `i`).
    /// The snapshot is shared, not cloned: the server never mutates a
    /// model that is in flight (aggregation replaces the Arc).
    DownloadDone {
        client: usize,
        w: Arc<ParamSet>,
        i: u64,
    },
    ComputeDone {
        client: usize,
    },
    UploadDone {
        client: usize,
    },
}

struct ClientState {
    cursor: BatchCursor,
    /// Local model awaiting upload + the iteration it started from.
    pending: Option<(ParamSet, u64)>,
}

/// Sec. III-C adaptive local-iteration policy (after [4]): clients scale
/// their local step count inversely with their slowness so every client's
/// compute phase lasts roughly the same and channel access stays fair.
pub fn adaptive_steps(base: usize, factor: f64, enabled: bool) -> usize {
    if !enabled {
        return base;
    }
    ((base as f64 / factor).round() as usize).clamp(1, base * 4)
}

/// Run the event-driven asynchronous engine: Algorithm 1 with the given
/// β policy (naive vs eq.-11 staleness-aware) and upload-slot
/// arbitration policy. `label` names the emitted series.
pub fn run_afl(
    ctx: &FlContext<'_>,
    beta_policy: BetaPolicy,
    sched_policy: SchedulerPolicy,
    label: String,
) -> Result<RunResult> {
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    // Identical slot unit as the paired SFL run: fair x-axis.
    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();
    let alpha = 1.0 / m as f64;

    let mut w = ctx.learner.init(cfg.seed as u32)?;
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut channel = UplinkChannel::new();
    let mut scheduler = UploadScheduler::new(sched_policy, m);
    let mut tracker = StalenessTracker::new(cfg.mu_rho);
    let mut clients: Vec<ClientState> = ctx
        .shards
        .iter()
        .map(|s| ClientState {
            cursor: BatchCursor::new(s.indices.clone()),
            pending: None,
        })
        .collect();

    let mut j: u64 = 0; // global aggregation count
    let mut staleness_sum: f64 = 0.0;
    let mut lost_uploads: u64 = 0;
    let mut xs = Vec::new();
    let mut ys = Vec::new();

    // t=0: the server broadcasts w_0 to everyone (Algorithm 1 line 1).
    // One shared snapshot for the whole broadcast.
    let w0 = Arc::new(w.clone());
    for c in 0..m {
        queue.schedule_at(cfg.time.tau_down, Event::DownloadDone {
            client: c,
            w: Arc::clone(&w0),
            i: 0,
        });
    }
    drop(w0);

    while let Some((now, ev)) = queue.pop() {
        if now > max_ticks {
            break;
        }
        match ev {
            Event::DownloadDone { client, w: w_recv, i } => {
                // Local learning (eq. 4) — executed now, surfaced at
                // ComputeDone per the virtual-time compute model.
                let steps = adaptive_steps(
                    cfg.local_steps,
                    cm.factor(client),
                    cfg.adaptive_iters,
                );
                clients[client]
                    .cursor
                    .fill(ctx.train, steps * batch, img, &mut xs, &mut ys);
                let (local, _loss) = ctx.learner.train(&w_recv, &xs, &ys, steps)?;
                clients[client].pending = Some((local, i));
                let dur = cm.duration(&cfg.time, client, steps, &mut jrng);
                queue.schedule_in(dur, Event::ComputeDone { client });
            }
            Event::ComputeDone { client } => {
                scheduler.request(client, now);
                if channel.is_free(now) {
                    if let Some(winner) = scheduler.grant() {
                        let done = channel.reserve(now, cfg.time.tau_up);
                        queue.schedule_at(done, Event::UploadDone { client: winner });
                    }
                }
            }
            Event::UploadDone { client } => {
                let (local, i) = clients[client]
                    .pending
                    .take()
                    .expect("upload without a pending local model");
                // Failure injection: the upload is lost in transit. The
                // server never sees the model; it re-sends the current
                // global so the client rejoins the loop.
                if cfg.upload_loss > 0.0 && jrng.f64() < cfg.upload_loss {
                    lost_uploads += 1;
                    queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                        client,
                        w: Arc::new(w.clone()),
                        i: j,
                    });
                    if channel.is_free(now) {
                        if let Some(winner) = scheduler.grant() {
                            let done = channel.reserve(now, cfg.time.tau_up);
                            queue.schedule_at(done, Event::UploadDone { client: winner });
                        }
                    }
                    continue;
                }
                // Evaluate cadence points that precede this aggregation.
                rec.catch_up(now, &w, j)?;

                let staleness = j - i;
                let weight = match beta_policy {
                    BetaPolicy::NaiveAlpha => alpha,
                    BetaPolicy::Staleness { gamma, .. } => {
                        let lw = local_weight(tracker.mu(), gamma, j + 1, staleness);
                        tracker.observe(staleness);
                        lw
                    }
                };
                staleness_sum += staleness as f64;
                let beta = (1.0 - weight) as f32;
                ctx.aggregate(&mut w, &local, beta)?; // eq. (3)/(11)
                j += 1;

                // Fresh global goes back to this client only (a snapshot:
                // further aggregations must not mutate an in-flight model).
                queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                    client,
                    w: Arc::new(w.clone()),
                    i: j,
                });
                // Channel freed: grant the next contender, if any.
                if channel.is_free(now) {
                    if let Some(winner) = scheduler.grant() {
                        let done = channel.reserve(now, cfg.time.tau_up);
                        queue.schedule_at(done, Event::UploadDone { client: winner });
                    }
                }
            }
        }
    }
    rec.finish(&w, j)?;
    if lost_uploads > 0 {
        crate::log_info!(
            "afl: {lost_uploads} uploads lost in transit ({} delivered)",
            j
        );
    }

    let uploads = scheduler.grants().to_vec();
    let fairness = scheduler.jain_fairness();
    let mean_staleness = if j > 0 { staleness_sum / j as f64 } else { 0.0 };
    Ok(rec.into_result(label, uploads, j, mean_staleness, fairness, max_ticks))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_steps_policy() {
        assert_eq!(adaptive_steps(16, 1.0, true), 16);
        assert_eq!(adaptive_steps(16, 2.0, true), 8);
        assert_eq!(adaptive_steps(16, 10.0, true), 2);
        assert_eq!(adaptive_steps(16, 100.0, true), 1, "floored");
        assert_eq!(adaptive_steps(16, 10.0, false), 16, "disabled");
        // Very fast clients don't blow up unboundedly.
        assert_eq!(adaptive_steps(16, 0.1, true), 64);
    }
}
