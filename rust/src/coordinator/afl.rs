//! Event-driven asynchronous FL (Sec. II-B): the virtual-time driver
//! shell around the sans-IO `ServerCore`.
//!
//! Lifecycle per client (Fig. 1 right / Fig. 2 bottom):
//!   DownloadDone(w_i) → local compute (`a_m·E'·τ_step`) → ComputeDone →
//!   upload-slot request → grant (TDMA, one at a time) → UploadDone →
//!   server aggregates w_{j+1} = β_j·w_j + (1-β_j)·w_i^m, sends the fresh
//!   global back to that client only.
//!
//! All server-side decisions — which β, which statistics — live in
//! `coordinator::core`/`coordinator::policy`; this file only simulates
//! time, compute and the uplink channel. The same core drives the TCP
//! deployment leader (`net::leader`), so the simulator and the
//! deployment share one aggregation code path.
//!
//! The *world* being simulated is a pluggable [`Scenario`]
//! (`sim::scenario`, config spelling `scenario=<name[:params]>`): the
//! loop consults it when drawing compute durations (`drift`), when a
//! client contends for the channel (`churn` — an offline client holds
//! its local model and re-contends on rejoin, so its eventual upload is
//! stale), and when an upload completes (`dropout`). The pinned
//! `static` default answers every hook with the identity and draws no
//! randomness, so default runs are bit-identical to the pre-scenario
//! engine.
//!
//! Heterogeneous capacity (`capacity=<profile>`, `sim::capacity`):
//! clients in a rate-r class compute r× faster and upload only the
//! leading r-slice of each tensor (`model::submodel`), which the server
//! merges slice-wise. One approximation vs the HeteroFL discipline the
//! scale sim implements: this engine's learner API has no sliced
//! training, so clients train the *full* model and upload the covered
//! slice. Submodel merges always run through the native slice kernels
//! (the PJRT aggregator has no slice path). The trivial `full` /
//! `uniform:1.0` profile takes the pre-submodel code path untouched.

use std::sync::Arc;

use anyhow::Result;

use super::core::ServerCore;
use super::policy::AggregationPolicy;
use super::runner::{FlContext, Recorder, RunStats};
use super::scale::{class_cells, scaled_tau_up, SubmodelCtx};
use super::scheduler::{SchedulerPolicy, UploadScheduler};
use crate::data::Dataset;
use crate::learner::BatchCursor;
use crate::metrics::{ClassMetrics, RunResult};
use crate::model::{ParamLayout, ParamSet, SubmodelMap};
use crate::net::wire::flat_update_wire_bytes;
use crate::sim::{
    capacity, channel, scenario, ChannelState, ComputeModel, EventQueue, Scenario, Ticks,
    UplinkChannel,
};
use crate::telemetry::{LossCause, Telemetry};
use crate::util::rng::Rng;

/// The learner-driven engines' event vocabulary, shared with the
/// sharded twin (`coordinator::learner_shard`) so both loops schedule
/// literally the same events at the same times.
#[derive(Debug)]
pub(super) enum Event {
    /// Client received a global model snapshot (sent at iteration `i`).
    /// The snapshot is shared, not cloned: the server never mutates a
    /// model that is in flight (aggregation replaces the Arc).
    DownloadDone {
        client: usize,
        w: Arc<ParamSet>,
        i: u64,
    },
    ComputeDone {
        client: usize,
    },
    UploadDone {
        client: usize,
    },
}

struct ClientState {
    cursor: BatchCursor,
    /// Local model awaiting upload + the iteration it started from.
    pending: Option<(ParamSet, u64)>,
}

/// Sec. III-C adaptive local-iteration policy (after [4]): clients scale
/// their local step count inversely with their slowness so every client's
/// compute phase lasts roughly the same and channel access stays fair.
pub fn adaptive_steps(base: usize, factor: f64, enabled: bool) -> usize {
    if !enabled {
        return base;
    }
    ((base as f64 / factor).round() as usize).clamp(1, base * 4)
}

/// If the uplink is idle, grant the next contender a slot and schedule
/// its upload completion (the TDMA channel-grant step, shared by every
/// place an upload can start or the channel can free up — and by the
/// sharded twin in `coordinator::learner_shard`).
///
/// Under a fading channel the contenders' instantaneous gains are
/// refreshed first (gain-sensitive arbitration reads them through the
/// scheduler view) and the winner's slot is stretched by its gain; the
/// trivial `ideal` model skips both, leaving the pre-channel timeline
/// untouched.
#[allow(clippy::too_many_arguments)]
pub(super) fn grant_next(
    scheduler: &mut UploadScheduler,
    channel: &mut UplinkChannel,
    fading: &mut ChannelState,
    gains: &mut [f64],
    queue: &mut EventQueue<Event>,
    now: Ticks,
    tau_up_for: impl Fn(usize) -> Ticks,
    tel: &mut Telemetry,
) {
    if channel.is_free(now) {
        let winner = if fading.is_trivial() {
            scheduler.grant()
        } else {
            for r in scheduler.pending_clients() {
                gains[r.client] = fading.gain(r.client, now);
            }
            scheduler.grant_with_gains(Some(gains))
        };
        if let Some(winner) = winner {
            if tel.is_enabled() {
                let level = if fading.is_trivial() {
                    -1
                } else {
                    channel::level_of_gain(fading.gain(winner, now))
                        .map(|l| l as i8)
                        .unwrap_or(-1)
                };
                tel.grant(now, winner, scheduler.pending_len(), level);
            }
            let dur = fading.scaled_tau(winner, now, tau_up_for(winner));
            let done = channel.reserve(now, dur);
            queue.schedule_at(done, Event::UploadDone { client: winner });
        }
    }
}

/// Run the event-driven asynchronous engine: Algorithm 1 with the given
/// aggregation policy and upload-slot arbitration policy. `label` names
/// the emitted series.
pub fn run_afl(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
) -> Result<RunResult> {
    run_afl_full(ctx, policy, sched_policy, label).map(|(result, _)| result)
}

/// As [`run_afl`], also yielding the final global model — the
/// bit-identity witness `rust/tests/sharded.rs` compares against the
/// sharded learner engine (`coordinator::learner_shard`), for which
/// this sequential loop is the executable spec.
pub fn run_afl_full(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
) -> Result<(RunResult, ParamSet)> {
    run_afl_traced(ctx, policy, sched_policy, label, &mut Telemetry::off())
}

/// As [`run_afl_full`], recording ordered trace events and aggregate
/// histograms through `tel`. All emission happens on this (the only)
/// thread at the engine's decision points, so the sharded twin
/// (`coordinator::learner_shard`) reproduces the trace byte-for-byte.
pub fn run_afl_traced(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
    tel: &mut Telemetry,
) -> Result<(RunResult, ParamSet)> {
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    // Identical slot unit as the paired SFL run: fair x-axis.
    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    // The world model (static | dropout | churn | drift). Stochastic
    // scenarios draw from their own forked streams, never from `jrng`.
    let mut world: Box<dyn Scenario> = scenario::resolve(cfg.scenario.as_deref())?;
    world.bind(m, slot_ticks, cfg.seed);
    if cfg.scenario.is_some() {
        crate::log_info!("afl[{}]: scenario {}", label, world.label());
    }

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();

    let w_init = ctx.learner.init(cfg.seed as u32)?;
    // Heterogeneous capacity: assign each client a submodel rate and
    // precompute one slice map per class. The trivial profile stays
    // `None` so the pre-submodel paths below run literally unchanged.
    let profile = capacity::resolve(cfg.capacity.as_deref())?;
    let subctx: Option<SubmodelCtx> = if profile.is_trivial() {
        None
    } else {
        let layout = ParamLayout::of(&w_init);
        let class_of = profile.assign(m, &root);
        let maps: Vec<SubmodelMap> = profile
            .classes()
            .iter()
            .map(|c| SubmodelMap::new(&layout, c.rate))
            .collect();
        crate::log_info!("afl[{}]: capacity {}", label, profile.spec());
        Some(SubmodelCtx {
            profile,
            class_of,
            maps,
        })
    };
    // Reusable packed-slice upload buffer, sized to the largest map.
    let mut subbuf = vec![
        0.0f32;
        subctx.as_ref().map_or(0, |sc| {
            sc.maps.iter().map(|mp| mp.numel()).max().unwrap_or(0)
        })
    ];

    // The uplink fading model (`channel=<name[:params]>`). The trivial
    // `ideal` default forks nothing and draws nothing, so default runs
    // are bit-identical to the pre-channel engine.
    let fading = channel::resolve(cfg.channel.as_deref())?;
    let channel_label = fading.spec();
    let mut chan: ChannelState = fading.bind(m, &root);
    if cfg.channel.is_some() {
        crate::log_info!("afl[{}]: channel {}", label, channel_label);
    }
    let mut gains: Vec<f64> = if chan.is_trivial() {
        Vec::new()
    } else {
        vec![1.0; m]
    };
    // Upload frame size (wire-format bytes) per client: the full flat
    // model, or the packed submodel prefix.
    let full_numel: usize = w_init.tensors.iter().map(|t| t.data.len()).sum();
    let numel_of = |client: usize| match &subctx {
        None => full_numel,
        Some(sc) => sc.map_of(client).numel(),
    };
    let mut bytes_on_wire = 0u64;
    let mut channel_lost = 0u64;

    let mut core = ServerCore::new(w_init, m, policy, cfg.mu_rho);
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut channel = UplinkChannel::new();
    let mut scheduler = UploadScheduler::new(sched_policy, m);
    let mut clients: Vec<ClientState> = ctx
        .shards
        .iter()
        .map(|s| ClientState {
            cursor: BatchCursor::new(s.indices.clone()),
            pending: None,
        })
        .collect();

    let mut xs = Vec::new();
    let mut ys = Vec::new();

    // Upload duration per client: τ^u under the trivial profile (the
    // pre-submodel constant), scaled by the client's rate otherwise.
    let tau_up_of = |client: usize| match &subctx {
        None => cfg.time.tau_up,
        Some(sc) => scaled_tau_up(cfg.time.tau_up, sc.map_of(client).rate()),
    };

    // Telemetry setup mirrors the sharded twin exactly (same call
    // points before the t=0 broadcast), so traces agree byte-for-byte.
    tel.bind(m);
    if let Some(sc) = &subctx {
        for (c, &k) in sc.class_of.iter().enumerate() {
            tel.class_assign(c, k);
        }
    }

    // t=0: the server broadcasts w_0 to everyone (Algorithm 1 line 1).
    // One shared snapshot for the whole broadcast.
    let w0 = Arc::new(core.global().clone());
    for c in 0..m {
        let i = core.issue_to(c);
        queue.schedule_at(cfg.time.tau_down, Event::DownloadDone {
            client: c,
            w: Arc::clone(&w0),
            i,
        });
    }
    drop(w0);

    while let Some((now, ev)) = queue.pop() {
        if now > max_ticks {
            break;
        }
        match ev {
            Event::DownloadDone { client, w: w_recv, i } => {
                // Local learning (eq. 4) — executed now, surfaced at
                // ComputeDone per the virtual-time compute model.
                let steps = adaptive_steps(
                    cfg.local_steps,
                    cm.factor(client),
                    cfg.adaptive_iters,
                );
                clients[client]
                    .cursor
                    .fill(ctx.train, steps * batch, img, &mut xs, &mut ys);
                let (local, loss) = ctx.learner.train(&w_recv, &xs, &ys, steps)?;
                core.record_loss(client, loss as f64);
                clients[client].pending = Some((local, i));
                // Scenario drift: time-varying compute (scale 1.0 under
                // the static default — bit-identical draw). A rate-r
                // capacity class pays r× the compute cost on top.
                let mut scale = world.compute_scale(client, now);
                if let Some(sc) = &subctx {
                    scale *= sc.map_of(client).rate();
                }
                let dur = cm.duration_scaled(&cfg.time, client, steps, &mut jrng, scale);
                queue.schedule_in(dur, Event::ComputeDone { client });
            }
            Event::ComputeDone { client } => {
                // Scenario churn: an offline client holds its local
                // model and re-contends only when it rejoins, by which
                // point the version it trained from is stale.
                if let Some(rejoin) = world.offline_until(client, now) {
                    queue.schedule_at(rejoin, Event::ComputeDone { client });
                    continue;
                }
                scheduler.request(client, now);
                grant_next(
                    &mut scheduler,
                    &mut channel,
                    &mut chan,
                    &mut gains,
                    &mut queue,
                    now,
                    tau_up_of,
                    tel,
                );
            }
            Event::UploadDone { client } => {
                let (local, i) = clients[client]
                    .pending
                    .take()
                    .expect("upload without a pending local model");
                // The TDMA slot was held for the full transmission
                // whether or not the payload survives, so the wire
                // meter counts lost uploads too.
                bytes_on_wire += flat_update_wire_bytes(numel_of(client));
                // Failure injection (`upload_loss` knob, `dropout`
                // scenario, or a channel fade): the upload is lost in
                // transit. The server never sees the model; it re-sends
                // the current global so the client rejoins the loop.
                // The scenario and channel draws come first and from
                // their own streams, so they cannot perturb the legacy
                // `upload_loss` sequence (the trivial channel draws
                // nothing at all).
                let scenario_lost = world.upload_lost(client, now);
                let chan_lost = chan.upload_lost(client, now);
                if chan_lost {
                    channel_lost += 1;
                }
                // The cause ladder matches the draw order (scenario,
                // channel, then the legacy knob — which short-circuits,
                // preserving the `jrng` sequence); the legacy knob
                // reports as scenario loss, per the trace schema.
                let lost = if scenario_lost {
                    Some(LossCause::Scenario)
                } else if chan_lost {
                    Some(LossCause::Channel)
                } else if cfg.upload_loss > 0.0 && jrng.f64() < cfg.upload_loss {
                    Some(LossCause::Scenario)
                } else {
                    None
                };
                if let Some(cause) = lost {
                    tel.upload_lost(now, client, cause);
                    core.on_lost_upload(client);
                    let i = core.issue_to(client);
                    queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                        client,
                        w: Arc::new(core.global().clone()),
                        i,
                    });
                    grant_next(
                        &mut scheduler,
                        &mut channel,
                        &mut chan,
                        &mut gains,
                        &mut queue,
                        now,
                        tau_up_of,
                        tel,
                    );
                    continue;
                }
                // Evaluate cadence points that precede this aggregation.
                rec.catch_up(now, core.global(), core.iteration())?;

                let out = match &subctx {
                    None => core.on_update(client, i, &local, ctx)?, // eq. (3)/(11)
                    Some(sc) => {
                        // Pack the client's covered slice and merge it
                        // slice-wise (uncovered elements keep the
                        // previous global).
                        let map = sc.map_of(client);
                        map.extract_from_set(&local, &mut subbuf[..map.numel()]);
                        core.on_update_submodel(client, i, &subbuf[..map.numel()], map)?
                    }
                };
                tel.upload_applied(
                    now,
                    client,
                    out.iteration,
                    out.staleness,
                    out.beta,
                    out.weight,
                );

                // Fresh global goes back to this client only (a snapshot:
                // further aggregations must not mutate an in-flight model).
                let i = core.issue_to(client);
                queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                    client,
                    w: Arc::new(core.global().clone()),
                    i,
                });
                // Channel freed: grant the next contender, if any.
                grant_next(
                    &mut scheduler,
                    &mut channel,
                    &mut chan,
                    &mut gains,
                    &mut queue,
                    now,
                    tau_up_of,
                    tel,
                );
            }
        }
    }
    rec.finish(core.global(), core.iteration())?;
    if core.lost_uploads() > 0 {
        crate::log_info!(
            "afl: {} uploads lost in transit ({} delivered)",
            core.lost_uploads(),
            core.iteration()
        );
    }

    // Per-class roll-up: participation from the core's dense tables,
    // plus the final global evaluated on each class's pooled training
    // data — the system-bias signal (classes that upload less or
    // smaller slices get modeled worse).
    let classes: Vec<ClassMetrics> = match &subctx {
        None => Vec::new(),
        Some(sc) => {
            let cells = class_cells(
                sc,
                core.updates_per_client(),
                core.lost_per_client(),
                core.loss_totals(),
            );
            let mut out = Vec::with_capacity(cells.len());
            for (k, cell) in cells.into_iter().enumerate() {
                let mut x = Vec::new();
                let mut y = Vec::new();
                for (c, &cls) in sc.class_of.iter().enumerate() {
                    if cls as usize != k {
                        continue;
                    }
                    for &s in &ctx.shards[c].indices {
                        x.extend_from_slice(ctx.train.image(s));
                        y.push(ctx.train.y[s]);
                    }
                }
                let (accuracy, loss) = if y.is_empty() {
                    (0.0, 0.0)
                } else {
                    let pooled = Dataset { x, y };
                    ctx.learner.evaluate(core.global(), &pooled)?
                };
                out.push(ClassMetrics {
                    label: cell.label,
                    rate: cell.rate,
                    clients: cell.clients,
                    uploads: cell.uploads,
                    lost_uploads: cell.lost_uploads,
                    mean_train_loss: cell.mean_train_loss,
                    accuracy,
                    loss,
                });
            }
            out
        }
    };

    let stats = RunStats {
        label,
        uploads: scheduler.grants().to_vec(),
        aggregations: core.iteration(),
        mean_staleness: core.mean_staleness(),
        fairness: scheduler.jain_fairness(),
        lost_uploads: core.lost_uploads(),
        lost_per_client: core.lost_per_client().to_vec(),
        mean_train_loss: core.mean_train_loss(),
        classes,
        channel: channel_label,
        bytes_on_wire,
        channel_lost,
        total_ticks: max_ticks,
    };
    let mut result = rec.into_result(stats);
    result.telemetry = tel.registry_json();
    Ok((result, core.into_global()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_steps_policy() {
        assert_eq!(adaptive_steps(16, 1.0, true), 16);
        assert_eq!(adaptive_steps(16, 2.0, true), 8);
        assert_eq!(adaptive_steps(16, 10.0, true), 2);
        assert_eq!(adaptive_steps(16, 100.0, true), 1, "floored");
        assert_eq!(adaptive_steps(16, 10.0, false), 16, "disabled");
        // Very fast clients don't blow up unboundedly.
        assert_eq!(adaptive_steps(16, 0.1, true), 64);
    }
}
