//! Shared run harness: evaluation cadence, aggregation dispatch, context.
//!
//! All three engines (SFL, event-driven AFL, baseline-AFL sweeps) share
//! this plumbing so their results are directly comparable: same data, same
//! learner, same virtual-time axis, same evaluation cadence.

use anyhow::Result;

use super::core::ModelAggregator;
use crate::config::{AggregatorKind, RunConfig};
use crate::data::{ClientShard, Dataset};
use crate::learner::Learner;
use crate::metrics::{ClassMetrics, EvalPoint, RunResult};
use crate::model::ParamSet;
use crate::runtime::Engine;
use crate::sim::Ticks;

/// Everything an engine needs to execute one run.
pub struct FlContext<'a> {
    /// The run's full configuration.
    pub cfg: &'a RunConfig,
    /// Local trainer/evaluator shared by every client.
    pub learner: &'a dyn Learner,
    /// Needed only when `cfg.aggregator == Pjrt`.
    pub engine: Option<&'a Engine>,
    /// The full training set (clients index into it via shards).
    pub train: &'a Dataset,
    /// Per-client sample-index shards.
    pub shards: &'a [ClientShard],
    /// Held-out test set for the evaluation cadence.
    pub test: &'a Dataset,
}

impl<'a> FlContext<'a> {
    /// Server-side eq.(3) aggregation:
    /// `global ← beta·global + (1-beta)·local`.
    pub fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()> {
        match self.cfg.aggregator {
            AggregatorKind::Native => {
                global.lerp_inplace(local, beta);
                Ok(())
            }
            AggregatorKind::Pjrt => {
                let engine = self.engine.ok_or_else(|| {
                    anyhow::anyhow!("PJRT aggregator requested but no engine provided")
                })?;
                *global = engine.aggregate(global, local, beta)?;
                Ok(())
            }
        }
    }
}

impl ModelAggregator for FlContext<'_> {
    // The context's aggregator dispatch (native lerp vs the PJRT Pallas
    // artifact) is what `ServerCore` runs eq. (3) through in simulation.
    fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()> {
        FlContext::aggregate(self, global, local, beta)
    }
}

/// Everything an engine hands the [`Recorder`] to assemble a
/// [`RunResult`] besides the curve itself.
#[derive(Debug, Clone)]
pub struct RunStats {
    /// Series label, e.g. `fedavg` or `csmaafl g=0.2`.
    pub label: String,
    /// Upload count per client (fairness analysis).
    pub uploads: Vec<u64>,
    /// Total global aggregations.
    pub aggregations: u64,
    /// Mean observed staleness (AFL runs; 0 for SFL).
    pub mean_staleness: f64,
    /// Jain fairness index over uploads.
    pub fairness: f64,
    /// Uploads lost in transit (failure injection; 0 = reliable).
    pub lost_uploads: u64,
    /// Uploads lost in transit, per client (dropout-bias accounting;
    /// empty or all-zero on reliable channels).
    pub lost_per_client: Vec<u64>,
    /// Mean client-reported local training loss across the run (from
    /// the core's dense per-client loss table; 0 for engines that do
    /// not report it, e.g. SFL).
    pub mean_train_loss: f64,
    /// Per-capacity-class metrics (heterogeneous-capacity runs; empty
    /// under the trivial `full`/`uniform:1.0` profile and for engines
    /// that do not support capacity).
    pub classes: Vec<ClassMetrics>,
    /// Canonical channel-model spelling (`"ideal"` for engines without
    /// a fading channel, e.g. SFL).
    pub channel: String,
    /// Upload payload that crossed the uplink, in wire-format bytes.
    pub bytes_on_wire: u64,
    /// Uploads lost to channel fades (subset of `lost_uploads`).
    pub channel_lost: u64,
    /// Virtual completion time.
    pub total_ticks: Ticks,
}

/// Evaluation-cadence recorder.
///
/// The paper's figures plot test accuracy against *relative time slots*
/// (one slot = one synchronous round under the run's time model). The
/// recorder owns that axis: engines call [`Recorder::catch_up`] with the
/// current global model right *before* every aggregation at time `T`;
/// every pending cadence point strictly before `T` is evaluated with the
/// model that was in force at that point.
pub struct Recorder<'a> {
    ctx: &'a FlContext<'a>,
    /// Ticks per relative slot.
    slot_ticks: f64,
    /// Cadence interval in ticks.
    every_ticks: f64,
    /// Index of the next cadence point.
    next_idx: u64,
    /// Evaluation points recorded so far, in slot order.
    pub points: Vec<EvalPoint>,
    started: std::time::Instant,
}

impl<'a> Recorder<'a> {
    /// Build a recorder whose x-axis unit is `slot_ticks` virtual ticks
    /// (one synchronous round under the run's time model).
    pub fn new(ctx: &'a FlContext<'a>, slot_ticks: Ticks) -> Result<Recorder<'a>> {
        let slot_ticks = slot_ticks.max(1) as f64;
        Ok(Recorder {
            ctx,
            slot_ticks,
            every_ticks: ctx.cfg.eval_every_slots * slot_ticks,
            next_idx: 0,
            points: Vec::new(),
            started: std::time::Instant::now(),
        })
    }

    /// Virtual ticks per relative time slot.
    pub fn slot_ticks(&self) -> f64 {
        self.slot_ticks
    }

    /// Virtual end of the run in ticks.
    pub fn max_ticks(&self) -> Ticks {
        (self.ctx.cfg.max_slots * self.slot_ticks).ceil() as Ticks
    }

    fn next_tick(&self) -> f64 {
        self.next_idx as f64 * self.every_ticks
    }

    fn eval_point(&mut self, at_tick: f64, w: &ParamSet, iteration: u64) -> Result<()> {
        let (acc, loss) = self.ctx.learner.evaluate(w, self.ctx.test)?;
        self.points.push(EvalPoint {
            slot: at_tick / self.slot_ticks,
            ticks: at_tick.round() as Ticks,
            iteration,
            accuracy: acc,
            loss,
        });
        Ok(())
    }

    /// Evaluate all cadence points strictly before `t` using `w` (the
    /// model in force on [last-aggregation, t)).
    pub fn catch_up(&mut self, t: Ticks, w: &ParamSet, iteration: u64) -> Result<()> {
        while self.next_tick() < t as f64 && self.next_tick() <= self.ctx.cfg.max_slots * self.slot_ticks {
            let at = self.next_tick();
            self.eval_point(at, w, iteration)?;
            self.next_idx += 1;
        }
        Ok(())
    }

    /// Flush every remaining cadence point up to and including the run end
    /// with the final model.
    pub fn finish(&mut self, w: &ParamSet, iteration: u64) -> Result<()> {
        let end = self.ctx.cfg.max_slots * self.slot_ticks;
        while self.next_tick() <= end {
            let at = self.next_tick();
            self.eval_point(at, w, iteration)?;
            self.next_idx += 1;
        }
        Ok(())
    }

    /// Real time elapsed since the recorder was created.
    pub fn wallclock_secs(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    /// Assemble the RunResult.
    pub fn into_result(self, stats: RunStats) -> RunResult {
        let wallclock = self.wallclock_secs();
        RunResult {
            label: stats.label,
            points: self.points,
            uploads_per_client: stats.uploads,
            aggregations: stats.aggregations,
            mean_staleness: stats.mean_staleness,
            fairness: stats.fairness,
            lost_uploads: stats.lost_uploads,
            lost_per_client: stats.lost_per_client,
            mean_train_loss: stats.mean_train_loss,
            classes: stats.classes,
            channel: stats.channel,
            bytes_on_wire: stats.bytes_on_wire,
            channel_lost: stats.channel_lost,
            total_ticks: stats.total_ticks,
            wallclock_secs: wallclock,
            // Engines that ran multi-core overwrite this after assembly
            // (`coordinator::learner_shard`); everything else is 1.
            shards: 1,
            // Traced engines overwrite this with the registry JSON
            // after assembly; untraced runs stay `None`.
            telemetry: None,
        }
    }
}
