//! Synchronous FedAvg (Sec. II-A) — the paper's comparator.
//!
//! Round structure (eq. 1–2, Fig. 2 top): broadcast `τ^d`, all clients
//! compute in parallel (round waits for the slowest), TDMA uploads
//! `M·τ^u`, server aggregates `w ← Σ α_m w^m` with α_m = |D_m|/Σ|D_c|
//! (uniform here: equal shards), repeat.

use anyhow::Result;

use super::runner::{FlContext, Recorder, RunStats};
use crate::learner::BatchCursor;
use crate::model::ParamSet;
use crate::sim::ComputeModel;
use crate::util::rng::Rng;

/// Run synchronous FedAvg (optionally client-sampled via
/// `cfg.sfl_sample_fraction`) on the shared context.
pub fn run_sfl(ctx: &FlContext<'_>) -> Result<crate::metrics::RunResult> {
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();
    let mut cursors: Vec<BatchCursor> = ctx
        .shards
        .iter()
        .map(|s| BatchCursor::new(s.indices.clone()))
        .collect();

    let mut w = ctx.learner.init(cfg.seed as u32)?;
    let mut now: u64 = 0;
    let mut rounds: u64 = 0;
    let mut xs = Vec::new();
    let mut ys = Vec::new();

    // Client sampling ([2]): the server waits for only K = ⌈fM⌉ randomly
    // chosen clients per round. f = 1 is the paper's full-participation
    // setting (and the CSMAAFL comparison baseline).
    let k = ((cfg.sfl_sample_fraction * m as f64).ceil() as usize).clamp(1, m);
    let mut srng = root.fork(0x5a3b);

    while now < max_ticks {
        let participants: Vec<usize> = if k == m {
            (0..m).collect()
        } else {
            srng.sample_indices(m, k)
        };
        // Virtual round duration: τ^d + slowest *participant* compute
        // draw + K·τ^u. (Sampling shortens the straggler tail only when
        // the slow clients happen to be excluded — the [2] critique.)
        let compute: u64 = participants
            .iter()
            .map(|&c| cm.duration(&cfg.time, c, cfg.local_steps, &mut jrng))
            .max()
            .unwrap_or(1);
        let round_end = now + cfg.time.tau_down + compute + k as u64 * cfg.time.tau_up;

        // Participants train from the broadcast global (eq. 1).
        let alpha = 1.0 / k as f32;
        let mut agg = ParamSet::zeros(&w.specs());
        for &c in &participants {
            cursors[c].fill(ctx.train, cfg.local_steps * batch, img, &mut xs, &mut ys);
            let (local, _loss) = ctx.learner.train(&w, &xs, &ys, cfg.local_steps)?;
            agg.axpy_inplace(&local, alpha);
        }

        // Cadence points inside this round see the pre-round model.
        rec.catch_up(round_end.min(max_ticks), &w, rounds)?;
        w = agg; // eq. (2)
        rounds += 1;
        now = round_end;
    }
    rec.finish(&w, rounds)?;

    let stats = RunStats {
        label: "fedavg".into(),
        uploads: vec![rounds; m],
        aggregations: rounds,
        mean_staleness: 0.0,
        fairness: 1.0,
        lost_uploads: 0,
        lost_per_client: vec![0; m],
        mean_train_loss: 0.0, // SFL does not report per-client losses
        classes: Vec::new(), // capacity is AFL-only (RunConfig::validate)
        channel: "ideal".into(), // and so are channel models
        bytes_on_wire: 0,
        channel_lost: 0,
        total_ticks: now,
    };
    Ok(rec.into_result(stats))
}
