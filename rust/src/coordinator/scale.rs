//! The million-client scale simulator (`repro sim`): the coordinator
//! hot path — discrete-event queue, TDMA slot arbitration, sans-IO
//! `ServerCore` aggregation over the arena-backed flat parameter store —
//! with *synthetic* local training instead of a learner, so the pure
//! coordination cost at 10^5–10^6 clients is measurable on one machine.
//!
//! What is real: the event loop (`sim::EventQueue`), the scheduler
//! (`coordinator::scheduler`, heap/cursor fast paths), the aggregation
//! policies (`coordinator::policy`) and the eq.-(3) arithmetic
//! ([`crate::model::lerp_flat`] through [`ServerCore::on_update_flat`]),
//! the heterogeneous compute-time model, and all per-client bookkeeping.
//! What is synthetic: the local "training" — each upload is the current
//! global model contracted toward zero plus a per-upload scalar offset
//! (an O(params) transform into a recycled [`ParamArena`] slot, zero
//! allocation at steady state). Clients therefore train from an
//! approximation of their download snapshot; staleness bookkeeping still
//! uses the true issued iteration stamp.
//!
//! Everything is seeded, so two runs with one config produce identical
//! aggregation counts, staleness and fairness statistics; only the
//! wall-clock fields differ.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::afl::adaptive_steps;
use super::core::ServerCore;
use super::policy::{AggregationPolicy, PolicyParams, StalenessEq11};
use super::scheduler::{SchedulerPolicy, UploadScheduler};
use crate::model::{ParamArena, ParamLayout, ParamSet, SlotId, TensorSpec};
use crate::sim::{ComputeModel, EventQueue, HeterogeneityProfile, Ticks, TimeModel, UplinkChannel};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Configuration of one scale-simulation run. All fields have CLI
/// spellings on `repro sim`.
#[derive(Debug, Clone)]
pub struct ScaleSimConfig {
    /// Number of simulated clients M.
    pub clients: usize,
    /// Aggregations to perform before stopping; 0 = one per client
    /// (`clients` total).
    pub iterations: u64,
    /// Flat model size in f32 elements (one tensor).
    pub params: usize,
    /// Root seed for speeds, jitter and synthetic updates.
    pub seed: u64,
    /// Upload-slot arbitration policy.
    pub scheduler: SchedulerPolicy,
    /// Aggregation-policy registry spelling; `None` = eq. (11) at
    /// `gamma`.
    pub aggregation: Option<String>,
    /// Eq.-(11) γ (also the registry default parameter).
    pub gamma: f64,
    /// μ_ji EMA rate.
    pub mu_rho: f64,
    /// Base local step count E (scaled by the adaptive policy).
    pub local_steps: usize,
    /// How per-client compute speed factors are drawn.
    pub heterogeneity: HeterogeneityProfile,
    /// Per-round multiplicative compute jitter.
    pub jitter: f64,
    /// Sec. II-C communication/computation time parameters.
    pub time: TimeModel,
}

impl Default for ScaleSimConfig {
    fn default() -> Self {
        ScaleSimConfig {
            clients: 1000,
            iterations: 0,
            params: 64,
            seed: 42,
            scheduler: SchedulerPolicy::OldestModelFirst,
            aggregation: None,
            gamma: 0.2,
            mu_rho: 0.1,
            local_steps: 48,
            heterogeneity: HeterogeneityProfile::Uniform { max_factor: 4.0 },
            jitter: 0.1,
            time: TimeModel::default(),
        }
    }
}

/// What one scale-simulation run did, plus its throughput.
#[derive(Debug, Clone)]
pub struct ScaleSimReport {
    /// Simulated client count.
    pub clients: usize,
    /// Flat model size in f32 elements.
    pub params: usize,
    /// Aggregation-policy label in force.
    pub policy: String,
    /// Scheduler spelling in force.
    pub scheduler: &'static str,
    /// Global aggregations performed.
    pub aggregations: u64,
    /// Events processed by the loop.
    pub events: u64,
    /// Virtual time reached (ticks).
    pub virtual_ticks: Ticks,
    /// Real time spent.
    pub wall_secs: f64,
    /// Events per wall-clock second.
    pub events_per_sec: f64,
    /// Aggregations per wall-clock second.
    pub aggs_per_sec: f64,
    /// Mean observed staleness.
    pub mean_staleness: f64,
    /// Jain fairness over granted slots.
    pub fairness: f64,
    /// Mean synthetic training loss recorded through the dense
    /// per-client loss table.
    pub mean_train_loss: f64,
    /// Arena high-water mark (slots ever created).
    pub arena_slots: usize,
    /// Arena slots still allocated at exit (in-flight locals).
    pub arena_live: usize,
    /// L2 norm of the final global model (finite-ness sanity value).
    pub final_norm: f64,
}

impl ScaleSimReport {
    /// Machine-readable form (the `repro sim --format json` output).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("clients", Json::Int(self.clients as i64))
            .set("params", Json::Int(self.params as i64))
            .set("policy", Json::Str(self.policy.clone()))
            .set("scheduler", Json::Str(self.scheduler.into()))
            .set("aggregations", Json::Int(self.aggregations as i64))
            .set("events", Json::Int(self.events as i64))
            .set("virtual_ticks", Json::Int(self.virtual_ticks as i64))
            .set("wall_secs", Json::Float(self.wall_secs))
            .set("events_per_sec", Json::Float(self.events_per_sec))
            .set("aggs_per_sec", Json::Float(self.aggs_per_sec))
            .set("mean_staleness", Json::Float(self.mean_staleness))
            .set("fairness", Json::Float(self.fairness))
            .set("mean_train_loss", Json::Float(self.mean_train_loss))
            .set("arena_slots", Json::Int(self.arena_slots as i64))
            .set("arena_live", Json::Int(self.arena_live as i64))
            .set("final_norm", Json::Float(self.final_norm));
        o
    }

    /// Human-readable table (the default `repro sim` output).
    pub fn table(&self) -> String {
        format!(
            "scale sim: {} clients, {} params, policy {}, scheduler {}\n\
             {:<18} {}\n{:<18} {}\n{:<18} {}\n{:<18} {:.2}\n\
             {:<18} {:.0}\n{:<18} {:.0}\n{:<18} {:.2}\n{:<18} {:.4}\n\
             {:<18} {:.4}\n{:<18} {} (live {})\n{:<18} {:.4}",
            self.clients,
            self.params,
            self.policy,
            self.scheduler,
            "aggregations",
            self.aggregations,
            "events",
            self.events,
            "virtual ticks",
            self.virtual_ticks,
            "wall (s)",
            self.wall_secs,
            "events/sec",
            self.events_per_sec,
            "aggs/sec",
            self.aggs_per_sec,
            "mean staleness",
            self.mean_staleness,
            "fairness",
            self.fairness,
            "mean train loss",
            self.mean_train_loss,
            "arena slots",
            self.arena_slots,
            self.arena_live,
            "final |w|",
            self.final_norm
        )
    }
}

/// Scale-sim event. Unlike the learner-driven engine (`afl.rs`), no
/// event carries model parameters — the bookkeeping travels as iteration
/// stamps and locals live in the arena — so the queue stays small at
/// 10^6 clients.
#[derive(Debug)]
enum Event {
    /// Client received the global model issued at iteration `i`.
    Download { client: usize, i: u64 },
    /// Client finished local compute on the model from iteration `i`.
    Compute { client: usize, i: u64 },
    /// Client's TDMA upload completed.
    Upload { client: usize },
}

/// If the uplink is idle, grant the next contender a slot and schedule
/// its upload completion (the same TDMA channel-grant step as the
/// learner-driven engine).
fn grant_next(
    scheduler: &mut UploadScheduler,
    channel: &mut UplinkChannel,
    queue: &mut EventQueue<Event>,
    now: Ticks,
    tau_up: Ticks,
) {
    if channel.is_free(now) {
        if let Some(winner) = scheduler.grant() {
            let done = channel.reserve(now, tau_up);
            queue.schedule_at(done, Event::Upload { client: winner });
        }
    }
}

/// Run the coordinator-only scale simulation. Deterministic up to the
/// wall-clock fields of the report.
pub fn run_scale_sim(cfg: &ScaleSimConfig) -> Result<ScaleSimReport> {
    ensure!(cfg.clients > 0, "sim requires clients > 0");
    ensure!(cfg.params > 0, "sim requires params > 0");
    ensure!(cfg.local_steps > 0, "sim requires local_steps > 0");
    let m = cfg.clients;
    let target = if cfg.iterations == 0 {
        m as u64
    } else {
        cfg.iterations
    };

    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);
    let mut urng = root.fork(0x10ca1);
    let mut irng = root.fork(0x1217);

    let layout = ParamLayout::new(vec![TensorSpec {
        name: "w".into(),
        shape: vec![cfg.params],
    }]);
    let w0_flat: Vec<f32> = (0..cfg.params).map(|_| 0.1 * irng.normal()).collect();
    let w0 = ParamSet::from_flat(&layout, &w0_flat);

    let params = PolicyParams {
        clients: m,
        gamma: cfg.gamma,
    };
    let policy: Box<dyn AggregationPolicy> = match &cfg.aggregation {
        Some(spec) => <dyn AggregationPolicy>::parse(spec, &params)?,
        None => Box::new(StalenessEq11::new(cfg.gamma)?),
    };
    let policy_label = policy.label();

    let mut core = ServerCore::new(w0, m, policy, cfg.mu_rho);
    let mut scheduler = UploadScheduler::new(cfg.scheduler, m);
    let mut channel = UplinkChannel::new();
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut arena = ParamArena::new(layout);
    // Pending local update per client: arena slot + start iteration.
    let mut pending: Vec<Option<(SlotId, u64)>> = vec![None; m];

    let started = Instant::now();
    let mut events = 0u64;

    // t=0 broadcast: every client is issued w_0 (stamps only — the
    // synthetic trainer reads the live global at compute time).
    for c in 0..m {
        let i = core.issue_to(c);
        queue.schedule_at(cfg.time.tau_down, Event::Download { client: c, i });
    }

    while core.iteration() < target {
        let Some((now, ev)) = queue.pop() else {
            break;
        };
        events += 1;
        match ev {
            Event::Download { client, i } => {
                let steps = adaptive_steps(cfg.local_steps, cm.factor(client), true);
                let dur = cm.duration(&cfg.time, client, steps, &mut jrng);
                queue.schedule_in(dur, Event::Compute { client, i });
            }
            Event::Compute { client, i } => {
                // Synthetic local training into a recycled arena slot:
                // local = 0.999·global + δ, one scalar δ per upload.
                let slot = arena.alloc();
                let d = 0.02 * urng.f32() - 0.01;
                core.global().copy_to_flat(arena.get_mut(slot));
                for x in arena.get_mut(slot) {
                    *x = 0.999 * *x + d;
                }
                core.record_loss(client, (d as f64).abs());
                pending[client] = Some((slot, i));
                scheduler.request(client, now);
                grant_next(&mut scheduler, &mut channel, &mut queue, now, cfg.time.tau_up);
            }
            Event::Upload { client } => {
                let (slot, i) = pending[client]
                    .take()
                    .expect("upload without a pending local model");
                core.on_update_flat(client, i, arena.get(slot))?;
                arena.free(slot);
                let i = core.issue_to(client);
                queue.schedule_in(cfg.time.tau_down, Event::Download { client, i });
                grant_next(&mut scheduler, &mut channel, &mut queue, now, cfg.time.tau_up);
            }
        }
    }

    let wall = started.elapsed().as_secs_f64().max(1e-9);
    Ok(ScaleSimReport {
        clients: m,
        params: cfg.params,
        policy: policy_label,
        scheduler: cfg.scheduler.name(),
        aggregations: core.iteration(),
        events,
        virtual_ticks: queue.now(),
        wall_secs: wall,
        events_per_sec: events as f64 / wall,
        aggs_per_sec: core.iteration() as f64 / wall,
        mean_staleness: core.mean_staleness(),
        fairness: scheduler.jain_fairness(),
        mean_train_loss: core.mean_train_loss(),
        arena_slots: arena.slots(),
        arena_live: arena.live(),
        final_norm: core.global().l2_norm(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_and_reports_invariants() {
        let cfg = ScaleSimConfig {
            clients: 200,
            iterations: 400,
            params: 16,
            ..ScaleSimConfig::default()
        };
        let r = run_scale_sim(&cfg).unwrap();
        assert_eq!(r.aggregations, 400);
        assert!(r.events >= r.aggregations, "{r:?}");
        assert!(r.final_norm.is_finite());
        assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
        assert!(r.mean_train_loss > 0.0 && r.mean_train_loss <= 0.01);
        // At most one in-flight local per client, and the live count at
        // exit never exceeds the pool's high-water mark.
        assert!(r.arena_slots <= 200, "{}", r.arena_slots);
        assert!(r.arena_live <= r.arena_slots);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ScaleSimConfig {
            clients: 100,
            iterations: 250,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let a = run_scale_sim(&cfg).unwrap();
        let b = run_scale_sim(&cfg).unwrap();
        assert_eq!(a.aggregations, b.aggregations);
        assert_eq!(a.events, b.events);
        assert_eq!(a.virtual_ticks, b.virtual_ticks);
        assert_eq!(a.mean_staleness, b.mean_staleness);
        assert_eq!(a.final_norm, b.final_norm);
        assert_eq!(a.mean_train_loss, b.mean_train_loss);
    }

    #[test]
    fn iterations_zero_defaults_to_one_per_client() {
        let cfg = ScaleSimConfig {
            clients: 64,
            params: 4,
            ..ScaleSimConfig::default()
        };
        let r = run_scale_sim(&cfg).unwrap();
        assert_eq!(r.aggregations, 64);
    }

    #[test]
    fn every_scheduler_and_policy_spelling_runs() {
        for sched in [
            SchedulerPolicy::OldestModelFirst,
            SchedulerPolicy::Fifo,
            SchedulerPolicy::RoundRobin,
        ] {
            for agg in [None, Some("fedasync:0.5".to_string()), Some("adaptive".to_string())] {
                let cfg = ScaleSimConfig {
                    clients: 50,
                    iterations: 100,
                    params: 8,
                    scheduler: sched,
                    aggregation: agg.clone(),
                    ..ScaleSimConfig::default()
                };
                let r = run_scale_sim(&cfg).unwrap();
                assert_eq!(r.aggregations, 100, "{sched:?} {agg:?}");
            }
        }
    }

    #[test]
    fn rejects_degenerate_configs() {
        let bad = ScaleSimConfig {
            clients: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            params: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            aggregation: Some("bogus".into()),
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
    }

    #[test]
    fn json_report_has_the_contract_fields() {
        let cfg = ScaleSimConfig {
            clients: 20,
            iterations: 40,
            params: 4,
            ..ScaleSimConfig::default()
        };
        let j = run_scale_sim(&cfg).unwrap().to_json();
        for key in [
            "clients",
            "aggregations",
            "events",
            "events_per_sec",
            "mean_staleness",
            "fairness",
            "mean_train_loss",
            "arena_slots",
            "final_norm",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
