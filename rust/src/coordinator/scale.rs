//! The million-client scale simulator (`repro sim`): the coordinator
//! hot path — discrete-event queue, TDMA slot arbitration, sans-IO
//! `ServerCore` aggregation over the arena-backed flat parameter store —
//! with *synthetic* local training instead of a learner, so the pure
//! coordination cost at 10^5–10^6 clients is measurable on one machine.
//!
//! What is real: the event loop (`sim::EventQueue`), the scheduler
//! (`coordinator::scheduler`, heap/cursor fast paths), the aggregation
//! policies (`coordinator::policy`) and the eq.-(3) arithmetic
//! ([`crate::model::lerp_flat`] through [`ServerCore::on_update_flat`]),
//! the heterogeneous compute-time model, the scenario hooks
//! (`sim::scenario`: `dropout` transit loss, `churn` leave/rejoin,
//! `drift` compute slow-down) and all per-client bookkeeping. What is
//! synthetic: the local "training" — each upload is the current global
//! model contracted toward zero plus a per-upload scalar offset
//! (`synth_train`: `train_passes` elementwise passes into a recycled
//! [`ParamArena`] slot, zero allocation at steady state). Clients
//! therefore train from an approximation of their download snapshot;
//! staleness bookkeeping still uses the true issued iteration stamp.
//!
//! This file is the *sequential reference*: one thread does everything,
//! in pure event order. `coordinator::shard` is the multi-core engine
//! over the same semantics — `rust/tests/sharded.rs` asserts the two
//! agree bit-for-bit (summary JSON and final global model) at every
//! shard count, so this loop doubles as the executable spec of the
//! sharded pipeline. When editing one, edit both.
//!
//! Everything is seeded, so two runs with one config produce identical
//! aggregation counts, staleness and fairness statistics; only the
//! wall-clock fields differ.

use std::time::Instant;

use anyhow::{ensure, Result};

use super::afl::adaptive_steps;
use super::core::ServerCore;
use super::policy::{AggregationPolicy, PolicyParams, StalenessEq11};
use super::scheduler::{SchedulerPolicy, UploadScheduler};
use crate::model::{ParamArena, ParamLayout, ParamSet, SlotId, SubmodelMap, TensorSpec};
use crate::net::wire::flat_update_wire_bytes;
use crate::sim::{
    capacity, channel, scenario, CapacityProfile, ChannelState, ComputeModel, EventQueue,
    HeterogeneityProfile, Scenario, Ticks, TimeModel, UplinkChannel,
};
use crate::telemetry::{LossCause, Telemetry};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Configuration of one scale-simulation run. All fields have CLI
/// spellings on `repro sim`.
#[derive(Debug, Clone)]
pub struct ScaleSimConfig {
    /// Number of simulated clients M.
    pub clients: usize,
    /// Aggregations to perform before stopping; 0 = one per client
    /// (`clients` total).
    pub iterations: u64,
    /// Flat model size in f32 elements (one tensor).
    pub params: usize,
    /// Root seed for speeds, jitter and synthetic updates.
    pub seed: u64,
    /// Upload-slot arbitration policy.
    pub scheduler: SchedulerPolicy,
    /// Aggregation-policy registry spelling; `None` = eq. (11) at
    /// `gamma`.
    pub aggregation: Option<String>,
    /// Scenario registry spelling (`sim::scenario`); `None` = the
    /// pinned `static` world.
    pub scenario: Option<String>,
    /// Capacity-profile registry spelling (`sim::capacity`); `None` =
    /// the pinned `full` profile (every client trains the full model).
    pub capacity: Option<String>,
    /// Fading-channel registry spelling (`sim::channel`); `None` = the
    /// pinned `ideal` channel (gain 1.0, no channel losses).
    pub channel: Option<String>,
    /// Eq.-(11) γ (also the registry default parameter).
    pub gamma: f64,
    /// μ_ji EMA rate.
    pub mu_rho: f64,
    /// Base local step count E (scaled by the adaptive policy).
    pub local_steps: usize,
    /// Elementwise passes of the synthetic trainer per upload (>= 1).
    /// 1 reproduces the historical single-pass transform; larger values
    /// model heavier local training, which is the work the sharded
    /// engine (`coordinator::shard`) parallelizes.
    pub train_passes: u32,
    /// How per-client compute speed factors are drawn.
    pub heterogeneity: HeterogeneityProfile,
    /// Per-round multiplicative compute jitter.
    pub jitter: f64,
    /// Sec. II-C communication/computation time parameters.
    pub time: TimeModel,
}

impl Default for ScaleSimConfig {
    fn default() -> Self {
        ScaleSimConfig {
            clients: 1000,
            iterations: 0,
            params: 64,
            seed: 42,
            scheduler: SchedulerPolicy::OldestModelFirst,
            aggregation: None,
            scenario: None,
            capacity: None,
            channel: None,
            gamma: 0.2,
            mu_rho: 0.1,
            local_steps: 48,
            train_passes: 1,
            heterogeneity: HeterogeneityProfile::Uniform { max_factor: 4.0 },
            jitter: 0.1,
            time: TimeModel::default(),
        }
    }
}

impl ScaleSimConfig {
    /// Apply one `key=value` override in the `repro grid --sim`
    /// spelling. Numeric fields parse their natural types; `scheduler`,
    /// `aggregation`, `scenario` and `heterogeneity` take their
    /// registry spellings. Unknown keys and malformed values are
    /// errors (validated per-cell before any cell runs).
    pub fn set_field(&mut self, key: &str, val: &str) -> Result<()> {
        let bad = |what: &str| anyhow::anyhow!("sim field {key}: invalid {what} {val:?}");
        match key {
            "clients" => self.clients = val.parse().map_err(|_| bad("count"))?,
            "iterations" => self.iterations = val.parse().map_err(|_| bad("count"))?,
            "params" => self.params = val.parse().map_err(|_| bad("count"))?,
            "seed" => self.seed = val.parse().map_err(|_| bad("seed"))?,
            "gamma" => self.gamma = val.parse().map_err(|_| bad("number"))?,
            "mu_rho" => self.mu_rho = val.parse().map_err(|_| bad("number"))?,
            "local_steps" => self.local_steps = val.parse().map_err(|_| bad("count"))?,
            "train_passes" => self.train_passes = val.parse().map_err(|_| bad("count"))?,
            "jitter" => self.jitter = val.parse().map_err(|_| bad("number"))?,
            "scheduler" => {
                self.scheduler = SchedulerPolicy::parse(val).ok_or_else(|| bad("scheduler"))?;
            }
            "aggregation" => self.aggregation = Some(val.to_string()),
            "scenario" => self.scenario = Some(val.to_string()),
            "capacity" => self.capacity = Some(val.to_string()),
            "channel" => self.channel = Some(val.to_string()),
            "heterogeneity" => {
                self.heterogeneity =
                    HeterogeneityProfile::parse(val).ok_or_else(|| bad("profile"))?;
            }
            other => anyhow::bail!(
                "unknown sim field {other:?} (clients | iterations | params | seed | \
                 gamma | mu_rho | local_steps | train_passes | jitter | scheduler | \
                 aggregation | scenario | capacity | channel | heterogeneity)"
            ),
        }
        Ok(())
    }

    /// Cheap whole-config validation (no population-sized allocation):
    /// numeric bounds plus registry parses of the `aggregation` and
    /// `scenario` spellings — the two fields [`ScaleSimConfig::set_field`]
    /// stores unparsed (their parse can depend on other fields, e.g.
    /// `clients`/`gamma`). The engines re-check internally; `repro grid
    /// --sim` calls this on every cell before any cell runs.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.clients > 0, "sim requires clients > 0");
        ensure!(self.params > 0, "sim requires params > 0");
        ensure!(self.local_steps > 0, "sim requires local_steps > 0");
        ensure!(self.train_passes > 0, "sim requires train_passes > 0");
        if let Some(spec) = &self.aggregation {
            let params = PolicyParams {
                clients: self.clients,
                gamma: self.gamma,
            };
            <dyn AggregationPolicy>::parse(spec, &params)?;
        }
        scenario::resolve(self.scenario.as_deref())?;
        capacity::resolve(self.capacity.as_deref())?;
        channel::resolve(self.channel.as_deref())?;
        Ok(())
    }
}

/// Per-capacity-class roll-up of the dense per-client tables — the
/// system-bias signal of heterogeneous-capacity runs (which classes the
/// global model actually hears from).
#[derive(Debug, Clone, PartialEq)]
pub struct CapacityClassCell {
    /// Canonical class label (`r1`, `r0.5`, ...).
    pub label: String,
    /// Submodel rate of the class.
    pub rate: f64,
    /// Clients assigned to the class.
    pub clients: usize,
    /// Updates absorbed from the class.
    pub uploads: u64,
    /// Uploads from the class lost in transit.
    pub lost_uploads: u64,
    /// Mean reported training loss across the class (0 before any
    /// report).
    pub mean_train_loss: f64,
}

impl CapacityClassCell {
    /// JSON form (one element of the `classes` array in summaries).
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("label", Json::Str(self.label.clone()))
            .set("rate", Json::Float(self.rate))
            .set("clients", Json::Int(self.clients as i64))
            .set("uploads", Json::Int(self.uploads as i64))
            .set("lost_uploads", Json::Int(self.lost_uploads as i64))
            .set("mean_train_loss", Json::Float(self.mean_train_loss));
        o
    }
}

/// The resolved non-trivial capacity context of a run: which class each
/// client is in and each class's slice map. `None` in [`SimSetup`] under
/// the trivial (`full` / `uniform:1.0`) profile, in which case the
/// engines take their pre-submodel path untouched.
pub(crate) struct SubmodelCtx {
    pub profile: CapacityProfile,
    pub class_of: Vec<u8>,
    pub maps: Vec<SubmodelMap>,
}

impl SubmodelCtx {
    /// The slice map of one client's class.
    pub fn map_of(&self, client: usize) -> &SubmodelMap {
        &self.maps[self.class_of[client] as usize]
    }
}

/// Upload duration of a rate-`rate` submodel: τ^u scaled by the upload
/// size ratio, rounded, at least one tick.
pub(crate) fn scaled_tau_up(tau_up: Ticks, rate: f64) -> Ticks {
    ((tau_up as f64 * rate).round() as Ticks).max(1)
}

/// Roll the dense per-client tables up into per-class cells (one per
/// capacity class, in profile order).
pub(crate) fn class_cells(
    ctx: &SubmodelCtx,
    updates: &[u64],
    lost: &[u64],
    loss_totals: (&[f64], &[u64]),
) -> Vec<CapacityClassCell> {
    let (loss_sum, loss_n) = loss_totals;
    ctx.profile
        .classes()
        .iter()
        .enumerate()
        .map(|(k, class)| {
            let mut cell = CapacityClassCell {
                label: class.label.clone(),
                rate: class.rate,
                clients: 0,
                uploads: 0,
                lost_uploads: 0,
                mean_train_loss: 0.0,
            };
            let (mut sum, mut n) = (0.0f64, 0u64);
            for (c, &cls) in ctx.class_of.iter().enumerate() {
                if cls as usize == k {
                    cell.clients += 1;
                    cell.uploads += updates[c];
                    cell.lost_uploads += lost[c];
                    sum += loss_sum[c];
                    n += loss_n[c];
                }
            }
            if n > 0 {
                cell.mean_train_loss = sum / n as f64;
            }
            cell
        })
        .collect()
}

/// What one scale-simulation run did, plus its throughput.
#[derive(Debug, Clone)]
pub struct ScaleSimReport {
    /// Simulated client count.
    pub clients: usize,
    /// Flat model size in f32 elements.
    pub params: usize,
    /// Aggregation-policy label in force.
    pub policy: String,
    /// Scheduler spelling in force.
    pub scheduler: &'static str,
    /// Scenario label in force (`static` for the pinned default).
    pub scenario: String,
    /// Capacity-profile spelling in force (`full` for the pinned
    /// default).
    pub capacity: String,
    /// Per-capacity-class roll-ups; empty under the trivial profile, in
    /// which case the summary JSON is byte-identical to a pre-submodel
    /// run.
    pub classes: Vec<CapacityClassCell>,
    /// Channel-model spelling in force (`ideal` for the pinned
    /// default).
    pub channel: String,
    /// Total upload bytes on the (simulated) wire — every completed
    /// upload slot metered at the real frame size
    /// ([`flat_update_wire_bytes`]), lost uploads included: the channel
    /// was occupied either way.
    pub bytes_on_wire: u64,
    /// Uploads lost to channel fades specifically (subset of
    /// `lost_uploads`; 0 under the ideal channel).
    pub channel_lost: u64,
    /// Shard workers the run executed on (1 = the sequential reference
    /// path). Every other field except the wall-clock ones is
    /// bit-identical across shard counts (`rust/tests/sharded.rs`).
    pub shards: usize,
    /// Global aggregations performed.
    pub aggregations: u64,
    /// Events processed by the loop.
    pub events: u64,
    /// Virtual time reached (ticks).
    pub virtual_ticks: Ticks,
    /// Real time spent.
    pub wall_secs: f64,
    /// Events per wall-clock second.
    pub events_per_sec: f64,
    /// Aggregations per wall-clock second.
    pub aggs_per_sec: f64,
    /// Mean observed staleness.
    pub mean_staleness: f64,
    /// Jain fairness over granted slots.
    pub fairness: f64,
    /// Uploads lost in transit (`dropout` scenario; 0 under `static`).
    pub lost_uploads: u64,
    /// Mean synthetic training loss recorded through the dense
    /// per-client loss table.
    pub mean_train_loss: f64,
    /// Arena high-water mark: the most local models ever in flight at
    /// once (slots ever created, given freelist recycling).
    pub arena_slots: usize,
    /// Arena slots still allocated at exit (in-flight locals).
    pub arena_live: usize,
    /// L2 norm of the final global model (finite-ness sanity value).
    pub final_norm: f64,
    /// Telemetry aggregates (`telemetry::Registry` JSON) — `Some` only
    /// when the run was traced, and carried by the full record only,
    /// never the deterministic summary.
    pub telemetry: Option<Json>,
}

impl ScaleSimReport {
    /// The deterministic sub-record: every field that is a pure
    /// function of the config — excludes the wall-clock fields and the
    /// shard count, so `--shards N` summaries are bit-identical for
    /// every N (and identical to the sequential reference). This is
    /// what `rust/tests/sharded.rs` compares and what `repro grid
    /// --sim` matrices are built from.
    pub fn summary_json(&self) -> Json {
        let mut o = Json::object();
        o.set("clients", Json::Int(self.clients as i64))
            .set("params", Json::Int(self.params as i64))
            .set("policy", Json::Str(self.policy.clone()))
            .set("scheduler", Json::Str(self.scheduler.into()))
            .set("scenario", Json::Str(self.scenario.clone()))
            .set("aggregations", Json::Int(self.aggregations as i64))
            .set("events", Json::Int(self.events as i64))
            .set("virtual_ticks", Json::Int(self.virtual_ticks as i64))
            .set("mean_staleness", Json::Float(self.mean_staleness))
            .set("fairness", Json::Float(self.fairness))
            .set("lost_uploads", Json::Int(self.lost_uploads as i64))
            .set("mean_train_loss", Json::Float(self.mean_train_loss))
            .set("arena_slots", Json::Int(self.arena_slots as i64))
            .set("arena_live", Json::Int(self.arena_live as i64))
            .set("final_norm", Json::Float(self.final_norm));
        // Capacity fields appear only under a non-trivial profile, so
        // `capacity=uniform:1.0` summaries stay byte-identical to the
        // pre-submodel engine (`tests/sharded.rs` pins this).
        if !self.classes.is_empty() {
            o.set("capacity", Json::Str(self.capacity.clone())).set(
                "classes",
                Json::Array(self.classes.iter().map(|c| c.to_json()).collect()),
            );
        }
        // Channel fields likewise appear only under a non-trivial
        // model, keeping `channel=ideal` summaries byte-identical to
        // pre-channel records (`tests/sharded.rs` pins this too).
        if self.channel != "ideal" {
            o.set("channel", Json::Str(self.channel.clone()))
                .set("bytes_on_wire", Json::Int(self.bytes_on_wire as i64))
                .set("channel_lost", Json::Int(self.channel_lost as i64));
        }
        o
    }

    /// Machine-readable form (the `repro sim --format json` output):
    /// the deterministic summary plus the shard count and the
    /// wall-clock throughput fields.
    pub fn to_json(&self) -> Json {
        let mut o = self.summary_json();
        o.set("shards", Json::Int(self.shards as i64))
            .set("wall_secs", Json::Float(self.wall_secs))
            .set("events_per_sec", Json::Float(self.events_per_sec))
            .set("aggs_per_sec", Json::Float(self.aggs_per_sec))
            // Full records always carry the channel provenance and the
            // wire meter (idempotent re-set under a fading channel).
            .set("channel", Json::Str(self.channel.clone()))
            .set("bytes_on_wire", Json::Int(self.bytes_on_wire as i64));
        // Telemetry aggregates appear only when the run was traced, so
        // untraced records stay byte-identical to pre-telemetry builds.
        if let Some(t) = &self.telemetry {
            o.set("telemetry", t.clone());
        }
        o
    }

    /// Human-readable table (the default `repro sim` output).
    pub fn table(&self) -> String {
        let mut out = self.base_table();
        if self.channel != "ideal" {
            out.push_str(&format!(
                "\n{:<18} {} ({} bytes on wire, {} channel losses)",
                "channel", self.channel, self.bytes_on_wire, self.channel_lost
            ));
        }
        for c in &self.classes {
            out.push_str(&format!(
                "\n{:<18} {} clients, {} uploads, {} lost, mean loss {:.4}",
                format!("class {}", c.label),
                c.clients,
                c.uploads,
                c.lost_uploads,
                c.mean_train_loss
            ));
        }
        out
    }

    fn base_table(&self) -> String {
        format!(
            "scale sim: {} clients, {} params, policy {}, scheduler {}, \
             scenario {}, {} shard(s)\n\
             {:<18} {}\n{:<18} {}\n{:<18} {}\n{:<18} {:.2}\n\
             {:<18} {:.0}\n{:<18} {:.0}\n{:<18} {:.2}\n{:<18} {:.4}\n\
             {:<18} {}\n{:<18} {:.4}\n{:<18} {} (live {})\n{:<18} {:.4}",
            self.clients,
            self.params,
            self.policy,
            self.scheduler,
            self.scenario,
            self.shards,
            "aggregations",
            self.aggregations,
            "events",
            self.events,
            "virtual ticks",
            self.virtual_ticks,
            "wall (s)",
            self.wall_secs,
            "events/sec",
            self.events_per_sec,
            "aggs/sec",
            self.aggs_per_sec,
            "mean staleness",
            self.mean_staleness,
            "fairness",
            self.fairness,
            "lost uploads",
            self.lost_uploads,
            "mean train loss",
            self.mean_train_loss,
            "arena slots",
            self.arena_slots,
            self.arena_live,
            "final |w|",
            self.final_norm
        )
    }
}

/// Scale-sim event. Unlike the learner-driven engine (`afl.rs`), no
/// event carries model parameters — the bookkeeping travels as iteration
/// stamps and locals live in the arena — so the queue stays small at
/// 10^6 clients. Shared with the sharded engine (`coordinator::shard`),
/// which processes the identical event stream.
#[derive(Debug)]
pub(crate) enum Event {
    /// Client received the global model issued at iteration `i`.
    Download { client: usize, i: u64 },
    /// Client finished local compute on the model from iteration `i`.
    Compute { client: usize, i: u64 },
    /// Client's TDMA upload completed.
    Upload { client: usize },
}

/// The synthetic local trainer: `passes` elementwise contractions
/// `x ← 0.999·x + δ` over the slot buffer. One definition shared by the
/// sequential reference (this file) and the shard workers
/// (`coordinator::shard`), so the two paths are op-for-op identical by
/// construction.
pub(crate) fn synth_train(buf: &mut [f32], delta: f32, passes: u32) {
    for _ in 0..passes {
        for x in buf.iter_mut() {
            *x = 0.999 * *x + delta;
        }
    }
}

/// If the uplink is idle, grant the next contender a slot and schedule
/// its upload completion (the same TDMA channel-grant step as the
/// learner-driven engine). `tau_up_for` maps the winner to its upload
/// duration — constant under the trivial capacity profile, scaled by
/// the winner's submodel rate otherwise — which the fading channel then
/// divides by the winner's instantaneous gain. Under a fading channel
/// the contenders' gains are refreshed (into the caller's `gains`
/// buffer, O(pending) per grant) so gain-sensitive policies
/// (`channel-aware`) arbitrate on current link state; the trivial
/// channel takes the exact pre-channel path.
///
/// Every grant is the single ordered decision point, so this is also
/// where the telemetry Grant event fires (with the post-grant queue
/// depth and the winner's gain level). Gain lookups for telemetry only
/// happen when tracing is on — harmless either way, since the fading
/// process is a pure function of (seed, client, block).
#[allow(clippy::too_many_arguments)]
pub(crate) fn grant_next(
    scheduler: &mut UploadScheduler,
    channel: &mut UplinkChannel,
    fading: &mut ChannelState,
    gains: &mut [f64],
    queue: &mut EventQueue<Event>,
    now: Ticks,
    tau_up_for: impl Fn(usize) -> Ticks,
    tel: &mut Telemetry,
) {
    if channel.is_free(now) {
        let winner = if fading.is_trivial() {
            scheduler.grant()
        } else {
            // Only the scan arbiter exposes contenders; the heap/cursor
            // fast paths return an empty slice and never read gains.
            for r in scheduler.pending_clients() {
                gains[r.client] = fading.gain(r.client, now);
            }
            scheduler.grant_with_gains(Some(gains))
        };
        if let Some(winner) = winner {
            if tel.is_enabled() {
                let level = if fading.is_trivial() {
                    -1
                } else {
                    channel::level_of_gain(fading.gain(winner, now))
                        .map(|l| l as i8)
                        .unwrap_or(-1)
                };
                tel.grant(now, winner, scheduler.pending_len(), level);
            }
            let dur = fading.scaled_tau(winner, now, tau_up_for(winner));
            let done = channel.reserve(now, dur);
            queue.schedule_at(done, Event::Upload { client: winner });
        }
    }
}

/// Shared validation + setup of both scale engines. Returns everything
/// whose construction order (and RNG fork labels) must match between
/// the reference and sharded paths.
pub(crate) struct SimSetup {
    pub m: usize,
    pub target: u64,
    pub cm: ComputeModel,
    pub jrng: Rng,
    pub urng: Rng,
    pub layout: ParamLayout,
    pub core: ServerCore,
    pub policy_label: String,
    pub world: Box<dyn Scenario>,
    pub world_label: String,
    /// Canonical capacity spelling (`full` under the trivial profile).
    pub capacity_label: String,
    /// Non-trivial capacity context; `None` keeps the engines on their
    /// pre-submodel path.
    pub submodel: Option<SubmodelCtx>,
    /// The bound fading channel (trivial = the exact pre-channel path).
    pub chan: ChannelState,
    /// Canonical channel spelling (`ideal` under the trivial model).
    pub channel_label: String,
}

pub(crate) fn setup(cfg: &ScaleSimConfig) -> Result<SimSetup> {
    cfg.validate()?;
    let m = cfg.clients;
    let target = if cfg.iterations == 0 {
        m as u64
    } else {
        cfg.iterations
    };

    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let jrng = root.fork(0xd1ce);
    let urng = root.fork(0x10ca1);
    let mut irng = root.fork(0x1217);

    let layout = ParamLayout::new(vec![TensorSpec {
        name: "w".into(),
        shape: vec![cfg.params],
    }]);
    let w0_flat: Vec<f32> = (0..cfg.params).map(|_| 0.1 * irng.normal()).collect();
    let w0 = ParamSet::from_flat(&layout, &w0_flat);

    let params = PolicyParams {
        clients: m,
        gamma: cfg.gamma,
    };
    let policy: Box<dyn AggregationPolicy> = match &cfg.aggregation {
        Some(spec) => <dyn AggregationPolicy>::parse(spec, &params)?,
        None => Box::new(StalenessEq11::new(cfg.gamma)?),
    };
    let policy_label = policy.label();

    // The world model (static | dropout | churn | drift). Stochastic
    // scenarios draw from their own forked streams, never from `jrng`
    // or `urng`. The relative slot unit here is the steady-state
    // τ^u + τ^d inter-aggregation gap, not the SFL round the
    // learner-driven engine uses: at 10^6 clients one SFL round
    // (M·τ^u + ...) would exceed the whole simulated horizon, leaving
    // churn/drift epochs unreachable.
    let mut world = scenario::resolve(cfg.scenario.as_deref())?;
    world.bind(m, cfg.time.afl_update_interval(), cfg.seed);
    let world_label = world.label();

    // Capacity classes. Assignment draws come from their own fork of
    // the root RNG (`fork` never advances `root`), and the trivial
    // profile makes no draws at all, so `full`/`uniform:1.0` perturbs
    // nothing and `submodel` stays `None` — the engines' pre-submodel
    // path, bit for bit.
    let profile = capacity::resolve(cfg.capacity.as_deref())?;
    let capacity_label = profile.spec();
    let submodel = if profile.is_trivial() {
        None
    } else {
        let class_of = profile.assign(m, &root);
        let maps = profile
            .classes()
            .iter()
            .map(|c| SubmodelMap::new(&layout, c.rate))
            .collect();
        Some(SubmodelCtx {
            profile,
            class_of,
            maps,
        })
    };

    // The fading channel. Like capacity, its stream is a fork of the
    // root RNG (`fork` never advances `root`) and the trivial `ideal`
    // model makes no draws and no forks at all, so it perturbs nothing.
    let fading = channel::resolve(cfg.channel.as_deref())?;
    let channel_label = fading.spec();
    let chan = fading.bind(m, &root);

    let core = ServerCore::new(w0, m, policy, cfg.mu_rho);
    Ok(SimSetup {
        m,
        target,
        cm,
        jrng,
        urng,
        layout,
        core,
        policy_label,
        world,
        world_label,
        capacity_label,
        submodel,
        chan,
        channel_label,
    })
}

/// Run the coordinator-only scale simulation on the sequential
/// reference path. Deterministic up to the wall-clock fields of the
/// report.
pub fn run_scale_sim(cfg: &ScaleSimConfig) -> Result<ScaleSimReport> {
    run_scale_sim_full(cfg).map(|(report, _)| report)
}

/// As [`run_scale_sim`], also yielding the final global model (the
/// bit-identity witness `rust/tests/sharded.rs` compares across
/// engines).
pub fn run_scale_sim_full(cfg: &ScaleSimConfig) -> Result<(ScaleSimReport, ParamSet)> {
    run_scale_sim_traced(cfg, &mut Telemetry::off())
}

/// As [`run_scale_sim_full`], recording trace events and aggregates
/// into `tel`. With a disabled handle ([`Telemetry::off`]) the loop is
/// the exact untraced hot path: every telemetry call is one branch,
/// zero allocation, and the report's `telemetry` field stays `None`.
pub fn run_scale_sim_traced(
    cfg: &ScaleSimConfig,
    tel: &mut Telemetry,
) -> Result<(ScaleSimReport, ParamSet)> {
    let SimSetup {
        m,
        target,
        cm,
        mut jrng,
        mut urng,
        layout,
        mut core,
        policy_label,
        mut world,
        world_label,
        capacity_label,
        submodel,
        mut chan,
        channel_label,
    } = setup(cfg)?;

    let mut scheduler = UploadScheduler::new(cfg.scheduler, m);
    let mut channel = UplinkChannel::new();
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut arena = ParamArena::new(layout);
    // Winner → upload duration: constant under the trivial profile,
    // scaled by the winner's submodel rate otherwise.
    let tau_up_of = |client: usize| match &submodel {
        None => cfg.time.tau_up,
        Some(ctx) => scaled_tau_up(cfg.time.tau_up, ctx.map_of(client).rate()),
    };
    // Upload frame size (wire-format bytes) per client.
    let numel_of = |client: usize| match &submodel {
        None => cfg.params,
        Some(ctx) => ctx.map_of(client).numel(),
    };
    // Per-contender gains buffer for gain-sensitive arbitration; never
    // touched (and never allocated) under the trivial channel.
    let mut gains: Vec<f64> = if chan.is_trivial() {
        Vec::new()
    } else {
        vec![1.0; m]
    };
    // Pending local update per client: arena slot + start iteration.
    let mut pending: Vec<Option<(SlotId, u64)>> = vec![None; m];

    let started = Instant::now();
    let mut events = 0u64;
    let mut bytes_on_wire = 0u64;
    let mut channel_lost = 0u64;

    // Telemetry setup mirrors the sharded engine exactly (same call
    // points before the t=0 broadcast), so traces agree byte-for-byte.
    tel.bind(m);
    if let Some(ctx) = &submodel {
        for (c, &k) in ctx.class_of.iter().enumerate() {
            tel.class_assign(c, k);
        }
    }

    // t=0 broadcast: every client is issued w_0 (stamps only — the
    // synthetic trainer reads the live global at compute time).
    for c in 0..m {
        let i = core.issue_to(c);
        queue.schedule_at(cfg.time.tau_down, Event::Download { client: c, i });
    }

    while core.iteration() < target {
        let Some((now, ev)) = queue.pop() else {
            break;
        };
        events += 1;
        match ev {
            Event::Download { client, i } => {
                let steps = adaptive_steps(cfg.local_steps, cm.factor(client), true);
                // Scenario drift: time-varying compute (scale 1.0 under
                // the static default — bit-identical draw). A rate-r
                // submodel trains r× the parameters, so capacity scales
                // the compute duration the same way.
                let mut scale = world.compute_scale(client, now);
                if let Some(ctx) = &submodel {
                    scale *= ctx.map_of(client).rate();
                }
                let dur = cm.duration_scaled(&cfg.time, client, steps, &mut jrng, scale);
                queue.schedule_in(dur, Event::Compute { client, i });
            }
            Event::Compute { client, i } => {
                // Scenario churn: an offline client re-contends only
                // when it rejoins; its synthetic local is produced then,
                // but the staleness stamp `i` stays the issued one.
                if let Some(rejoin) = world.offline_until(client, now) {
                    queue.schedule_at(rejoin, Event::Compute { client, i });
                    continue;
                }
                // Synthetic local training into a recycled arena slot:
                // local = 0.999·global + δ, one scalar δ per upload. A
                // capacity-constrained client trains only its covered
                // slices, packed into the slot prefix — same recycled
                // full-size slot, zero extra allocation.
                let slot = arena.alloc();
                tel.arena_alloc(now);
                let d = 0.02 * urng.f32() - 0.01;
                match &submodel {
                    None => {
                        core.global().copy_to_flat(arena.get_mut(slot));
                        synth_train(arena.get_mut(slot), d, cfg.train_passes);
                    }
                    Some(ctx) => {
                        let map = ctx.map_of(client);
                        let buf = &mut arena.get_mut(slot)[..map.numel()];
                        map.extract_from_set(core.global(), buf);
                        synth_train(buf, d, cfg.train_passes);
                    }
                }
                core.record_loss(client, (d as f64).abs());
                pending[client] = Some((slot, i));
                scheduler.request(client, now);
                grant_next(
                    &mut scheduler,
                    &mut channel,
                    &mut chan,
                    &mut gains,
                    &mut queue,
                    now,
                    tau_up_of,
                    tel,
                );
            }
            Event::Upload { client } => {
                let (slot, i) = pending[client]
                    .take()
                    .expect("upload without a pending local model");
                // The TDMA slot was occupied for the full transmission
                // whether or not the payload survives, so the wire meter
                // counts lost uploads too.
                bytes_on_wire += flat_update_wire_bytes(numel_of(client));
                // Scenario dropout and channel fade both lose the upload
                // in transit; the local work is wasted and the client
                // re-downloads. Both draws run unconditionally so the
                // scenario's RNG stream is untouched by the channel.
                let scenario_lost = world.upload_lost(client, now);
                let chan_lost = chan.upload_lost(client, now);
                if chan_lost {
                    channel_lost += 1;
                }
                if scenario_lost || chan_lost {
                    let cause = if scenario_lost {
                        LossCause::Scenario
                    } else {
                        LossCause::Channel
                    };
                    tel.upload_lost(now, client, cause);
                    core.on_lost_upload(client);
                    arena.free(slot);
                } else {
                    let out = match &submodel {
                        None => core.on_update_flat(client, i, arena.get(slot))?,
                        Some(ctx) => {
                            let map = ctx.map_of(client);
                            core.on_update_submodel(
                                client,
                                i,
                                &arena.get(slot)[..map.numel()],
                                map,
                            )?
                        }
                    };
                    tel.upload_applied(
                        now,
                        client,
                        out.iteration,
                        out.staleness,
                        out.beta,
                        out.weight,
                    );
                    arena.free(slot);
                }
                tel.arena_free();
                let i = core.issue_to(client);
                queue.schedule_in(cfg.time.tau_down, Event::Download { client, i });
                grant_next(
                    &mut scheduler,
                    &mut channel,
                    &mut chan,
                    &mut gains,
                    &mut queue,
                    now,
                    tau_up_of,
                    tel,
                );
            }
        }
    }

    let wall = started.elapsed().as_secs_f64().max(1e-9);
    let classes = match &submodel {
        None => Vec::new(),
        Some(ctx) => class_cells(
            ctx,
            core.updates_per_client(),
            core.lost_per_client(),
            core.loss_totals(),
        ),
    };
    let report = ScaleSimReport {
        clients: m,
        params: cfg.params,
        policy: policy_label,
        scheduler: cfg.scheduler.name(),
        scenario: world_label,
        capacity: capacity_label,
        classes,
        channel: channel_label,
        bytes_on_wire,
        channel_lost,
        shards: 1,
        aggregations: core.iteration(),
        events,
        virtual_ticks: queue.now(),
        wall_secs: wall,
        events_per_sec: events as f64 / wall,
        aggs_per_sec: core.iteration() as f64 / wall,
        mean_staleness: core.mean_staleness(),
        fairness: scheduler.jain_fairness(),
        lost_uploads: core.lost_uploads(),
        mean_train_loss: core.mean_train_loss(),
        arena_slots: arena.slots(),
        arena_live: arena.live(),
        final_norm: core.global().l2_norm(),
        telemetry: tel.registry_json(),
    };
    Ok((report, core.into_global()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn completes_and_reports_invariants() {
        let cfg = ScaleSimConfig {
            clients: 200,
            iterations: 400,
            params: 16,
            ..ScaleSimConfig::default()
        };
        let r = run_scale_sim(&cfg).unwrap();
        assert_eq!(r.aggregations, 400);
        assert!(r.events >= r.aggregations, "{r:?}");
        assert!(r.final_norm.is_finite());
        assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
        assert!(r.mean_train_loss > 0.0 && r.mean_train_loss <= 0.01);
        assert_eq!(r.lost_uploads, 0, "static world loses nothing");
        assert_eq!(r.scenario, "static");
        assert_eq!(r.shards, 1);
        // At most one in-flight local per client, and the live count at
        // exit never exceeds the pool's high-water mark.
        assert!(r.arena_slots <= 200, "{}", r.arena_slots);
        assert!(r.arena_live <= r.arena_slots);
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = ScaleSimConfig {
            clients: 100,
            iterations: 250,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let a = run_scale_sim(&cfg).unwrap();
        let b = run_scale_sim(&cfg).unwrap();
        assert_eq!(
            a.summary_json().to_string_compact(),
            b.summary_json().to_string_compact(),
            "full deterministic summary"
        );
    }

    #[test]
    fn iterations_zero_defaults_to_one_per_client() {
        let cfg = ScaleSimConfig {
            clients: 64,
            params: 4,
            ..ScaleSimConfig::default()
        };
        let r = run_scale_sim(&cfg).unwrap();
        assert_eq!(r.aggregations, 64);
    }

    #[test]
    fn every_scheduler_and_policy_spelling_runs() {
        for sched in [
            SchedulerPolicy::OldestModelFirst,
            SchedulerPolicy::Fifo,
            SchedulerPolicy::RoundRobin,
            SchedulerPolicy::ChannelAware,
        ] {
            for agg in [None, Some("fedasync:0.5".to_string()), Some("adaptive".to_string())] {
                let cfg = ScaleSimConfig {
                    clients: 50,
                    iterations: 100,
                    params: 8,
                    scheduler: sched,
                    aggregation: agg.clone(),
                    ..ScaleSimConfig::default()
                };
                let r = run_scale_sim(&cfg).unwrap();
                assert_eq!(r.aggregations, 100, "{sched:?} {agg:?}");
            }
        }
    }

    #[test]
    fn every_scenario_spelling_runs_and_dropout_loses_uploads() {
        for spec in crate::sim::scenario::SCENARIO_SPECS {
            let cfg = ScaleSimConfig {
                clients: 60,
                iterations: 150,
                params: 4,
                scenario: Some(spec.to_string()),
                ..ScaleSimConfig::default()
            };
            let r = run_scale_sim(&cfg).unwrap();
            assert_eq!(r.aggregations, 150, "{spec}");
            if spec.starts_with("dropout") {
                assert!(r.lost_uploads > 0, "{spec}: {r:?}");
            } else {
                assert_eq!(r.lost_uploads, 0, "{spec}");
            }
        }
    }

    #[test]
    fn static_scenario_spelling_is_bit_identical_to_none() {
        let base = ScaleSimConfig {
            clients: 80,
            iterations: 200,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let pinned = ScaleSimConfig {
            scenario: Some("static".into()),
            ..base.clone()
        };
        let (ra, wa) = run_scale_sim_full(&base).unwrap();
        let (rb, wb) = run_scale_sim_full(&pinned).unwrap();
        assert_eq!(ra.summary_json().to_string_compact(), rb.summary_json().to_string_compact());
        assert_eq!(wa, wb, "final models must agree bit-for-bit");
    }

    #[test]
    fn multi_pass_training_changes_the_model_but_not_the_timeline() {
        let base = ScaleSimConfig {
            clients: 40,
            iterations: 100,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let heavy = ScaleSimConfig {
            train_passes: 4,
            ..base.clone()
        };
        let a = run_scale_sim(&base).unwrap();
        let b = run_scale_sim(&heavy).unwrap();
        // Training cost is synthetic work, not virtual time: the event
        // stream is identical, only the model values differ.
        assert_eq!(a.events, b.events);
        assert_eq!(a.virtual_ticks, b.virtual_ticks);
        assert_ne!(a.final_norm, b.final_norm);
    }

    #[test]
    fn rejects_degenerate_configs() {
        let bad = ScaleSimConfig {
            clients: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            params: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            train_passes: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            aggregation: Some("bogus".into()),
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
        let bad = ScaleSimConfig {
            scenario: Some("blizzard".into()),
            ..ScaleSimConfig::default()
        };
        assert!(run_scale_sim(&bad).is_err());
    }

    #[test]
    fn json_report_has_the_contract_fields() {
        let cfg = ScaleSimConfig {
            clients: 20,
            iterations: 40,
            params: 4,
            ..ScaleSimConfig::default()
        };
        let j = run_scale_sim(&cfg).unwrap().to_json();
        for key in [
            "clients",
            "aggregations",
            "events",
            "events_per_sec",
            "mean_staleness",
            "fairness",
            "lost_uploads",
            "mean_train_loss",
            "arena_slots",
            "final_norm",
            "scenario",
            "shards",
        ] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
        // The deterministic summary must exclude anything wall-clock-
        // or thread-count-dependent, and the telemetry aggregates.
        let s = run_scale_sim(&cfg).unwrap().summary_json();
        for key in [
            "wall_secs",
            "events_per_sec",
            "aggs_per_sec",
            "shards",
            "telemetry",
        ] {
            assert!(s.get(key).is_none(), "summary must not carry {key}");
        }
    }

    #[test]
    fn telemetry_rides_the_full_record_only_when_traced() {
        let cfg = ScaleSimConfig {
            clients: 30,
            iterations: 60,
            params: 4,
            channel: Some("markov:0.5,200".into()),
            ..ScaleSimConfig::default()
        };
        let (plain, _) = run_scale_sim_full(&cfg).unwrap();
        assert!(plain.telemetry.is_none());
        assert!(plain.to_json().get("telemetry").is_none());

        let mut tel = Telemetry::buffered();
        let (traced, _) = run_scale_sim_traced(&cfg, &mut tel).unwrap();
        let reg = traced.telemetry.as_ref().expect("traced run carries aggregates");
        assert_eq!(
            reg.get("uploads_applied").unwrap().as_i64().unwrap() as u64,
            traced.aggregations
        );
        assert!(traced.to_json().get("telemetry").is_some());
        assert!(traced.summary_json().get("telemetry").is_none());
        // And tracing never changes the deterministic summary.
        assert_eq!(
            plain.summary_json().to_string_compact(),
            traced.summary_json().to_string_compact()
        );
        let trace = String::from_utf8(tel.take_buffer()).unwrap();
        assert!(trace.lines().count() > 0);
        assert!(trace.lines().all(|l| l.starts_with("{\"ev\":\"")));
    }

    #[test]
    fn set_field_covers_every_key_and_rejects_unknown() {
        let mut cfg = ScaleSimConfig::default();
        for (k, v) in [
            ("clients", "123"),
            ("iterations", "7"),
            ("params", "9"),
            ("seed", "5"),
            ("gamma", "0.3"),
            ("mu_rho", "0.2"),
            ("local_steps", "12"),
            ("train_passes", "3"),
            ("jitter", "0.05"),
            ("scheduler", "fifo"),
            ("aggregation", "fedasync:0.5"),
            ("scenario", "dropout:0.1"),
            ("capacity", "classes:1.0x0.5,0.5x0.5"),
            ("channel", "markov:0.5,500"),
            ("heterogeneity", "lognormal:0.5"),
        ] {
            cfg.set_field(k, v).unwrap_or_else(|e| panic!("{k}: {e}"));
        }
        assert_eq!(cfg.clients, 123);
        assert_eq!(cfg.scheduler, SchedulerPolicy::Fifo);
        assert_eq!(cfg.scenario.as_deref(), Some("dropout:0.1"));
        assert_eq!(cfg.capacity.as_deref(), Some("classes:1.0x0.5,0.5x0.5"));
        assert_eq!(cfg.channel.as_deref(), Some("markov:0.5,500"));
        assert!(cfg.set_field("clients", "banana").is_err());
        assert!(cfg.set_field("scheduler", "lottery").is_err());
        assert!(cfg.set_field("warp", "9").is_err());
    }

    #[test]
    fn validate_catches_the_spellings_set_field_stores_unparsed() {
        let ok = ScaleSimConfig {
            aggregation: Some("staleness:0.3".into()),
            scenario: Some("dropout:0.1".into()),
            ..ScaleSimConfig::default()
        };
        ok.validate().unwrap();
        let bad = ScaleSimConfig {
            aggregation: Some("bogus".into()),
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScaleSimConfig {
            scenario: Some("blizzard".into()),
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScaleSimConfig {
            capacity: Some("uniform:2.0".into()),
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScaleSimConfig {
            channel: Some("tropo".into()),
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScaleSimConfig {
            channel: Some("markov:1.5".into()),
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
        let bad = ScaleSimConfig {
            train_passes: 0,
            ..ScaleSimConfig::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn trivial_capacity_spellings_are_bit_identical_to_none() {
        let base = ScaleSimConfig {
            clients: 80,
            iterations: 200,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let (ra, wa) = run_scale_sim_full(&base).unwrap();
        for spec in ["full", "uniform:1.0"] {
            let cfg = ScaleSimConfig {
                capacity: Some(spec.into()),
                ..base.clone()
            };
            let (rb, wb) = run_scale_sim_full(&cfg).unwrap();
            assert_eq!(
                ra.summary_json().to_string_compact(),
                rb.summary_json().to_string_compact(),
                "{spec}"
            );
            assert_eq!(wa, wb, "{spec}: final models must agree bit-for-bit");
            assert!(rb.classes.is_empty(), "{spec}");
        }
    }

    #[test]
    fn ideal_channel_spelling_is_bit_identical_to_none() {
        let base = ScaleSimConfig {
            clients: 80,
            iterations: 200,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let pinned = ScaleSimConfig {
            channel: Some("ideal".into()),
            ..base.clone()
        };
        let (ra, wa) = run_scale_sim_full(&base).unwrap();
        let (rb, wb) = run_scale_sim_full(&pinned).unwrap();
        assert_eq!(ra.summary_json().to_string_compact(), rb.summary_json().to_string_compact());
        assert_eq!(wa, wb, "final models must agree bit-for-bit");
        assert_eq!(rb.channel, "ideal");
        assert_eq!(rb.channel_lost, 0);
    }

    #[test]
    fn markov_channel_stretches_time_and_loses_uploads() {
        let base = ScaleSimConfig {
            clients: 60,
            iterations: 300,
            params: 8,
            ..ScaleSimConfig::default()
        };
        let faded = ScaleSimConfig {
            channel: Some("markov:0.5,500".into()),
            ..base.clone()
        };
        let a = run_scale_sim(&base).unwrap();
        let b = run_scale_sim(&faded).unwrap();
        assert_eq!(b.aggregations, 300);
        assert_eq!(b.channel, "markov:0.5,500");
        assert!(b.bytes_on_wire > 0, "{b:?}");
        assert!(b.channel_lost > 0, "deep fades must cost uploads: {b:?}");
        assert!(b.lost_uploads >= b.channel_lost, "{b:?}");
        // Fades stretch upload slots, so the faded timeline runs longer.
        assert!(b.virtual_ticks > a.virtual_ticks, "{} vs {}", b.virtual_ticks, a.virtual_ticks);
        // Determinism holds under fading too.
        let c = run_scale_sim(&faded).unwrap();
        assert_eq!(
            b.summary_json().to_string_compact(),
            c.summary_json().to_string_compact()
        );
    }

    #[test]
    fn channel_aware_scheduler_diverges_only_under_fading() {
        let base = ScaleSimConfig {
            clients: 60,
            iterations: 200,
            params: 8,
            scheduler: SchedulerPolicy::ChannelAware,
            ..ScaleSimConfig::default()
        };
        // Under the ideal channel the gain-weighted score degenerates to
        // oldest-model-first, bit for bit.
        let omf = ScaleSimConfig {
            scheduler: SchedulerPolicy::OldestModelFirst,
            ..base.clone()
        };
        let (ra, wa) = run_scale_sim_full(&base).unwrap();
        let (rb, wb) = run_scale_sim_full(&omf).unwrap();
        assert_eq!(ra.mean_staleness, rb.mean_staleness);
        assert_eq!(ra.fairness, rb.fairness);
        assert_eq!(wa, wb, "ideal channel: channel-aware ≡ oldest");
        // Under fading the two schedules part ways.
        let faded_ca = ScaleSimConfig {
            channel: Some("markov:0.5,500".into()),
            ..base
        };
        let faded_omf = ScaleSimConfig {
            channel: Some("markov:0.5,500".into()),
            ..omf
        };
        let (_, wc) = run_scale_sim_full(&faded_ca).unwrap();
        let (_, wd) = run_scale_sim_full(&faded_omf).unwrap();
        assert_ne!(wc, wd, "fading must differentiate the schedulers");
    }

    #[test]
    fn capacity_classes_run_and_report_per_class_cells() {
        let cfg = ScaleSimConfig {
            clients: 120,
            iterations: 300,
            params: 32,
            capacity: Some("classes:1.0x0.5,0.5x0.3,0.25x0.2".into()),
            ..ScaleSimConfig::default()
        };
        let r = run_scale_sim(&cfg).unwrap();
        assert_eq!(r.aggregations, 300);
        assert!(r.final_norm.is_finite());
        assert_eq!(r.classes.len(), 3);
        assert_eq!(r.capacity, "classes:1.0x0.5,0.5x0.3,0.25x0.2");
        let clients: usize = r.classes.iter().map(|c| c.clients).sum();
        let uploads: u64 = r.classes.iter().map(|c| c.uploads).sum();
        assert_eq!(clients, 120);
        assert_eq!(uploads, r.aggregations);
        assert!(r.classes.iter().all(|c| c.clients > 0), "{:?}", r.classes);
        // Summary carries the class cells; runs stay deterministic.
        let j = r.summary_json();
        assert!(j.get("capacity").is_some());
        assert_eq!(j.get("classes").unwrap().as_array().unwrap().len(), 3);
        let again = run_scale_sim(&cfg).unwrap();
        assert_eq!(
            j.to_string_compact(),
            again.summary_json().to_string_compact()
        );
    }

    #[test]
    fn uniform_capacity_shrinks_upload_and_compute_time() {
        let base = ScaleSimConfig {
            clients: 60,
            iterations: 150,
            params: 16,
            ..ScaleSimConfig::default()
        };
        let half = ScaleSimConfig {
            capacity: Some("uniform:0.5".into()),
            ..base.clone()
        };
        let a = run_scale_sim(&base).unwrap();
        let b = run_scale_sim(&half).unwrap();
        // Same aggregation count in less virtual time: rate-0.5 clients
        // compute and upload half as much.
        assert_eq!(a.aggregations, b.aggregations);
        assert!(
            b.virtual_ticks < a.virtual_ticks,
            "half-capacity run must finish sooner: {} vs {}",
            b.virtual_ticks,
            a.virtual_ticks
        );
        assert_eq!(b.classes.len(), 1);
        assert_eq!(b.classes[0].label, "r0.5");
        assert_eq!(b.classes[0].clients, 60);
    }
}
