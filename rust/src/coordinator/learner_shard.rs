//! Multi-core twin of the learner-driven AFL engine (`coordinator::afl`),
//! built on the snapshot/dispatch/join discipline proven by
//! `coordinator::shard` for the coordinator-only scale simulator.
//!
//! # Architecture
//!
//! One **coordinator thread** owns every ordered decision — the event
//! queue, every `jrng` draw, scheduler requests/grants, capacity
//! slicing, `ServerCore::decide()` + lerp — exactly as the sequential
//! engine does. K **shard workers** own the only expensive pure
//! function on the path: the real [`crate::learner::Learner::train`]
//! call. Clients are
//! partitioned across workers with [`ClientPartition`] (contiguous
//! ranges, same as `repro sim --shards N`), each worker consumes its
//! own task channel, and completions return on one shared channel.
//!
//! Per event, the coordinator:
//!
//! 1. **DownloadDone** — assembles the client's training slab from its
//!    [`BatchCursor`] (ordered, cursor state advances in event order),
//!    dispatches `(snapshot, slab, steps)` to the client's shard worker,
//!    then draws the compute duration from `jrng` and schedules
//!    `ComputeDone` — the same draw, at the same stream position, as the
//!    sequential engine, because `Learner::train` consumes no RNG.
//! 2. **ComputeDone** — scheduler request + grant, identical code.
//! 3. **UploadDone** — **joins** the client's training result (blocking
//!    on the done channel until this client's model has arrived), then
//!    runs the loss/lost draws and the aggregation in exact event order.
//!
//! Training slabs are recycled through a pool (the recycled-arena idiom
//! from `coordinator::shard`): buffers travel to the worker inside the
//! task and come back inside the completion, so steady-state dispatch
//! allocates nothing for batch data.
//!
//! # Why `--shards N` is bit-identical to the sequential engine
//!
//! - Every RNG draw (`jrng` durations, loss coin-flips; scenario
//!   streams) happens on the coordinator at the same point in the same
//!   event order — workers draw nothing.
//! - `Learner::train` is a pure function of `(snapshot, slab, steps)`;
//!   both engines hand it bit-identical inputs, so the returned local
//!   models are bit-identical regardless of which thread ran them.
//! - Aggregation order is the event order: the join in `UploadDone`
//!   forces the client's local model to exist before `ServerCore`
//!   consumes it, and `ServerCore` only ever runs on the coordinator.
//! - The one reordering this engine allows is *when*
//!   [`ServerCore::record_loss`] is called: the sequential engine
//!   records at `DownloadDone` (training time), this engine records at
//!   join/drain time, in completion-arrival order. That is observation-
//!   equivalent: `record_loss` only adds into dense per-client tables
//!   (`loss_sum[c] += loss`), a single client's results join in its own
//!   dispatch order, different clients touch disjoint entries, and no
//!   decision path reads the tables mid-run — `mean_train_loss()` sums
//!   them once at the end. The final drain below guarantees the *set*
//!   of recorded losses matches the sequential engine's exactly (one
//!   per processed `DownloadDone`, including trainings whose upload
//!   never completed before the horizon).
//!
//! The sequential loop in `coordinator::afl` is the executable spec for
//! this file, the way `scale.rs` is for `shard.rs`; `rust/tests/
//! sharded.rs` holds the identity to it across schedulers ×
//! aggregation policies × scenarios × capacity profiles.

use std::sync::{mpsc, Arc};

use anyhow::{ensure, Context, Result};

use super::afl::{adaptive_steps, grant_next, Event};
use super::core::ServerCore;
use super::policy::AggregationPolicy;
use super::runner::{FlContext, Recorder, RunStats};
use super::scale::{class_cells, scaled_tau_up, SubmodelCtx};
use super::scheduler::{SchedulerPolicy, UploadScheduler};
use crate::data::Dataset;
use crate::learner::BatchCursor;
use crate::metrics::{ClassMetrics, RunResult};
use crate::model::{ParamLayout, ParamSet, SubmodelMap};
use crate::net::wire::flat_update_wire_bytes;
use crate::sim::{
    capacity, channel, scenario, ChannelState, ClientPartition, ComputeModel, EventQueue,
    Scenario, UplinkChannel,
};
use crate::telemetry::{LossCause, Telemetry};
use crate::util::rng::Rng;

/// One local-training job: everything `Learner::train` needs, owned, so
/// the worker touches no coordinator state.
struct TrainTask {
    client: usize,
    /// The global snapshot the client trains from (shared, never
    /// mutated — aggregation replaces the server's Arc).
    w: Arc<ParamSet>,
    /// Pre-assembled training slab; recycled through the pool.
    xs: Vec<f32>,
    ys: Vec<i32>,
    steps: usize,
}

/// A finished training job, returning the slab buffers for reuse.
struct TrainDone {
    client: usize,
    result: Result<(ParamSet, f32)>,
    xs: Vec<f32>,
    ys: Vec<i32>,
}

/// Run the sharded learner-driven engine: bit-identical results to
/// [`super::afl::run_afl`] with wall-clock divided across `shards`
/// worker threads (clamped to the client count).
pub fn run_afl_sharded(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
    shards: usize,
) -> Result<RunResult> {
    run_afl_sharded_full(ctx, policy, sched_policy, label, shards).map(|(result, _)| result)
}

/// As [`run_afl_sharded`], also yielding the final global model — the
/// identity witness `rust/tests/sharded.rs` compares against the
/// sequential spec's.
pub fn run_afl_sharded_full(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
    shards: usize,
) -> Result<(RunResult, ParamSet)> {
    run_afl_sharded_traced(ctx, policy, sched_policy, label, shards, &mut Telemetry::off())
}

/// As [`run_afl_sharded_full`], recording ordered trace events and
/// aggregate histograms through `tel`. Every emission happens on the
/// coordinator thread at the same decision points as the sequential
/// spec ([`super::afl::run_afl_traced`]), so the trace is byte-identical
/// at any shard count.
pub fn run_afl_sharded_traced(
    ctx: &FlContext<'_>,
    policy: Box<dyn AggregationPolicy>,
    sched_policy: SchedulerPolicy,
    label: String,
    shards: usize,
    tel: &mut Telemetry,
) -> Result<(RunResult, ParamSet)> {
    ensure!(shards >= 1, "train requires shards >= 1");
    let cfg = ctx.cfg;
    let m = cfg.clients;
    let root = Rng::new(cfg.seed);
    let cm = ComputeModel::new(cfg.heterogeneity, m, cfg.jitter, &root);
    let mut jrng = root.fork(0xd1ce);

    // Identical slot unit as the paired SFL run: fair x-axis.
    let slot_ticks =
        cfg.time
            .sfl_round_heterogeneous(m, cfg.local_steps, cm.slowest_factor());
    let mut rec = Recorder::new(ctx, slot_ticks)?;
    let max_ticks = rec.max_ticks();

    // The world model (static | dropout | churn | drift). Stochastic
    // scenarios draw from their own forked streams, never from `jrng`.
    let mut world: Box<dyn Scenario> = scenario::resolve(cfg.scenario.as_deref())?;
    world.bind(m, slot_ticks, cfg.seed);
    if cfg.scenario.is_some() {
        crate::log_info!("afl[{}]: scenario {}", label, world.label());
    }

    let img = ctx.train.x.len() / ctx.train.len();
    let batch = ctx.learner.batch();

    let w_init = ctx.learner.init(cfg.seed as u32)?;
    // Heterogeneous capacity: same resolution (and `root` draws) as the
    // sequential engine.
    let profile = capacity::resolve(cfg.capacity.as_deref())?;
    let subctx: Option<SubmodelCtx> = if profile.is_trivial() {
        None
    } else {
        let layout = ParamLayout::of(&w_init);
        let class_of = profile.assign(m, &root);
        let maps: Vec<SubmodelMap> = profile
            .classes()
            .iter()
            .map(|c| SubmodelMap::new(&layout, c.rate))
            .collect();
        crate::log_info!("afl[{}]: capacity {}", label, profile.spec());
        Some(SubmodelCtx {
            profile,
            class_of,
            maps,
        })
    };
    let mut subbuf = vec![
        0.0f32;
        subctx.as_ref().map_or(0, |sc| {
            sc.maps.iter().map(|mp| mp.numel()).max().unwrap_or(0)
        })
    ];

    // Uplink fading model — same resolution, fork and draw order as the
    // sequential engine; the coordinator thread owns it like every
    // other ordered decision input.
    let fading = channel::resolve(cfg.channel.as_deref())?;
    let channel_label = fading.spec();
    let mut chan: ChannelState = fading.bind(m, &root);
    if cfg.channel.is_some() {
        crate::log_info!("afl[{}]: channel {}", label, channel_label);
    }
    let mut gains: Vec<f64> = if chan.is_trivial() {
        Vec::new()
    } else {
        vec![1.0; m]
    };
    let full_numel: usize = w_init.tensors.iter().map(|t| t.data.len()).sum();
    let numel_of = |client: usize| match &subctx {
        None => full_numel,
        Some(sc) => sc.map_of(client).numel(),
    };
    let mut bytes_on_wire = 0u64;
    let mut channel_lost = 0u64;

    let partition = ClientPartition::new(m, shards);
    let k_shards = partition.shards();

    let mut core = ServerCore::new(w_init, m, policy, cfg.mu_rho);
    let mut queue: EventQueue<Event> = EventQueue::new();
    let mut channel = UplinkChannel::new();
    let mut scheduler = UploadScheduler::new(sched_policy, m);
    let mut cursors: Vec<BatchCursor> = ctx
        .shards
        .iter()
        .map(|s| BatchCursor::new(s.indices.clone()))
        .collect();
    // Iteration stamp of each client's in-flight training (the model
    // itself joins from the done channel).
    let mut pending: Vec<Option<u64>> = vec![None; m];
    // Joined-but-unconsumed local models, indexed by client.
    let mut locals: Vec<Option<ParamSet>> = vec![None; m];
    // `ready[c]` ⇔ client c has no training in flight with the workers.
    let mut ready: Vec<bool> = vec![true; m];
    // Recycled (xs, ys) slab buffers — dispatch pops, join pushes back.
    let mut slab_pool: Vec<(Vec<f32>, Vec<i32>)> = Vec::new();
    let mut in_flight = 0usize;

    // Upload duration per client: τ^u under the trivial profile, scaled
    // by the client's rate otherwise.
    let tau_up_of = |client: usize| match &subctx {
        None => cfg.time.tau_up,
        Some(sc) => scaled_tau_up(cfg.time.tau_up, sc.map_of(client).rate()),
    };

    let learner = ctx.learner;
    let (result, model) = std::thread::scope(|scope| -> Result<(RunResult, ParamSet)> {
        let (done_tx, done_rx) = mpsc::channel::<TrainDone>();
        let mut task_txs: Vec<mpsc::Sender<TrainTask>> = Vec::with_capacity(k_shards);
        for _ in 0..k_shards {
            let (tx, rx) = mpsc::channel::<TrainTask>();
            task_txs.push(tx);
            let done_tx = done_tx.clone();
            scope.spawn(move || {
                for t in rx {
                    let result = learner.train(&t.w, &t.xs, &t.ys, t.steps);
                    if done_tx
                        .send(TrainDone {
                            client: t.client,
                            result,
                            xs: t.xs,
                            ys: t.ys,
                        })
                        .is_err()
                    {
                        break; // coordinator gone: stop quietly
                    }
                }
            });
        }
        // Workers hold the only other senders; the coordinator's recv
        // must observe worker death, not self-deadlock.
        drop(done_tx);

        // Telemetry setup mirrors the sequential spec exactly (same
        // call points before the t=0 broadcast), so traces agree
        // byte-for-byte at every shard count.
        tel.bind(m);
        if let Some(sc) = &subctx {
            for (c, &k) in sc.class_of.iter().enumerate() {
                tel.class_assign(c, k);
            }
        }

        // t=0: the server broadcasts w_0 to everyone (Algorithm 1
        // line 1). One shared snapshot for the whole broadcast.
        let w0 = Arc::new(core.global().clone());
        for c in 0..m {
            let i = core.issue_to(c);
            queue.schedule_at(cfg.time.tau_down, Event::DownloadDone {
                client: c,
                w: Arc::clone(&w0),
                i,
            });
        }
        drop(w0);

        while let Some((now, ev)) = queue.pop() {
            if now > max_ticks {
                break;
            }
            match ev {
                Event::DownloadDone { client, w: w_recv, i } => {
                    // Slab assembly stays on the coordinator so cursor
                    // state advances in event order; the train call —
                    // a pure function of what we ship — goes to the
                    // client's shard worker.
                    let steps = adaptive_steps(
                        cfg.local_steps,
                        cm.factor(client),
                        cfg.adaptive_iters,
                    );
                    let (mut xs, mut ys) = slab_pool.pop().unwrap_or_default();
                    cursors[client].fill(ctx.train, steps * batch, img, &mut xs, &mut ys);
                    ready[client] = false;
                    in_flight += 1;
                    task_txs[partition.shard_of(client)]
                        .send(TrainTask {
                            client,
                            w: w_recv,
                            xs,
                            ys,
                            steps,
                        })
                        .map_err(|_| anyhow::anyhow!("shard worker exited early"))?;
                    pending[client] = Some(i);
                    // Same `jrng` draw at the same stream position as
                    // the sequential engine (training consumes no RNG).
                    let mut scale = world.compute_scale(client, now);
                    if let Some(sc) = &subctx {
                        scale *= sc.map_of(client).rate();
                    }
                    let dur = cm.duration_scaled(&cfg.time, client, steps, &mut jrng, scale);
                    queue.schedule_in(dur, Event::ComputeDone { client });
                }
                Event::ComputeDone { client } => {
                    if let Some(rejoin) = world.offline_until(client, now) {
                        queue.schedule_at(rejoin, Event::ComputeDone { client });
                        continue;
                    }
                    scheduler.request(client, now);
                    grant_next(
                        &mut scheduler,
                        &mut channel,
                        &mut chan,
                        &mut gains,
                        &mut queue,
                        now,
                        tau_up_of,
                        tel,
                    );
                }
                Event::UploadDone { client } => {
                    let i = pending[client]
                        .take()
                        .expect("upload without a pending local model");
                    // Join: block until THIS client's training result
                    // has arrived, banking any other completions that
                    // drain first. Unconditional — even a lost upload
                    // trained, and its loss must be recorded.
                    while !ready[client] {
                        let done = done_rx
                            .recv()
                            .context("shard worker died before completing its task")?;
                        let (local, loss) = done.result?;
                        core.record_loss(done.client, loss as f64);
                        locals[done.client] = Some(local);
                        ready[done.client] = true;
                        slab_pool.push((done.xs, done.ys));
                        in_flight -= 1;
                    }
                    let local = locals[client]
                        .take()
                        .expect("joined without a trained local model");
                    // Wire meter + loss draws in exact event order,
                    // after the join — same sequence as the sequential
                    // spec (lost uploads still held the TDMA slot).
                    bytes_on_wire += flat_update_wire_bytes(numel_of(client));
                    let scenario_lost = world.upload_lost(client, now);
                    let chan_lost = chan.upload_lost(client, now);
                    if chan_lost {
                        channel_lost += 1;
                    }
                    // Cause ladder in draw order, short-circuiting like
                    // the sequential spec so the `jrng` sequence holds;
                    // the legacy knob reports as scenario loss.
                    let lost = if scenario_lost {
                        Some(LossCause::Scenario)
                    } else if chan_lost {
                        Some(LossCause::Channel)
                    } else if cfg.upload_loss > 0.0 && jrng.f64() < cfg.upload_loss {
                        Some(LossCause::Scenario)
                    } else {
                        None
                    };
                    if let Some(cause) = lost {
                        tel.upload_lost(now, client, cause);
                        core.on_lost_upload(client);
                        let i = core.issue_to(client);
                        queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                            client,
                            w: Arc::new(core.global().clone()),
                            i,
                        });
                        grant_next(
                            &mut scheduler,
                            &mut channel,
                            &mut chan,
                            &mut gains,
                            &mut queue,
                            now,
                            tau_up_of,
                            tel,
                        );
                        continue;
                    }
                    rec.catch_up(now, core.global(), core.iteration())?;

                    let out = match &subctx {
                        None => core.on_update(client, i, &local, ctx)?, // eq. (3)/(11)
                        Some(sc) => {
                            let map = sc.map_of(client);
                            map.extract_from_set(&local, &mut subbuf[..map.numel()]);
                            core.on_update_submodel(client, i, &subbuf[..map.numel()], map)?
                        }
                    };
                    tel.upload_applied(
                        now,
                        client,
                        out.iteration,
                        out.staleness,
                        out.beta,
                        out.weight,
                    );

                    let i = core.issue_to(client);
                    queue.schedule_in(cfg.time.tau_down, Event::DownloadDone {
                        client,
                        w: Arc::new(core.global().clone()),
                        i,
                    });
                    grant_next(
                        &mut scheduler,
                        &mut channel,
                        &mut chan,
                        &mut gains,
                        &mut queue,
                        now,
                        tau_up_of,
                        tel,
                    );
                }
            }
        }

        // Horizon reached: close the task queues (ends the workers once
        // drained) and join every outstanding training. The sequential
        // spec records a loss for every processed DownloadDone — even
        // ones whose upload never lands before max_ticks — so the drain
        // records those losses too; the models are discarded, exactly
        // as the sequential engine discards a never-uploaded `pending`.
        drop(task_txs);
        while in_flight > 0 {
            let done = done_rx
                .recv()
                .context("shard worker died before completing its task")?;
            let (_, loss) = done.result?;
            core.record_loss(done.client, loss as f64);
            in_flight -= 1;
        }

        rec.finish(core.global(), core.iteration())?;
        if core.lost_uploads() > 0 {
            crate::log_info!(
                "afl: {} uploads lost in transit ({} delivered)",
                core.lost_uploads(),
                core.iteration()
            );
        }

        // Per-class roll-up, identical to the sequential engine.
        let classes: Vec<ClassMetrics> = match &subctx {
            None => Vec::new(),
            Some(sc) => {
                let cells = class_cells(
                    sc,
                    core.updates_per_client(),
                    core.lost_per_client(),
                    core.loss_totals(),
                );
                let mut out = Vec::with_capacity(cells.len());
                for (k, cell) in cells.into_iter().enumerate() {
                    let mut x = Vec::new();
                    let mut y = Vec::new();
                    for (c, &cls) in sc.class_of.iter().enumerate() {
                        if cls as usize != k {
                            continue;
                        }
                        for &s in &ctx.shards[c].indices {
                            x.extend_from_slice(ctx.train.image(s));
                            y.push(ctx.train.y[s]);
                        }
                    }
                    let (accuracy, loss) = if y.is_empty() {
                        (0.0, 0.0)
                    } else {
                        let pooled = Dataset { x, y };
                        ctx.learner.evaluate(core.global(), &pooled)?
                    };
                    out.push(ClassMetrics {
                        label: cell.label,
                        rate: cell.rate,
                        clients: cell.clients,
                        uploads: cell.uploads,
                        lost_uploads: cell.lost_uploads,
                        mean_train_loss: cell.mean_train_loss,
                        accuracy,
                        loss,
                    });
                }
                out
            }
        };

        let stats = RunStats {
            label,
            uploads: scheduler.grants().to_vec(),
            aggregations: core.iteration(),
            mean_staleness: core.mean_staleness(),
            fairness: scheduler.jain_fairness(),
            lost_uploads: core.lost_uploads(),
            lost_per_client: core.lost_per_client().to_vec(),
            mean_train_loss: core.mean_train_loss(),
            classes,
            channel: channel_label,
            bytes_on_wire,
            channel_lost,
            total_ticks: max_ticks,
        };
        Ok((rec.into_result(stats), core.into_global()))
    })?;

    let mut result = result;
    result.shards = k_shards;
    result.telemetry = tel.registry_json();
    Ok((result, model))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::coordinator::resolve_policy;
    use crate::session::{LearnerKind, Session};

    fn tiny_cfg() -> RunConfig {
        RunConfig {
            clients: 5,
            samples_per_client: 12,
            test_samples: 40,
            local_steps: 3,
            max_slots: 3.0,
            ..RunConfig::default()
        }
    }

    fn ctx_of(s: &Session) -> FlContext<'_> {
        FlContext {
            cfg: &s.cfg,
            learner: s.learner(),
            engine: s.engine(),
            train: &s.train,
            shards: &s.shards,
            test: &s.test,
        }
    }

    #[test]
    fn matches_the_sequential_engine_bit_for_bit() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        let ctx = ctx_of(&s);
        let (policy, label) = resolve_policy(&s.cfg).unwrap();
        let (r_ref, w_ref) = super::super::afl::run_afl_full(&ctx, policy, s.cfg.scheduler, label).unwrap();
        for shards in [1usize, 2, 3, 7] {
            let (policy, label) = resolve_policy(&s.cfg).unwrap();
            let (r, w) =
                run_afl_sharded_full(&ctx, policy, s.cfg.scheduler, label, shards).unwrap();
            assert_eq!(
                r.summary_json().to_string_compact(),
                r_ref.summary_json().to_string_compact(),
                "summary diverged at shards={shards}"
            );
            assert_eq!(w, w_ref, "final model diverged at shards={shards}");
        }
    }

    #[test]
    fn shard_count_is_clamped_and_surfaced_outside_the_summary() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        let ctx = ctx_of(&s);
        let (policy, label) = resolve_policy(&s.cfg).unwrap();
        let (r, _) = run_afl_sharded_full(&ctx, policy, s.cfg.scheduler, label, 64).unwrap();
        assert_eq!(r.shards, 5, "clamped to the client count");
        assert!(r.summary_json().get("shards").is_none());
    }

    #[test]
    fn rejects_zero_shards() {
        let s = Session::new(tiny_cfg(), LearnerKind::Linear, "artifacts").unwrap();
        let ctx = ctx_of(&s);
        let (policy, label) = resolve_policy(&s.cfg).unwrap();
        let err = run_afl_sharded(&ctx, policy, s.cfg.scheduler, label, 0).unwrap_err();
        assert!(err.to_string().contains("shards >= 1"), "{err}");
    }
}
