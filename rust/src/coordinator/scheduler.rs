//! Sec. III-C client scheduling: TDMA upload-slot arbitration.
//!
//! When a client finishes local computation it *requests* the uplink. The
//! scheduler grants one slot at a time; among simultaneous contenders the
//! CSMAAFL policy favours the client whose *last upload is oldest*
//! (the paper's (k-m') > (k-n') rule), giving staleness-victims priority
//! and enforcing long-run fairness. FIFO and strict round-robin policies
//! are provided as baselines/ablations.

use crate::sim::Ticks;

/// Slot-arbitration policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// CSMAAFL: oldest-last-upload first; ties by request time, then id.
    OldestModelFirst,
    /// First-come-first-served on request time; ties by id.
    Fifo,
    /// Strict cyclic order over client ids (the Sec. III-B baseline
    /// requirement: re-scheduled only after all others uploaded).
    RoundRobin,
}

impl SchedulerPolicy {
    /// Parse a CLI/JSON spelling (`oldest`/`csmaafl`, `fifo`,
    /// `roundrobin`/`rr`).
    pub fn parse(s: &str) -> Option<SchedulerPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "oldest" | "csmaafl" | "oldest-model-first" => Some(SchedulerPolicy::OldestModelFirst),
            "fifo" => Some(SchedulerPolicy::Fifo),
            "roundrobin" | "round-robin" | "rr" => Some(SchedulerPolicy::RoundRobin),
            _ => None,
        }
    }
}

/// A pending upload request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UploadRequest {
    /// The requesting client's id.
    pub client: usize,
    /// Virtual time the request was filed (compute-done time).
    pub requested_at: Ticks,
}

/// The upload-slot scheduler. Tracks, per client, the slot index of its
/// most recent upload (the `m'` of the paper's priority rule) and the
/// total number of granted slots (fairness accounting).
#[derive(Debug, Clone)]
pub struct UploadScheduler {
    policy: SchedulerPolicy,
    pending: Vec<UploadRequest>,
    /// Slot index of each client's previous upload; None = never uploaded.
    last_slot: Vec<Option<u64>>,
    /// Total slots granted so far (the running slot counter k).
    slots_granted: u64,
    /// Per-client grant counts (fairness metrics).
    grants: Vec<u64>,
    /// Next client id for round-robin.
    rr_next: usize,
}

impl UploadScheduler {
    /// A scheduler for `clients` clients under the given policy.
    pub fn new(policy: SchedulerPolicy, clients: usize) -> Self {
        UploadScheduler {
            policy,
            pending: Vec::new(),
            last_slot: vec![None; clients],
            slots_granted: 0,
            grants: vec![0; clients],
            rr_next: 0,
        }
    }

    /// The arbitration policy in force.
    pub fn policy(&self) -> SchedulerPolicy {
        self.policy
    }

    /// Number of requests currently waiting for a slot.
    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Per-client grant counts (fairness accounting).
    pub fn grants(&self) -> &[u64] {
        &self.grants
    }

    /// Total slots granted so far (the running slot counter k).
    pub fn slots_granted(&self) -> u64 {
        self.slots_granted
    }

    /// File an upload request. Panics on duplicate in-flight requests —
    /// a client cannot request twice before being granted.
    pub fn request(&mut self, client: usize, now: Ticks) {
        assert!(
            !self.pending.iter().any(|r| r.client == client),
            "client {client} already has a pending request"
        );
        self.pending.push(UploadRequest {
            client,
            requested_at: now,
        });
    }

    /// Grant the next slot per policy. Returns the winning client, or
    /// None if no request is pending (or, for round-robin, the next
    /// client in cyclic order has not requested yet).
    pub fn grant(&mut self) -> Option<usize> {
        if self.pending.is_empty() {
            return None;
        }
        let pos = match self.policy {
            SchedulerPolicy::Fifo => self
                .pending
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| (r.requested_at, r.client))
                .map(|(i, _)| i)?,
            SchedulerPolicy::OldestModelFirst => self
                .pending
                .iter()
                .enumerate()
                .min_by_key(|(_, r)| {
                    // Never-uploaded clients sort before any slot index.
                    let last = self.last_slot[r.client].map_or(-1i64, |s| s as i64);
                    (last, r.requested_at, r.client)
                })
                .map(|(i, _)| i)?,
            SchedulerPolicy::RoundRobin => {
                let want = self.rr_next;
                let found = self.pending.iter().position(|r| r.client == want)?;
                self.rr_next = (self.rr_next + 1) % self.last_slot.len();
                found
            }
        };
        let req = self.pending.swap_remove(pos);
        self.slots_granted += 1;
        self.last_slot[req.client] = Some(self.slots_granted);
        self.grants[req.client] += 1;
        Some(req.client)
    }

    /// Jain's fairness index over per-client grant counts (1 = perfectly
    /// fair). Undefined (1.0) before any grant.
    pub fn jain_fairness(&self) -> f64 {
        let sum: f64 = self.grants.iter().map(|&g| g as f64).sum();
        if sum == 0.0 {
            return 1.0;
        }
        let sumsq: f64 = self.grants.iter().map(|&g| (g as f64) * (g as f64)).sum();
        sum * sum / (self.grants.len() as f64 * sumsq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_orders_by_request_time() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 3);
        s.request(2, 10);
        s.request(0, 5);
        s.request(1, 7);
        assert_eq!(s.grant(), Some(0));
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(2));
        assert_eq!(s.grant(), None);
    }

    #[test]
    fn oldest_model_first_prefers_never_uploaded() {
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 3);
        s.request(0, 0);
        assert_eq!(s.grant(), Some(0)); // slot 1
        s.request(0, 10);
        s.request(1, 12); // never uploaded: wins despite later request
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(0));
    }

    #[test]
    fn oldest_model_first_implements_paper_rule() {
        // Clients m and n request simultaneously at slot time k; the one
        // with the older previous slot (larger k - m') wins.
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 2);
        s.request(0, 0);
        s.grant(); // client 0 -> slot 1
        s.request(1, 1);
        s.grant(); // client 1 -> slot 2
        s.request(0, 5);
        s.request(1, 5); // simultaneous
        assert_eq!(s.grant(), Some(0), "client 0's last slot (1) is older");
    }

    #[test]
    fn round_robin_waits_for_the_next_in_cycle() {
        let mut s = UploadScheduler::new(SchedulerPolicy::RoundRobin, 3);
        s.request(1, 0);
        s.request(2, 0);
        assert_eq!(s.grant(), None, "client 0 has not requested");
        s.request(0, 1);
        assert_eq!(s.grant(), Some(0));
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(2));
    }

    #[test]
    #[should_panic]
    fn duplicate_request_panics() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 2);
        s.request(0, 0);
        s.request(0, 1);
    }

    #[test]
    fn fairness_index() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 2);
        assert_eq!(s.jain_fairness(), 1.0);
        s.request(0, 0);
        s.grant();
        s.request(0, 1);
        s.grant();
        // 2 grants vs 0: J = (2)^2 / (2 * 4) = 0.5
        assert!((s.jain_fairness() - 0.5).abs() < 1e-12);
        s.request(1, 2);
        s.grant();
        s.request(1, 3);
        s.grant();
        assert!((s.jain_fairness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn oldest_policy_is_long_run_fair_under_skew() {
        // Client 0 requests 5x as often; grants must stay balanced
        // because priority always returns to the starved client.
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 2);
        let mut t = 0;
        for _ in 0..100 {
            s.request(0, t);
            if t % 5 == 0 {
                s.request(1, t + 1);
            }
            while s.grant().is_some() {}
            t += 2;
        }
        let g = s.grants();
        // Client 1 only requested ~20 times; every one of its requests
        // should have been served promptly.
        assert!(g[1] >= 19, "{g:?}");
    }
}
