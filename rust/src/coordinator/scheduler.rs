//! Sec. III-C client scheduling: TDMA upload-slot arbitration.
//!
//! When a client finishes local computation it *requests* the uplink.
//! [`UploadScheduler`] owns the bookkeeping — pending requests, each
//! client's last-upload slot, grant counts — and delegates the actual
//! arbitration to a pluggable `SchedulingPolicy` (see
//! `coordinator::policy`): CSMAAFL's oldest-model-first rule, FIFO, or
//! strict round-robin. New arbitration rules are trait impls, not
//! engine changes.
//!
//! ## Complexity at scale
//!
//! The three built-in policies run on specialized index structures so a
//! million-client simulation stays event-loop-bound, not
//! arbitration-bound:
//!
//! | Policy                | request       | grant         | structure |
//! |-----------------------|---------------|---------------|-----------|
//! | `oldest` (CSMAAFL)    | O(log n)      | O(log n)      | binary heap keyed `(last-slot, request-time, id)` |
//! | `fifo`                | O(log n)      | O(log n)      | binary heap keyed `(request-time, id)` |
//! | `roundrobin`          | O(1)          | O(1)          | cyclic cursor over dense in-flight flags |
//! | `channel-aware`       | O(1)          | O(pending)    | scan scoring `(last-slot+1)/gain` per contender |
//!
//! The heap key of a pending `oldest` request is fixed at request time:
//! a client's last-upload slot can only change when it is *granted*, and
//! a client cannot be granted while its request is still pending — so
//! request-time priorities never go stale. `channel-aware` cannot use a
//! heap: a contender's priority moves with the fading channel while it
//! waits, so every grant re-scores the pending set against the gains the
//! engine passes to [`UploadScheduler::grant_with_gains`]. Custom
//! `SchedulingPolicy` impls (via [`UploadScheduler::with_policy`]) fall
//! back to the same O(n) reference scan; `tests/properties.rs` asserts
//! the fast paths pick the same winners as that scan on random
//! workloads.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::policy::{
    ChannelAware, Fifo, OldestModelFirst, RoundRobin, SchedulerView, SchedulingPolicy,
};
use crate::sim::Ticks;

/// Built-in slot-arbitration policy selector (config/CLI spelling).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerPolicy {
    /// CSMAAFL: oldest-last-upload first; ties by request time, then id.
    OldestModelFirst,
    /// First-come-first-served on request time; ties by id.
    Fifo,
    /// Strict cyclic order over client ids (the Sec. III-B baseline
    /// requirement: re-scheduled only after all others uploaded).
    RoundRobin,
    /// Channel-aware rule (arXiv:2107.11415): minimize
    /// `(last-slot + 1) / gain` so model age is weighed against the
    /// instantaneous fading-channel gain. Identical to
    /// `OldestModelFirst` when every gain is 1 (ideal channel).
    ChannelAware,
}

impl SchedulerPolicy {
    /// Parse a CLI/JSON spelling (`oldest`/`csmaafl`, `fifo`,
    /// `roundrobin`/`rr`, `channel-aware`).
    pub fn parse(s: &str) -> Option<SchedulerPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "oldest" | "csmaafl" | "oldest-model-first" => Some(SchedulerPolicy::OldestModelFirst),
            "fifo" => Some(SchedulerPolicy::Fifo),
            "roundrobin" | "round-robin" | "rr" => Some(SchedulerPolicy::RoundRobin),
            "channel-aware" | "channelaware" => Some(SchedulerPolicy::ChannelAware),
            _ => None,
        }
    }

    /// Canonical config spelling (JSON provenance).
    pub fn name(&self) -> &'static str {
        match self {
            SchedulerPolicy::OldestModelFirst => "oldest",
            SchedulerPolicy::Fifo => "fifo",
            SchedulerPolicy::RoundRobin => "roundrobin",
            SchedulerPolicy::ChannelAware => "channel-aware",
        }
    }

    /// Instantiate the corresponding `SchedulingPolicy` trait object.
    pub fn build(&self) -> Box<dyn SchedulingPolicy> {
        match self {
            SchedulerPolicy::OldestModelFirst => Box::new(OldestModelFirst),
            SchedulerPolicy::Fifo => Box::new(Fifo),
            SchedulerPolicy::RoundRobin => Box::new(RoundRobin::default()),
            SchedulerPolicy::ChannelAware => Box::new(ChannelAware),
        }
    }
}

/// A pending upload request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UploadRequest {
    /// The requesting client's id.
    pub client: usize,
    /// Virtual time the request was filed (compute-done time).
    pub requested_at: Ticks,
}

/// The arbitration engine behind [`UploadScheduler`]: a policy-shaped
/// index structure for the built-ins, or the O(n) reference scan for
/// arbitrary [`SchedulingPolicy`] impls.
#[derive(Debug)]
enum Arbiter {
    /// Min-heap over `(priority, request-time, client)`. `by_last_slot`
    /// keys priority on the requester's previous upload slot (-1 =
    /// never; the `oldest` rule); FIFO uses constant priority so the
    /// order is pure `(request-time, client)`.
    Heap {
        heap: BinaryHeap<Reverse<(i64, Ticks, usize)>>,
        by_last_slot: bool,
    },
    /// Strict cyclic cursor over the dense in-flight flags (roundrobin).
    Cursor { next: usize },
    /// Reference path: linear scan through an arbitrary policy impl.
    Scan {
        policy: Box<dyn SchedulingPolicy>,
        pending: Vec<UploadRequest>,
    },
}

/// The upload-slot scheduler. Tracks, per client, the slot index of its
/// most recent upload (the `m'` of the paper's priority rule) and the
/// total number of granted slots (fairness accounting); the winner
/// among contenders is chosen by the policy's arbitration structure
/// (see the module docs for the complexity table).
#[derive(Debug)]
pub struct UploadScheduler {
    kind: SchedulerPolicy,
    arbiter: Arbiter,
    /// Dense per-client flag: request filed, not yet granted. O(1)
    /// duplicate detection and the roundrobin cursor's state.
    in_flight: Vec<bool>,
    /// Number of requests currently waiting for a slot.
    pending: usize,
    /// Slot index of each client's previous upload; None = never uploaded.
    last_slot: Vec<Option<u64>>,
    /// Total slots granted so far (the running slot counter k).
    slots_granted: u64,
    /// Per-client grant counts (fairness metrics).
    grants: Vec<u64>,
}

impl UploadScheduler {
    /// A scheduler for `clients` clients under the given built-in policy
    /// (heap / cursor fast path).
    pub fn new(policy: SchedulerPolicy, clients: usize) -> Self {
        let arbiter = match policy {
            SchedulerPolicy::OldestModelFirst => Arbiter::Heap {
                heap: BinaryHeap::new(),
                by_last_slot: true,
            },
            SchedulerPolicy::Fifo => Arbiter::Heap {
                heap: BinaryHeap::new(),
                by_last_slot: false,
            },
            SchedulerPolicy::RoundRobin => Arbiter::Cursor { next: 0 },
            // Channel state moves while a request waits, so priorities
            // cannot be frozen into a heap at request time.
            SchedulerPolicy::ChannelAware => Arbiter::Scan {
                policy: Box::new(ChannelAware),
                pending: Vec::new(),
            },
        };
        Self::build_with(policy, arbiter, clients)
    }

    /// A scheduler driven by an arbitrary `SchedulingPolicy` impl via
    /// the O(n) reference scan. `kind` names the nearest built-in for
    /// provenance accessors.
    pub fn with_policy(
        kind: SchedulerPolicy,
        policy: Box<dyn SchedulingPolicy>,
        clients: usize,
    ) -> Self {
        Self::build_with(
            kind,
            Arbiter::Scan {
                policy,
                pending: Vec::new(),
            },
            clients,
        )
    }

    fn build_with(kind: SchedulerPolicy, arbiter: Arbiter, clients: usize) -> Self {
        UploadScheduler {
            kind,
            arbiter,
            in_flight: vec![false; clients],
            pending: 0,
            last_slot: vec![None; clients],
            slots_granted: 0,
            grants: vec![0; clients],
        }
    }

    /// The arbitration policy in force.
    pub fn policy(&self) -> SchedulerPolicy {
        self.kind
    }

    /// Number of requests currently waiting for a slot.
    pub fn pending_len(&self) -> usize {
        self.pending
    }

    /// Per-client grant counts (fairness accounting).
    pub fn grants(&self) -> &[u64] {
        &self.grants
    }

    /// Total slots granted so far (the running slot counter k).
    pub fn slots_granted(&self) -> u64 {
        self.slots_granted
    }

    /// File an upload request. Panics on duplicate in-flight requests —
    /// a client cannot request twice before being granted.
    pub fn request(&mut self, client: usize, now: Ticks) {
        assert!(
            !self.in_flight[client],
            "client {client} already has a pending request"
        );
        self.in_flight[client] = true;
        self.pending += 1;
        match &mut self.arbiter {
            Arbiter::Heap { heap, by_last_slot } => {
                let priority = if *by_last_slot {
                    self.last_slot[client].map_or(-1i64, |s| s as i64)
                } else {
                    0
                };
                heap.push(Reverse((priority, now, client)));
            }
            // The in-flight flags are the cursor's entire state.
            Arbiter::Cursor { .. } => {}
            Arbiter::Scan { pending, .. } => pending.push(UploadRequest {
                client,
                requested_at: now,
            }),
        }
    }

    /// Grant the next slot per policy. Returns the winning client, or
    /// None if no request is pending (or the policy leaves the slot
    /// idle, e.g. round-robin waiting for the next client in cycle).
    pub fn grant(&mut self) -> Option<usize> {
        self.grant_with_gains(None)
    }

    /// [`grant`](Self::grant) with instantaneous per-client channel
    /// gains for gain-sensitive policies (`channel-aware`). Engines
    /// refresh only the entries of clients listed by
    /// [`pending_clients`](Self::pending_clients) before each grant;
    /// the built-in age/time policies never read the slice.
    pub fn grant_with_gains(&mut self, gains: Option<&[f64]>) -> Option<usize> {
        if self.pending == 0 {
            return None;
        }
        let client = match &mut self.arbiter {
            Arbiter::Heap { heap, .. } => {
                let Reverse((_, _, client)) = heap.pop()?;
                client
            }
            Arbiter::Cursor { next } => {
                if !self.in_flight[*next] {
                    return None;
                }
                let client = *next;
                *next = (*next + 1) % self.in_flight.len().max(1);
                client
            }
            Arbiter::Scan { policy, pending } => {
                let view = SchedulerView {
                    last_slot: &self.last_slot,
                    gains,
                };
                let pos = policy.pick(pending, &view)?;
                pending.swap_remove(pos).client
            }
        };
        self.in_flight[client] = false;
        self.pending -= 1;
        self.slots_granted += 1;
        self.last_slot[client] = Some(self.slots_granted);
        self.grants[client] += 1;
        Some(client)
    }

    /// The requests currently contending for the slot, in arbitrary
    /// order — empty for the heap/cursor fast paths, which never need
    /// per-grant gain refreshes. Engines use this to fill a gains
    /// buffer for exactly the contending clients (O(pending), not
    /// O(clients)) before [`grant_with_gains`](Self::grant_with_gains).
    pub fn pending_clients(&self) -> &[UploadRequest] {
        match &self.arbiter {
            Arbiter::Scan { pending, .. } => pending,
            _ => &[],
        }
    }

    /// Jain's fairness index over per-client grant counts (1 = perfectly
    /// fair). Undefined (1.0) before any grant.
    pub fn jain_fairness(&self) -> f64 {
        let sum: f64 = self.grants.iter().map(|&g| g as f64).sum();
        if sum == 0.0 {
            return 1.0;
        }
        let sumsq: f64 = self.grants.iter().map(|&g| (g as f64) * (g as f64)).sum();
        sum * sum / (self.grants.len() as f64 * sumsq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_orders_by_request_time() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 3);
        s.request(2, 10);
        s.request(0, 5);
        s.request(1, 7);
        assert_eq!(s.grant(), Some(0));
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(2));
        assert_eq!(s.grant(), None);
    }

    #[test]
    fn oldest_model_first_prefers_never_uploaded() {
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 3);
        s.request(0, 0);
        assert_eq!(s.grant(), Some(0)); // slot 1
        s.request(0, 10);
        s.request(1, 12); // never uploaded: wins despite later request
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(0));
    }

    #[test]
    fn oldest_model_first_implements_paper_rule() {
        // Clients m and n request simultaneously at slot time k; the one
        // with the older previous slot (larger k - m') wins.
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 2);
        s.request(0, 0);
        s.grant(); // client 0 -> slot 1
        s.request(1, 1);
        s.grant(); // client 1 -> slot 2
        s.request(0, 5);
        s.request(1, 5); // simultaneous
        assert_eq!(s.grant(), Some(0), "client 0's last slot (1) is older");
    }

    #[test]
    fn round_robin_waits_for_the_next_in_cycle() {
        let mut s = UploadScheduler::new(SchedulerPolicy::RoundRobin, 3);
        s.request(1, 0);
        s.request(2, 0);
        assert_eq!(s.grant(), None, "client 0 has not requested");
        s.request(0, 1);
        assert_eq!(s.grant(), Some(0));
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.grant(), Some(2));
    }

    #[test]
    #[should_panic]
    fn duplicate_request_panics() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 2);
        s.request(0, 0);
        s.request(0, 1);
    }

    #[test]
    fn fairness_index() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 2);
        assert_eq!(s.jain_fairness(), 1.0);
        s.request(0, 0);
        s.grant();
        s.request(0, 1);
        s.grant();
        // 2 grants vs 0: J = (2)^2 / (2 * 4) = 0.5
        assert!((s.jain_fairness() - 0.5).abs() < 1e-12);
        s.request(1, 2);
        s.grant();
        s.request(1, 3);
        s.grant();
        assert!((s.jain_fairness() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn oldest_policy_is_long_run_fair_under_skew() {
        // Client 0 requests 5x as often; grants must stay balanced
        // because priority always returns to the starved client.
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 2);
        let mut t = 0;
        for _ in 0..100 {
            s.request(0, t);
            if t % 5 == 0 {
                s.request(1, t + 1);
            }
            while s.grant().is_some() {}
            t += 2;
        }
        let g = s.grants();
        // Client 1 only requested ~20 times; every one of its requests
        // should have been served promptly.
        assert!(g[1] >= 19, "{g:?}");
    }

    #[test]
    fn channel_aware_without_gains_mirrors_oldest() {
        // Same request trace through both schedulers: with no gains the
        // channel-aware score is pure model age, i.e. the oldest rule.
        let mut ca = UploadScheduler::new(SchedulerPolicy::ChannelAware, 3);
        let mut om = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, 3);
        let trace = [(2usize, 0), (0, 1), (1, 1), (2, 8), (0, 8), (1, 9)];
        let mut i = 0;
        for chunk in trace.chunks(3) {
            for &(c, t) in chunk {
                ca.request(c, t);
                om.request(c, t);
            }
            loop {
                let a = ca.grant_with_gains(None);
                let b = om.grant();
                assert_eq!(a, b, "step {i}");
                i += 1;
                if a.is_none() {
                    break;
                }
            }
        }
        assert_eq!(ca.policy().name(), "channel-aware");
    }

    #[test]
    fn channel_aware_grants_follow_the_gains() {
        let mut s = UploadScheduler::new(SchedulerPolicy::ChannelAware, 2);
        // Client 0 is staler (slot 1 vs 2: score 2/0.25 = 8 vs 3/2 =
        // 1.5) yet client 1's strong channel wins the slot.
        s.request(0, 0);
        s.request(1, 0);
        s.grant();
        s.grant();
        s.request(0, 5);
        s.request(1, 5);
        let pending: Vec<usize> = s.pending_clients().iter().map(|r| r.client).collect();
        assert_eq!(pending.len(), 2, "{pending:?}");
        let mut gains = [1.0f64, 1.0];
        gains[0] = 0.25;
        gains[1] = 2.0;
        assert_eq!(s.grant_with_gains(Some(&gains)), Some(1));
        assert_eq!(s.grant_with_gains(Some(&gains)), Some(0));
    }

    #[test]
    fn pending_clients_is_empty_on_fast_paths() {
        let mut s = UploadScheduler::new(SchedulerPolicy::Fifo, 2);
        s.request(0, 0);
        assert!(s.pending_clients().is_empty());
    }

    #[test]
    fn custom_policy_box_drives_the_scheduler() {
        // The same machinery accepts a policy constructed directly.
        let mut s = UploadScheduler::with_policy(
            SchedulerPolicy::Fifo,
            SchedulerPolicy::Fifo.build(),
            2,
        );
        s.request(1, 4);
        s.request(0, 9);
        assert_eq!(s.grant(), Some(1));
        assert_eq!(s.policy(), SchedulerPolicy::Fifo);
    }
}
