//! Sec. III-C model aggregation: the staleness-aware coefficient (eq. 11)
//! and the μ_ji moving-average tracker.
//!
//! ```text
//! 1 - β_j = min(1, μ_ji / (γ · j · (j - i)))
//! ```
//!
//! where j is the current global iteration, i the iteration whose global
//! model the uploading client started from, μ_ji the running average of
//! observed staleness (j - i), and γ > 0 a hyper-parameter. The 1/j term
//! makes individual contributions shrink as training progresses; the
//! μ/(j-i) term discounts stale updates relative to typical staleness.
//!
//! These are the pure math primitives; the `StalenessEq11` policy in
//! `coordinator::policy` wraps [`local_weight`] for the server core,
//! which owns the [`StalenessTracker`].

/// Exponential moving average of observed staleness values.
#[derive(Debug, Clone)]
pub struct StalenessTracker {
    mu: f64,
    rho: f64,
    observations: u64,
}

impl StalenessTracker {
    /// `rho` is the EMA rate (weight of the newest observation).
    pub fn new(rho: f64) -> Self {
        assert!((0.0..=1.0).contains(&rho), "rho in [0,1]");
        StalenessTracker {
            mu: 1.0,
            rho,
            observations: 0,
        }
    }

    /// Current μ estimate.
    pub fn mu(&self) -> f64 {
        self.mu
    }

    /// How many staleness values have been observed so far.
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Record an observed staleness (j - i).
    pub fn observe(&mut self, staleness: u64) {
        let s = staleness as f64;
        if self.observations == 0 {
            // Seed the average with the first real observation instead of
            // biasing toward the arbitrary initial value.
            self.mu = s.max(1.0);
        } else {
            self.mu = (1.0 - self.rho) * self.mu + self.rho * s.max(1.0);
        }
        self.observations += 1;
    }
}

/// Eq. (11): the weight `1-β_j` given to the uploaded local model.
///
/// `iteration` is the 1-based global iteration j of this aggregation;
/// `staleness` is j - i (0 when no other aggregation intervened — treated
/// as 1, the freshest possible, to keep the expression finite).
pub fn local_weight(mu: f64, gamma: f64, iteration: u64, staleness: u64) -> f64 {
    assert!(gamma > 0.0, "gamma must be positive");
    let j = iteration.max(1) as f64;
    let s = staleness.max(1) as f64;
    (mu / (gamma * j * s)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn early_iterations_take_full_update() {
        // Small j ⇒ min(1, ·) saturates at 1: fast early learning.
        assert_eq!(local_weight(1.0, 0.2, 1, 1), 1.0);
        // μ=20, γ=0.2, j=4, s=20 ⇒ 20/16 > 1 ⇒ saturates.
        assert_eq!(local_weight(20.0, 0.2, 4, 20), 1.0);
    }

    #[test]
    fn weight_decays_with_iteration() {
        let w10 = local_weight(5.0, 0.4, 10, 5);
        let w100 = local_weight(5.0, 0.4, 100, 5);
        let w1000 = local_weight(5.0, 0.4, 1000, 5);
        assert!(w10 > w100 && w100 > w1000);
        assert!((w100 / w1000 - 10.0).abs() < 1e-9, "1/j scaling");
    }

    #[test]
    fn staler_updates_weigh_less() {
        let fresh = local_weight(5.0, 0.4, 100, 1);
        let typical = local_weight(5.0, 0.4, 100, 5);
        let stale = local_weight(5.0, 0.4, 100, 50);
        assert!(fresh > typical && typical > stale);
    }

    #[test]
    fn typical_staleness_cancels_mu() {
        // When s == μ, weight = 1/(γ j): the pure 1/j decay of the paper.
        let w = local_weight(8.0, 0.5, 40, 8);
        assert!((w - 1.0 / (0.5 * 40.0)).abs() < 1e-12);
    }

    #[test]
    fn larger_gamma_shrinks_contributions() {
        let small = local_weight(5.0, 0.1, 50, 5);
        let large = local_weight(5.0, 0.6, 50, 5);
        assert!(small > large);
        assert!((small / large - 6.0).abs() < 1e-9);
    }

    #[test]
    fn zero_staleness_treated_as_fresh() {
        let w = local_weight(5.0, 0.4, 100, 0);
        assert_eq!(w, local_weight(5.0, 0.4, 100, 1));
        assert!(w <= 1.0);
    }

    #[test]
    fn weight_always_in_unit_interval() {
        for j in [1u64, 2, 10, 1000] {
            for s in [0u64, 1, 7, 500] {
                for mu in [0.5, 1.0, 30.0] {
                    for gamma in [0.1, 0.2, 0.4, 0.6] {
                        let w = local_weight(mu, gamma, j, s);
                        assert!((0.0..=1.0).contains(&w), "{w}");
                    }
                }
            }
        }
    }

    #[test]
    fn tracker_seeds_then_smooths() {
        let mut t = StalenessTracker::new(0.1);
        assert_eq!(t.mu(), 1.0);
        t.observe(9);
        assert_eq!(t.mu(), 9.0, "first observation seeds μ");
        t.observe(19);
        assert!((t.mu() - (0.9 * 9.0 + 0.1 * 19.0)).abs() < 1e-12);
        assert_eq!(t.observations(), 2);
    }

    #[test]
    fn tracker_converges_to_constant_stream() {
        let mut t = StalenessTracker::new(0.2);
        for _ in 0..200 {
            t.observe(7);
        }
        assert!((t.mu() - 7.0).abs() < 1e-6);
    }

    #[test]
    fn tracker_floors_zero_staleness() {
        let mut t = StalenessTracker::new(0.5);
        t.observe(0);
        assert_eq!(t.mu(), 1.0);
    }

    #[test]
    #[should_panic]
    fn gamma_must_be_positive() {
        local_weight(1.0, 0.0, 1, 1);
    }
}
