//! The sans-IO federated server: one state machine shared verbatim by
//! the virtual-time simulator (`afl`, `afl_baseline`) and the TCP
//! deployment leader (`net::leader`).
//!
//! `ServerCore` owns the global model, the aggregation counter j, the
//! μ_ji staleness tracker, per-client model-version bookkeeping and
//! lost-upload statistics. It is driven entirely by explicit inputs —
//! `issue_to` when a client is handed the global model, `on_update` when
//! an upload arrives, `on_lost_upload` when one is dropped in transit —
//! and knows nothing about virtual time, sockets or event queues. The
//! aggregation *rule* is a pluggable `AggregationPolicy`; the eq.-(3)
//! tensor arithmetic is a pluggable [`ModelAggregator`] (host lerp vs
//! the PJRT Pallas kernel).

use anyhow::{ensure, Result};

use super::policy::{AggregationPolicy, UpdateObservation};
use super::staleness::StalenessTracker;
use crate::model::{ParamSet, SubmodelMap};

/// Executor of eq. (3) `w ← β·w + (1-β)·w_local`: how the aggregation
/// arithmetic runs, independent of which policy chose β.
pub trait ModelAggregator {
    /// Blend `local` into `global` with global-model coefficient `beta`.
    fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()>;
}

/// Host-tensor lerp — the default executor (the TCP leader uses this).
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeAggregator;

impl ModelAggregator for NativeAggregator {
    fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()> {
        global.lerp_inplace(local, beta);
        Ok(())
    }
}

/// What one `ServerCore::on_update` did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AggregationOutcome {
    /// Global iteration count after this aggregation (1-based).
    pub iteration: u64,
    /// Observed staleness j - i of the absorbed update.
    pub staleness: u64,
    /// Weight `1-β_j` the policy gave the local model.
    pub weight: f64,
    /// The f32 β actually applied to the global model.
    pub beta: f32,
}

/// Dense, index-keyed per-client bookkeeping (structure-of-arrays).
/// At million-client scale this state is touched on every event, so it
/// lives in parallel flat vectors — cache-friendly, O(1) indexed, no
/// per-client heap objects.
#[derive(Debug, Clone, Default)]
struct ClientTable {
    /// Iteration stamp of the model most recently issued to each client.
    model_version: Vec<u64>,
    /// Updates absorbed per client (fairness accounting).
    updates: Vec<u64>,
    /// Uploads lost in transit per client (dropout-bias accounting).
    lost: Vec<u64>,
    /// Sum of client-reported local training losses.
    loss_sum: Vec<f64>,
    /// Number of loss reports behind `loss_sum`.
    loss_n: Vec<u64>,
}

impl ClientTable {
    fn new(clients: usize) -> ClientTable {
        ClientTable {
            model_version: vec![0; clients],
            updates: vec![0; clients],
            lost: vec![0; clients],
            loss_sum: vec![0.0; clients],
            loss_n: vec![0; clients],
        }
    }
}

/// The sans-IO server state machine. See the module docs for the
/// driving contract.
pub struct ServerCore {
    w: ParamSet,
    policy: Box<dyn AggregationPolicy>,
    tracker: StalenessTracker,
    j: u64,
    alpha: f64,
    clients: ClientTable,
    staleness_sum: f64,
    lost_uploads: u64,
}

impl ServerCore {
    /// A fresh server over initial global model `w0` for `clients`
    /// clients, aggregating per `policy`, tracking μ at EMA rate
    /// `mu_rho`.
    pub fn new(
        w0: ParamSet,
        clients: usize,
        policy: Box<dyn AggregationPolicy>,
        mu_rho: f64,
    ) -> ServerCore {
        ServerCore {
            w: w0,
            policy,
            tracker: StalenessTracker::new(mu_rho),
            j: 0,
            alpha: 1.0 / clients.max(1) as f64,
            clients: ClientTable::new(clients),
            staleness_sum: 0.0,
            lost_uploads: 0,
        }
    }

    /// The current global model.
    pub fn global(&self) -> &ParamSet {
        &self.w
    }

    /// Consume the core, yielding the final global model.
    pub fn into_global(self) -> ParamSet {
        self.w
    }

    /// Global aggregations performed so far (the paper's j).
    pub fn iteration(&self) -> u64 {
        self.j
    }

    /// Record that `client` is being handed the current global model and
    /// return the iteration stamp to attach to it. The driver ships the
    /// actual parameters (snapshot, socket frame, ...).
    pub fn issue_to(&mut self, client: usize) -> u64 {
        self.clients.model_version[client] = self.j;
        self.j
    }

    /// The iteration stamp of the model most recently issued to `client`.
    pub fn model_version(&self, client: usize) -> u64 {
        self.clients.model_version[client]
    }

    /// The shared decision step of both update paths — everything except
    /// the tensor arithmetic (staleness, policy weight/β, μ tracking) —
    /// so [`ServerCore::on_update`] and [`ServerCore::on_update_flat`]
    /// provably make bit-identical decisions.
    fn decide(&mut self, client: usize, start_iteration: u64, update_norm: f64) -> (u64, f64, f32) {
        let staleness = self.j.saturating_sub(start_iteration);
        let obs = UpdateObservation {
            client,
            iteration: self.j + 1,
            staleness,
            mu: self.tracker.mu(),
            alpha: self.alpha,
            update_norm,
        };
        let weight = self.policy.weight(&obs).clamp(0.0, 1.0);
        let beta = self.policy.beta(weight);
        self.tracker.observe(staleness);
        self.staleness_sum += staleness as f64;
        (staleness, weight, beta)
    }

    /// Advance the iteration counter and per-client statistics after an
    /// absorbed update.
    fn advance(&mut self, client: usize) {
        self.j += 1;
        self.clients.updates[client] += 1;
    }

    /// Absorb an uploaded local model: ask the policy for the weight,
    /// apply eq. (3) through `agg`, advance j and all statistics.
    /// `start_iteration` is the stamp the client trained from (clients
    /// self-report it in the TCP deployment; the simulator threads it
    /// through its download events).
    pub fn on_update(
        &mut self,
        client: usize,
        start_iteration: u64,
        local: &ParamSet,
        agg: &dyn ModelAggregator,
    ) -> Result<AggregationOutcome> {
        let update_norm = if self.policy.needs_update_norm() {
            self.w.l2_distance(local)
        } else {
            0.0
        };
        let (staleness, weight, beta) = self.decide(client, start_iteration, update_norm);
        agg.aggregate(&mut self.w, local, beta)?;
        self.advance(client);
        Ok(AggregationOutcome {
            iteration: self.j,
            staleness,
            weight,
            beta,
        })
    }

    /// The arena hot path: absorb a local model given as one flat buffer
    /// in manifest order (e.g. a [`crate::model::ParamArena`] slot),
    /// aggregating in place with the [`crate::model::lerp_flat`] kernel
    /// — no allocation, no `ParamSet` construction. Bit-identical to
    /// [`ServerCore::on_update`] with the native aggregator (asserted in
    /// `tests/properties.rs`).
    pub fn on_update_flat(
        &mut self,
        client: usize,
        start_iteration: u64,
        local: &[f32],
    ) -> Result<AggregationOutcome> {
        ensure!(
            local.len() == self.w.numel(),
            "flat update has {} elements, global model has {}",
            local.len(),
            self.w.numel()
        );
        let update_norm = if self.policy.needs_update_norm() {
            self.w.l2_distance_flat(local)
        } else {
            0.0
        };
        let (staleness, weight, beta) = self.decide(client, start_iteration, update_norm);
        self.w.lerp_inplace_flat(local, beta);
        self.advance(client);
        Ok(AggregationOutcome {
            iteration: self.j,
            staleness,
            weight,
            beta,
        })
    }

    /// The heterogeneous-capacity path: absorb a rate-scaled submodel
    /// given as a packed flat buffer over `map`'s covered slices (see
    /// [`crate::model::SubmodelMap`]). The policy decides exactly as in
    /// the full-model paths — same [`ServerCore::decide`], with the
    /// update norm measured over the covered slice only — and eq. (3)
    /// is applied only to the covered leading span of every tensor;
    /// uncovered elements keep the current global (the HeteroFL rule).
    /// When `map` is the identity (rate 1.0) this delegates to
    /// [`ServerCore::on_update_flat`], so `capacity=uniform:1.0` is
    /// bit-identical to the pre-submodel engine.
    pub fn on_update_submodel(
        &mut self,
        client: usize,
        start_iteration: u64,
        local_sub: &[f32],
        map: &SubmodelMap,
    ) -> Result<AggregationOutcome> {
        if map.is_full() {
            return self.on_update_flat(client, start_iteration, local_sub);
        }
        ensure!(
            map.full_numel() == self.w.numel(),
            "submodel map covers a {}-element model, global model has {}",
            map.full_numel(),
            self.w.numel()
        );
        ensure!(
            local_sub.len() == map.numel(),
            "submodel update has {} elements, map covers {}",
            local_sub.len(),
            map.numel()
        );
        let update_norm = if self.policy.needs_update_norm() {
            map.l2_distance_set(&self.w, local_sub)
        } else {
            0.0
        };
        let (staleness, weight, beta) = self.decide(client, start_iteration, update_norm);
        map.merge_lerp_set(&mut self.w, local_sub, beta);
        self.advance(client);
        Ok(AggregationOutcome {
            iteration: self.j,
            staleness,
            weight,
            beta,
        })
    }

    /// Record an upload lost in transit (failure injection / network
    /// drop / `dropout` scenario). No aggregation happens; only the
    /// statistics advance.
    pub fn on_lost_upload(&mut self, client: usize) {
        self.lost_uploads += 1;
        self.clients.lost[client] += 1;
    }

    /// Record a client-reported local training loss (dense per-client
    /// accounting; drivers call this when a trained model surfaces).
    pub fn record_loss(&mut self, client: usize, loss: f64) {
        self.clients.loss_sum[client] += loss;
        self.clients.loss_n[client] += 1;
    }

    /// Mean reported training loss of one client (0 before any report).
    pub fn mean_loss(&self, client: usize) -> f64 {
        if self.clients.loss_n[client] > 0 {
            self.clients.loss_sum[client] / self.clients.loss_n[client] as f64
        } else {
            0.0
        }
    }

    /// Mean reported training loss across every report from every
    /// client (0 before any report).
    pub fn mean_train_loss(&self) -> f64 {
        let n: u64 = self.clients.loss_n.iter().sum();
        if n > 0 {
            self.clients.loss_sum.iter().sum::<f64>() / n as f64
        } else {
            0.0
        }
    }

    /// Per-client loss accounting totals `(loss_sum, loss_n)` — the raw
    /// sums behind [`ServerCore::mean_loss`], so drivers can pool them
    /// into capacity-class means without losing report counts.
    pub fn loss_totals(&self) -> (&[f64], &[u64]) {
        (&self.clients.loss_sum, &self.clients.loss_n)
    }

    /// Uploads lost in transit so far.
    pub fn lost_uploads(&self) -> u64 {
        self.lost_uploads
    }

    /// Uploads lost in transit, per client — the systematic-bias signal
    /// under dropout (which clients the model stops hearing from).
    pub fn lost_per_client(&self) -> &[u64] {
        &self.clients.lost
    }

    /// Mean observed staleness across aggregations (0 before the first).
    pub fn mean_staleness(&self) -> f64 {
        if self.j > 0 {
            self.staleness_sum / self.j as f64
        } else {
            0.0
        }
    }

    /// Updates absorbed per client (fairness accounting).
    pub fn updates_per_client(&self) -> &[u64] {
        &self.clients.updates
    }

    /// Current μ_ji estimate of the staleness tracker.
    pub fn mu(&self) -> f64 {
        self.tracker.mu()
    }

    /// The aggregation policy's canonical label.
    pub fn policy_label(&self) -> String {
        self.policy.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{NaiveAlpha, StalenessEq11};
    use crate::coordinator::staleness::local_weight;
    use crate::model::{Tensor, TensorSpec};

    fn pset(vals: &[f32]) -> ParamSet {
        let spec = TensorSpec {
            name: "w".into(),
            shape: vec![vals.len()],
        };
        ParamSet {
            tensors: vec![Tensor::from_data(spec, vals.to_vec())],
        }
    }

    #[test]
    fn core_replays_the_pre_refactor_eq11_loop_bit_for_bit() {
        // The exact aggregation loop `afl.rs` ran before the refactor,
        // inlined: weight from (μ, γ, j+1, staleness), observe, lerp.
        let w0 = pset(&[1.0, -2.0, 0.5, 3.0]);
        let updates: Vec<(u64, ParamSet)> = (0..40u64)
            .map(|k| {
                let vals: Vec<f32> = (0..4u64)
                    .map(|t| ((k * 7 + t) % 13) as f32 * 0.25 - 1.0)
                    .collect();
                (k.saturating_sub(k % 5), pset(&vals))
            })
            .collect();

        let gamma = 0.2;
        let mut w = w0.clone();
        let mut tracker = StalenessTracker::new(0.1);
        let mut j = 0u64;
        let mut staleness_sum = 0.0;
        for (i, local) in &updates {
            let staleness = j.saturating_sub(*i);
            let lw = local_weight(tracker.mu(), gamma, j + 1, staleness);
            tracker.observe(staleness);
            staleness_sum += staleness as f64;
            w.lerp_inplace(local, (1.0 - lw) as f32);
            j += 1;
        }

        let mut core = ServerCore::new(
            w0,
            4,
            Box::new(StalenessEq11::new(gamma).unwrap()),
            0.1,
        );
        for (i, local) in &updates {
            core.on_update(0, *i, local, &NativeAggregator).unwrap();
        }
        assert_eq!(core.iteration(), j);
        assert_eq!(core.global().max_abs_diff(&w), 0.0, "bit-identical global");
        assert!((core.mean_staleness() - staleness_sum / j as f64).abs() < 1e-15);
    }

    #[test]
    fn issue_to_tracks_model_versions() {
        let mut core = ServerCore::new(pset(&[0.0, 0.0]), 2, Box::new(NaiveAlpha), 0.1);
        assert_eq!(core.issue_to(0), 0);
        core.on_update(0, 0, &pset(&[1.0, 1.0]), &NativeAggregator)
            .unwrap();
        assert_eq!(core.issue_to(1), 1);
        assert_eq!(core.model_version(0), 0);
        assert_eq!(core.model_version(1), 1);
        assert_eq!(core.updates_per_client(), &[1, 0]);
    }

    #[test]
    fn flat_update_path_is_bit_identical_to_tensor_path() {
        let w0 = pset(&[1.0, -2.0, 0.5, 3.0]);
        let mut a = ServerCore::new(
            w0.clone(),
            4,
            Box::new(StalenessEq11::new(0.2).unwrap()),
            0.1,
        );
        let mut b = ServerCore::new(
            w0,
            4,
            Box::new(StalenessEq11::new(0.2).unwrap()),
            0.1,
        );
        for k in 0..25u64 {
            let vals: Vec<f32> = (0..4u64)
                .map(|t| ((k * 11 + t) % 7) as f32 * 0.5 - 1.5)
                .collect();
            let local = pset(&vals);
            let client = (k % 4) as usize;
            let start = k.saturating_sub(k % 3);
            let oa = a.on_update(client, start, &local, &NativeAggregator).unwrap();
            let ob = b.on_update_flat(client, start, &vals).unwrap();
            assert_eq!(oa, ob, "k={k}");
        }
        assert_eq!(a.global().max_abs_diff(b.global()), 0.0);
        assert_eq!(a.updates_per_client(), b.updates_per_client());
    }

    #[test]
    fn submodel_update_at_rate_one_is_bit_identical_to_flat_path() {
        use crate::model::{ParamLayout, SubmodelMap};
        let w0 = pset(&[1.0, -2.0, 0.5, 3.0]);
        let map = SubmodelMap::new(&ParamLayout::of(&w0), 1.0);
        let mut a = ServerCore::new(
            w0.clone(),
            4,
            Box::new(StalenessEq11::new(0.2).unwrap()),
            0.1,
        );
        let mut b = ServerCore::new(
            w0,
            4,
            Box::new(StalenessEq11::new(0.2).unwrap()),
            0.1,
        );
        for k in 0..25u64 {
            let vals: Vec<f32> = (0..4u64)
                .map(|t| ((k * 11 + t) % 7) as f32 * 0.5 - 1.5)
                .collect();
            let client = (k % 4) as usize;
            let start = k.saturating_sub(k % 3);
            let oa = a.on_update_flat(client, start, &vals).unwrap();
            let ob = b.on_update_submodel(client, start, &vals, &map).unwrap();
            assert_eq!(oa, ob, "k={k}");
        }
        assert_eq!(a.global().max_abs_diff(b.global()), 0.0);
    }

    #[test]
    fn submodel_update_touches_only_the_covered_slice() {
        use crate::model::{ParamLayout, SubmodelMap};
        let w0 = pset(&[1.0, 1.0, 1.0, 1.0]);
        let map = SubmodelMap::new(&ParamLayout::of(&w0), 0.5);
        assert_eq!(map.numel(), 2);
        let mut core = ServerCore::new(w0, 1, Box::new(NaiveAlpha), 0.1);
        let out = core.on_update_submodel(0, 0, &[3.0, 5.0], &map).unwrap();
        assert_eq!(out.iteration, 1);
        // NaiveAlpha at 1 client: weight = 1, beta = 0 → covered slice
        // becomes the local values; the rest keeps the global.
        let got = &core.global().tensors[0].data;
        assert_eq!(got, &vec![3.0, 5.0, 1.0, 1.0]);
        assert_eq!(core.updates_per_client(), &[1]);
    }

    #[test]
    fn submodel_update_rejects_wrong_lengths() {
        use crate::model::{ParamLayout, SubmodelMap};
        let w0 = pset(&[0.0, 0.0, 0.0, 0.0]);
        let map = SubmodelMap::new(&ParamLayout::of(&w0), 0.5);
        let mut core = ServerCore::new(w0, 1, Box::new(NaiveAlpha), 0.1);
        assert!(core.on_update_submodel(0, 0, &[1.0], &map).is_err());
        let other = pset(&[0.0, 0.0]);
        let foreign = SubmodelMap::new(&ParamLayout::of(&other), 0.5);
        assert!(core.on_update_submodel(0, 0, &[1.0], &foreign).is_err());
    }

    #[test]
    fn flat_update_rejects_wrong_length() {
        let mut core = ServerCore::new(pset(&[0.0, 0.0]), 1, Box::new(NaiveAlpha), 0.1);
        assert!(core.on_update_flat(0, 0, &[1.0, 2.0, 3.0]).is_err());
    }

    #[test]
    fn loss_accounting_is_per_client_means() {
        let mut core = ServerCore::new(pset(&[0.0]), 3, Box::new(NaiveAlpha), 0.1);
        assert_eq!(core.mean_train_loss(), 0.0);
        core.record_loss(0, 2.0);
        core.record_loss(0, 4.0);
        core.record_loss(2, 1.0);
        assert_eq!(core.mean_loss(0), 3.0);
        assert_eq!(core.mean_loss(1), 0.0);
        assert_eq!(core.mean_loss(2), 1.0);
        assert!((core.mean_train_loss() - 7.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn lost_uploads_do_not_aggregate() {
        let mut core = ServerCore::new(pset(&[1.0]), 2, Box::new(NaiveAlpha), 0.1);
        core.on_lost_upload(0);
        core.on_lost_upload(0);
        core.on_lost_upload(1);
        assert_eq!(core.lost_uploads(), 3);
        assert_eq!(core.lost_per_client(), &[2, 1]);
        assert_eq!(core.iteration(), 0);
        assert_eq!(core.global().max_abs_diff(&pset(&[1.0])), 0.0);
    }
}
