//! The sans-IO federated server: one state machine shared verbatim by
//! the virtual-time simulator (`afl`, `afl_baseline`) and the TCP
//! deployment leader (`net::leader`).
//!
//! `ServerCore` owns the global model, the aggregation counter j, the
//! μ_ji staleness tracker, per-client model-version bookkeeping and
//! lost-upload statistics. It is driven entirely by explicit inputs —
//! `issue_to` when a client is handed the global model, `on_update` when
//! an upload arrives, `on_lost_upload` when one is dropped in transit —
//! and knows nothing about virtual time, sockets or event queues. The
//! aggregation *rule* is a pluggable `AggregationPolicy`; the eq.-(3)
//! tensor arithmetic is a pluggable [`ModelAggregator`] (host lerp vs
//! the PJRT Pallas kernel).

use anyhow::Result;

use super::policy::{AggregationPolicy, UpdateObservation};
use super::staleness::StalenessTracker;
use crate::model::ParamSet;

/// Executor of eq. (3) `w ← β·w + (1-β)·w_local`: how the aggregation
/// arithmetic runs, independent of which policy chose β.
pub trait ModelAggregator {
    /// Blend `local` into `global` with global-model coefficient `beta`.
    fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()>;
}

/// Host-tensor lerp — the default executor (the TCP leader uses this).
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeAggregator;

impl ModelAggregator for NativeAggregator {
    fn aggregate(&self, global: &mut ParamSet, local: &ParamSet, beta: f32) -> Result<()> {
        global.lerp_inplace(local, beta);
        Ok(())
    }
}

/// What one `ServerCore::on_update` did.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AggregationOutcome {
    /// Global iteration count after this aggregation (1-based).
    pub iteration: u64,
    /// Observed staleness j - i of the absorbed update.
    pub staleness: u64,
    /// Weight `1-β_j` the policy gave the local model.
    pub weight: f64,
    /// The f32 β actually applied to the global model.
    pub beta: f32,
}

/// The sans-IO server state machine. See the module docs for the
/// driving contract.
pub struct ServerCore {
    w: ParamSet,
    policy: Box<dyn AggregationPolicy>,
    tracker: StalenessTracker,
    j: u64,
    alpha: f64,
    model_version: Vec<u64>,
    updates_per_client: Vec<u64>,
    staleness_sum: f64,
    lost_uploads: u64,
    lost_per_client: Vec<u64>,
}

impl ServerCore {
    /// A fresh server over initial global model `w0` for `clients`
    /// clients, aggregating per `policy`, tracking μ at EMA rate
    /// `mu_rho`.
    pub fn new(
        w0: ParamSet,
        clients: usize,
        policy: Box<dyn AggregationPolicy>,
        mu_rho: f64,
    ) -> ServerCore {
        ServerCore {
            w: w0,
            policy,
            tracker: StalenessTracker::new(mu_rho),
            j: 0,
            alpha: 1.0 / clients.max(1) as f64,
            model_version: vec![0; clients],
            updates_per_client: vec![0; clients],
            staleness_sum: 0.0,
            lost_uploads: 0,
            lost_per_client: vec![0; clients],
        }
    }

    /// The current global model.
    pub fn global(&self) -> &ParamSet {
        &self.w
    }

    /// Consume the core, yielding the final global model.
    pub fn into_global(self) -> ParamSet {
        self.w
    }

    /// Global aggregations performed so far (the paper's j).
    pub fn iteration(&self) -> u64 {
        self.j
    }

    /// Record that `client` is being handed the current global model and
    /// return the iteration stamp to attach to it. The driver ships the
    /// actual parameters (snapshot, socket frame, ...).
    pub fn issue_to(&mut self, client: usize) -> u64 {
        self.model_version[client] = self.j;
        self.j
    }

    /// The iteration stamp of the model most recently issued to `client`.
    pub fn model_version(&self, client: usize) -> u64 {
        self.model_version[client]
    }

    /// Absorb an uploaded local model: ask the policy for the weight,
    /// apply eq. (3) through `agg`, advance j and all statistics.
    /// `start_iteration` is the stamp the client trained from (clients
    /// self-report it in the TCP deployment; the simulator threads it
    /// through its download events).
    pub fn on_update(
        &mut self,
        client: usize,
        start_iteration: u64,
        local: &ParamSet,
        agg: &dyn ModelAggregator,
    ) -> Result<AggregationOutcome> {
        let staleness = self.j.saturating_sub(start_iteration);
        let update_norm = if self.policy.needs_update_norm() {
            self.w.l2_distance(local)
        } else {
            0.0
        };
        let obs = UpdateObservation {
            client,
            iteration: self.j + 1,
            staleness,
            mu: self.tracker.mu(),
            alpha: self.alpha,
            update_norm,
        };
        let weight = self.policy.weight(&obs).clamp(0.0, 1.0);
        let beta = self.policy.beta(weight);
        self.tracker.observe(staleness);
        self.staleness_sum += staleness as f64;
        agg.aggregate(&mut self.w, local, beta)?;
        self.j += 1;
        self.updates_per_client[client] += 1;
        Ok(AggregationOutcome {
            iteration: self.j,
            staleness,
            weight,
            beta,
        })
    }

    /// Record an upload lost in transit (failure injection / network
    /// drop / `dropout` scenario). No aggregation happens; only the
    /// statistics advance.
    pub fn on_lost_upload(&mut self, client: usize) {
        self.lost_uploads += 1;
        self.lost_per_client[client] += 1;
    }

    /// Uploads lost in transit so far.
    pub fn lost_uploads(&self) -> u64 {
        self.lost_uploads
    }

    /// Uploads lost in transit, per client — the systematic-bias signal
    /// under dropout (which clients the model stops hearing from).
    pub fn lost_per_client(&self) -> &[u64] {
        &self.lost_per_client
    }

    /// Mean observed staleness across aggregations (0 before the first).
    pub fn mean_staleness(&self) -> f64 {
        if self.j > 0 {
            self.staleness_sum / self.j as f64
        } else {
            0.0
        }
    }

    /// Updates absorbed per client (fairness accounting).
    pub fn updates_per_client(&self) -> &[u64] {
        &self.updates_per_client
    }

    /// Current μ_ji estimate of the staleness tracker.
    pub fn mu(&self) -> f64 {
        self.tracker.mu()
    }

    /// The aggregation policy's canonical label.
    pub fn policy_label(&self) -> String {
        self.policy.label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::policy::{NaiveAlpha, StalenessEq11};
    use crate::coordinator::staleness::local_weight;
    use crate::model::{Tensor, TensorSpec};

    fn pset(vals: &[f32]) -> ParamSet {
        let spec = TensorSpec {
            name: "w".into(),
            shape: vec![vals.len()],
        };
        ParamSet {
            tensors: vec![Tensor::from_data(spec, vals.to_vec())],
        }
    }

    #[test]
    fn core_replays_the_pre_refactor_eq11_loop_bit_for_bit() {
        // The exact aggregation loop `afl.rs` ran before the refactor,
        // inlined: weight from (μ, γ, j+1, staleness), observe, lerp.
        let w0 = pset(&[1.0, -2.0, 0.5, 3.0]);
        let updates: Vec<(u64, ParamSet)> = (0..40u64)
            .map(|k| {
                let vals: Vec<f32> = (0..4u64)
                    .map(|t| ((k * 7 + t) % 13) as f32 * 0.25 - 1.0)
                    .collect();
                (k.saturating_sub(k % 5), pset(&vals))
            })
            .collect();

        let gamma = 0.2;
        let mut w = w0.clone();
        let mut tracker = StalenessTracker::new(0.1);
        let mut j = 0u64;
        let mut staleness_sum = 0.0;
        for (i, local) in &updates {
            let staleness = j.saturating_sub(*i);
            let lw = local_weight(tracker.mu(), gamma, j + 1, staleness);
            tracker.observe(staleness);
            staleness_sum += staleness as f64;
            w.lerp_inplace(local, (1.0 - lw) as f32);
            j += 1;
        }

        let mut core = ServerCore::new(
            w0,
            4,
            Box::new(StalenessEq11::new(gamma).unwrap()),
            0.1,
        );
        for (i, local) in &updates {
            core.on_update(0, *i, local, &NativeAggregator).unwrap();
        }
        assert_eq!(core.iteration(), j);
        assert_eq!(core.global().max_abs_diff(&w), 0.0, "bit-identical global");
        assert!((core.mean_staleness() - staleness_sum / j as f64).abs() < 1e-15);
    }

    #[test]
    fn issue_to_tracks_model_versions() {
        let mut core = ServerCore::new(pset(&[0.0, 0.0]), 2, Box::new(NaiveAlpha), 0.1);
        assert_eq!(core.issue_to(0), 0);
        core.on_update(0, 0, &pset(&[1.0, 1.0]), &NativeAggregator)
            .unwrap();
        assert_eq!(core.issue_to(1), 1);
        assert_eq!(core.model_version(0), 0);
        assert_eq!(core.model_version(1), 1);
        assert_eq!(core.updates_per_client(), &[1, 0]);
    }

    #[test]
    fn lost_uploads_do_not_aggregate() {
        let mut core = ServerCore::new(pset(&[1.0]), 2, Box::new(NaiveAlpha), 0.1);
        core.on_lost_upload(0);
        core.on_lost_upload(0);
        core.on_lost_upload(1);
        assert_eq!(core.lost_uploads(), 3);
        assert_eq!(core.lost_per_client(), &[2, 1]);
        assert_eq!(core.iteration(), 0);
        assert_eq!(core.global().max_abs_diff(&pset(&[1.0])), 0.0);
    }
}
