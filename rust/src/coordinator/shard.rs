//! The sharded scale coordinator (`repro sim --shards N`): the
//! multi-core engine over the exact semantics of the sequential
//! reference in `coordinator::scale`.
//!
//! ## Architecture
//!
//! One **coordinator** thread owns everything whose *order* defines the
//! run: the discrete-event queue, the scheduler and uplink channel, all
//! RNG streams, the `ServerCore` (staleness decisions + the eq.-(3)
//! lerp) and the arena's alloc/free bookkeeping. K **shard workers**
//! (`std::thread::scope`, the same idiom as the experiment engine's
//! `PlanRunner`) each own a disjoint client partition
//! ([`crate::sim::ClientPartition`]) and execute the one part of the
//! pipeline that is pure data-parallel arithmetic: the synthetic local
//! training ([`crate::coordinator::scale`]'s `synth_train`) into
//! recycled [`ParamArena`] slots.
//!
//! Per round of one client: at its Compute event the coordinator
//! allocates a slot, snapshots the live global into it, draws the
//! update offset δ from the shared stream, and ships `(slot, δ)` to the
//! client's shard worker; at the Upload event it joins on that worker's
//! completion message and feeds the slot through
//! [`crate::coordinator::ServerCore::on_update_flat`] — a single
//! ordered aggregation stage
//! whose order is the event queue's `(virtual time, insertion seq)`
//! key. Upload completions have strictly increasing virtual times (the
//! TDMA channel serializes them), so this *is* the deterministic
//! `(virtual time, client id)` aggregation order; the deployment leader
//! applies the same discipline to concurrent TCP bursts through
//! [`crate::sim::OrderedMerge`].
//!
//! ## Why `--shards N` is bit-identical to `--shards 1` and to the
//! sequential reference
//!
//! * Every decision input (RNG draw, scheduler grant, staleness stamp,
//!   policy weight) is computed on the coordinator in event order —
//!   identical to the reference loop.
//! * Worker output is a pure function of its inputs (`snapshot`, δ,
//!   pass count): the same f32 op sequence over the same values,
//!   whichever thread runs it, whenever it runs.
//! * Workers touch only disjoint slots, published and joined over
//!   channels (happens-before edges both ways); the coordinator never
//!   reads a slot before joining on its completion.
//!
//! Thread count therefore changes wall-clock only. `rust/tests/sharded.rs`
//! asserts the bit-identity (summary JSON + final global model) across
//! shard counts, schedulers, aggregation policies and random scenario
//! mixes; the `sharded` bench suite (`repro bench --suite sharded`)
//! measures the speedup instead of claiming it.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::afl::adaptive_steps;
use super::scale::{
    class_cells, grant_next, scaled_tau_up, setup, synth_train, Event, ScaleSimConfig,
    ScaleSimReport, SimSetup,
};
use super::scheduler::UploadScheduler;
use crate::model::{ParamArena, ParamSet, SlotId, SlotWindow};
use crate::net::wire::flat_update_wire_bytes;
use crate::sim::{ClientPartition, EventQueue, UplinkChannel};
use crate::telemetry::{LossCause, Telemetry};

/// One unit of shard-worker work: run the synthetic trainer over the
/// leading `len` elements of `slot` (which the coordinator has
/// pre-filled with the client's — possibly rate-scaled — snapshot of
/// the global) with offset `delta`, then report `client` done.
struct Task {
    client: u32,
    slot: u32,
    /// Elements of the slot the client trains: the full model under the
    /// trivial capacity profile, the packed submodel prefix otherwise.
    len: u32,
    delta: f32,
}

/// Run the scale simulation on `shards` shard workers plus the
/// coordinator thread. `shards` is clamped to the client count; pass 1
/// for a single worker (still pipelined). Output is bit-identical to
/// [`super::scale::run_scale_sim`] for every shard count.
pub fn run_sharded_sim(cfg: &ScaleSimConfig, shards: usize) -> Result<ScaleSimReport> {
    run_sharded_sim_full(cfg, shards).map(|(report, _)| report)
}

/// As [`run_sharded_sim`], also yielding the final global model (the
/// bit-identity witness `rust/tests/sharded.rs` compares across
/// engines).
pub fn run_sharded_sim_full(
    cfg: &ScaleSimConfig,
    shards: usize,
) -> Result<(ScaleSimReport, ParamSet)> {
    run_sharded_sim_traced(cfg, shards, &mut Telemetry::off())
}

/// As [`run_sharded_sim_full`], recording trace events and aggregates
/// into `tel`. All emission happens on the coordinator thread at the
/// same ordered decision points as the sequential reference, so the
/// trace bytes are identical to [`super::scale::run_scale_sim_traced`]
/// at every shard count (`rust/tests/sharded.rs` pins this).
pub fn run_sharded_sim_traced(
    cfg: &ScaleSimConfig,
    shards: usize,
    tel: &mut Telemetry,
) -> Result<(ScaleSimReport, ParamSet)> {
    ensure!(shards >= 1, "sim requires shards >= 1");
    let SimSetup {
        m,
        target,
        cm,
        mut jrng,
        mut urng,
        layout,
        mut core,
        policy_label,
        mut world,
        world_label,
        capacity_label,
        submodel,
        mut chan,
        channel_label,
    } = setup(cfg)?;

    let partition = ClientPartition::new(m, shards);
    let k_shards = partition.shards();

    let mut scheduler = UploadScheduler::new(cfg.scheduler, m);
    let mut channel = UplinkChannel::new();
    let mut queue: EventQueue<Event> = EventQueue::new();
    // Winner → upload duration: constant under the trivial profile,
    // scaled by the winner's submodel rate otherwise (same rule as the
    // sequential reference).
    let tau_up_of = |client: usize| match &submodel {
        None => cfg.time.tau_up,
        Some(ctx) => scaled_tau_up(cfg.time.tau_up, ctx.map_of(client).rate()),
    };
    // Upload frame size (wire-format bytes) per client — same meter as
    // the sequential reference.
    let numel_of = |client: usize| match &submodel {
        None => cfg.params,
        Some(ctx) => ctx.map_of(client).numel(),
    };
    // Per-contender gains buffer for gain-sensitive arbitration; the
    // coordinator thread owns it, like every other ordered decision
    // input, so fading cannot introduce shard-count dependence.
    let mut gains: Vec<f64> = if chan.is_trivial() {
        Vec::new()
    } else {
        vec![1.0; m]
    };
    // Every slot exists up front (at most one in-flight local per
    // client), so the backing buffer never reallocates while workers
    // hold raw views into it — the SlotWindow storage contract.
    let mut arena = ParamArena::preallocated(layout, m);
    let window: SlotWindow = arena.slot_window();
    // Pending local update per client: arena slot + start iteration.
    let mut pending: Vec<Option<(SlotId, u64)>> = vec![None; m];
    // Whether the client's dispatched training task has completed.
    let mut ready: Vec<bool> = vec![true; m];
    // Concurrency stats the reference reads off its lazily grown arena
    // (slots() == peak live there); tracked explicitly here because the
    // preallocated pool creates every slot up front.
    let mut live = 0usize;
    let mut peak_live = 0usize;

    let started = Instant::now();
    let mut events = 0u64;
    let mut bytes_on_wire = 0u64;
    let mut channel_lost = 0u64;

    let (report, model) = std::thread::scope(|scope| -> Result<(ScaleSimReport, ParamSet)> {
        let (done_tx, done_rx) = mpsc::channel::<u32>();
        let mut task_txs: Vec<mpsc::Sender<Task>> = Vec::with_capacity(k_shards);
        for _ in 0..k_shards {
            let (tx, rx) = mpsc::channel::<Task>();
            task_txs.push(tx);
            let done_tx = done_tx.clone();
            let passes = cfg.train_passes;
            scope.spawn(move || {
                for t in rx {
                    // SAFETY: the coordinator published this slot to
                    // exactly this worker and will not read or free it
                    // until our completion message below is received
                    // (see SlotWindow's exclusivity protocol).
                    let buf = unsafe { window.slot_mut(t.slot as usize) };
                    synth_train(&mut buf[..t.len as usize], t.delta, passes);
                    if done_tx.send(t.client).is_err() {
                        break;
                    }
                }
            });
        }
        // Workers hold the only clones; completions stop when they exit.
        drop(done_tx);

        // Telemetry setup mirrors the sequential reference exactly
        // (same call points before the t=0 broadcast), so traces agree
        // byte-for-byte at every shard count.
        tel.bind(m);
        if let Some(ctx) = &submodel {
            for (c, &k) in ctx.class_of.iter().enumerate() {
                tel.class_assign(c, k);
            }
        }

        // t=0 broadcast: every client is issued w_0 (stamps only — the
        // synthetic trainer reads the live global at compute time).
        for c in 0..m {
            let i = core.issue_to(c);
            queue.schedule_at(cfg.time.tau_down, Event::Download { client: c, i });
        }

        while core.iteration() < target {
            let Some((now, ev)) = queue.pop() else {
                break;
            };
            events += 1;
            match ev {
                Event::Download { client, i } => {
                    let steps = adaptive_steps(cfg.local_steps, cm.factor(client), true);
                    let mut scale = world.compute_scale(client, now);
                    if let Some(ctx) = &submodel {
                        scale *= ctx.map_of(client).rate();
                    }
                    let dur = cm.duration_scaled(&cfg.time, client, steps, &mut jrng, scale);
                    queue.schedule_in(dur, Event::Compute { client, i });
                }
                Event::Compute { client, i } => {
                    if let Some(rejoin) = world.offline_until(client, now) {
                        queue.schedule_at(rejoin, Event::Compute { client, i });
                        continue;
                    }
                    // Snapshot + dispatch: the coordinator fills the
                    // slot with the live global (it owns the only
                    // mutable view of the global), then hands the
                    // elementwise training passes to the client's
                    // shard worker.
                    let slot = arena.alloc();
                    tel.arena_alloc(now);
                    let d = 0.02 * urng.f32() - 0.01;
                    // SAFETY: freshly allocated slot; no worker holds it.
                    let buf = unsafe { window.slot_mut(slot.index()) };
                    let len = match &submodel {
                        None => {
                            core.global().copy_to_flat(buf);
                            buf.len()
                        }
                        Some(ctx) => {
                            // Capacity-constrained snapshot: only the
                            // covered slices, packed into the slot
                            // prefix (same recycled full-size slot).
                            let map = ctx.map_of(client);
                            map.extract_from_set(core.global(), &mut buf[..map.numel()]);
                            map.numel()
                        }
                    };
                    ready[client] = false;
                    task_txs[partition.shard_of(client)]
                        .send(Task {
                            client: client as u32,
                            slot: slot.index() as u32,
                            len: len as u32,
                            delta: d,
                        })
                        .map_err(|_| anyhow::anyhow!("shard worker exited early"))?;
                    core.record_loss(client, (d as f64).abs());
                    pending[client] = Some((slot, i));
                    live += 1;
                    peak_live = peak_live.max(live);
                    scheduler.request(client, now);
                    grant_next(
                        &mut scheduler,
                        &mut channel,
                        &mut chan,
                        &mut gains,
                        &mut queue,
                        now,
                        tau_up_of,
                        tel,
                    );
                }
                Event::Upload { client } => {
                    let (slot, i) = pending[client]
                        .take()
                        .expect("upload without a pending local model");
                    // Join: absorb completions (in whatever order the
                    // workers finished) until this client's local is
                    // ready. Which *other* flags get set early is
                    // timing-dependent but unobservable — no decision
                    // reads them.
                    while !ready[client] {
                        let done = done_rx
                            .recv()
                            .context("shard worker died before completing its task")?;
                        ready[done as usize] = true;
                    }
                    live -= 1;
                    // Same meter and draw order as the sequential
                    // reference: the slot was occupied either way, and
                    // both loss draws run unconditionally.
                    bytes_on_wire += flat_update_wire_bytes(numel_of(client));
                    let scenario_lost = world.upload_lost(client, now);
                    let chan_lost = chan.upload_lost(client, now);
                    if chan_lost {
                        channel_lost += 1;
                    }
                    if scenario_lost || chan_lost {
                        let cause = if scenario_lost {
                            LossCause::Scenario
                        } else {
                            LossCause::Channel
                        };
                        tel.upload_lost(now, client, cause);
                        core.on_lost_upload(client);
                        arena.free(slot);
                    } else {
                        // SAFETY: completion joined above; no worker
                        // touches this slot anymore.
                        let buf = unsafe { window.slot(slot.index()) };
                        let out = match &submodel {
                            None => core.on_update_flat(client, i, buf)?,
                            Some(ctx) => {
                                let map = ctx.map_of(client);
                                core.on_update_submodel(client, i, &buf[..map.numel()], map)?
                            }
                        };
                        tel.upload_applied(
                            now,
                            client,
                            out.iteration,
                            out.staleness,
                            out.beta,
                            out.weight,
                        );
                        arena.free(slot);
                    }
                    tel.arena_free();
                    let i = core.issue_to(client);
                    queue.schedule_in(cfg.time.tau_down, Event::Download { client, i });
                    grant_next(
                        &mut scheduler,
                        &mut channel,
                        &mut chan,
                        &mut gains,
                        &mut queue,
                        now,
                        tau_up_of,
                        tel,
                    );
                }
            }
        }

        // Dropping the task senders ends the worker loops; the scope
        // joins them (outstanding tasks for never-uploaded locals just
        // finish into slots nobody reads).
        drop(task_txs);

        let wall = started.elapsed().as_secs_f64().max(1e-9);
        let classes = match &submodel {
            None => Vec::new(),
            Some(ctx) => class_cells(
                ctx,
                core.updates_per_client(),
                core.lost_per_client(),
                core.loss_totals(),
            ),
        };
        let report = ScaleSimReport {
            clients: m,
            params: cfg.params,
            policy: policy_label,
            scheduler: cfg.scheduler.name(),
            scenario: world_label,
            capacity: capacity_label,
            classes,
            channel: channel_label,
            bytes_on_wire,
            channel_lost,
            shards: k_shards,
            aggregations: core.iteration(),
            events,
            virtual_ticks: queue.now(),
            wall_secs: wall,
            events_per_sec: events as f64 / wall,
            aggs_per_sec: core.iteration() as f64 / wall,
            mean_staleness: core.mean_staleness(),
            fairness: scheduler.jain_fairness(),
            lost_uploads: core.lost_uploads(),
            mean_train_loss: core.mean_train_loss(),
            arena_slots: peak_live,
            arena_live: live,
            final_norm: core.global().l2_norm(),
            telemetry: tel.registry_json(),
        };
        Ok((report, core.into_global()))
    })?;

    Ok((report, model))
}

#[cfg(test)]
mod tests {
    use super::super::scale::run_scale_sim_full;
    use super::*;
    use crate::coordinator::SchedulerPolicy;

    fn small_cfg() -> ScaleSimConfig {
        ScaleSimConfig {
            clients: 60,
            iterations: 150,
            params: 8,
            ..ScaleSimConfig::default()
        }
    }

    #[test]
    fn matches_the_sequential_reference_bit_for_bit() {
        let cfg = small_cfg();
        let (r_ref, w_ref) = run_scale_sim_full(&cfg).unwrap();
        for shards in [1, 2, 3, 7] {
            let (r, w) = run_sharded_sim_full(&cfg, shards).unwrap();
            assert_eq!(
                r.summary_json().to_string_compact(),
                r_ref.summary_json().to_string_compact(),
                "shards={shards}"
            );
            assert_eq!(w, w_ref, "final model, shards={shards}");
        }
    }

    #[test]
    fn shard_count_is_clamped_to_clients() {
        let cfg = ScaleSimConfig {
            clients: 3,
            iterations: 9,
            params: 4,
            ..ScaleSimConfig::default()
        };
        let r = run_sharded_sim(&cfg, 16).unwrap();
        assert_eq!(r.shards, 3);
        assert_eq!(r.aggregations, 9);
    }

    #[test]
    fn rejects_zero_shards_and_degenerate_configs() {
        assert!(run_sharded_sim(&small_cfg(), 0).is_err());
        let bad = ScaleSimConfig {
            clients: 0,
            ..ScaleSimConfig::default()
        };
        assert!(run_sharded_sim(&bad, 2).is_err());
        let bad = ScaleSimConfig {
            aggregation: Some("bogus".into()),
            ..ScaleSimConfig::default()
        };
        assert!(run_sharded_sim(&bad, 2).is_err());
    }

    #[test]
    fn multi_pass_sharded_run_still_matches_reference() {
        let cfg = ScaleSimConfig {
            train_passes: 5,
            ..small_cfg()
        };
        let (r_ref, w_ref) = run_scale_sim_full(&cfg).unwrap();
        let (r, w) = run_sharded_sim_full(&cfg, 4).unwrap();
        assert_eq!(r.summary_json().to_string_compact(), r_ref.summary_json().to_string_compact());
        assert_eq!(w, w_ref);
    }

    #[test]
    fn dropout_scenario_loses_uploads_identically_across_shards() {
        let cfg = ScaleSimConfig {
            scenario: Some("dropout:0.2".into()),
            ..small_cfg()
        };
        let a = run_sharded_sim(&cfg, 1).unwrap();
        let b = run_sharded_sim(&cfg, 3).unwrap();
        assert!(a.lost_uploads > 0, "{a:?}");
        assert_eq!(a.lost_uploads, b.lost_uploads);
        assert_eq!(a.summary_json().to_string_compact(), b.summary_json().to_string_compact());
    }

    #[test]
    fn report_carries_the_effective_shard_count() {
        let r = run_sharded_sim(&small_cfg(), 2).unwrap();
        assert_eq!(r.shards, 2);
        // Shards never appear in the deterministic summary.
        assert!(r.summary_json().get("shards").is_none());
        assert_eq!(r.to_json().get("shards").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn capacity_class_mix_matches_reference_across_shards() {
        let cfg = ScaleSimConfig {
            capacity: Some("classes:1.0x0.5,0.5x0.3,0.25x0.2".into()),
            ..small_cfg()
        };
        let (r_ref, w_ref) = run_scale_sim_full(&cfg).unwrap();
        assert_eq!(r_ref.classes.len(), 3);
        for shards in [1, 2, 4] {
            let (r, w) = run_sharded_sim_full(&cfg, shards).unwrap();
            assert_eq!(
                r.summary_json().to_string_compact(),
                r_ref.summary_json().to_string_compact(),
                "shards={shards}"
            );
            assert_eq!(w, w_ref, "final model, shards={shards}");
            assert_eq!(r.classes, r_ref.classes, "shards={shards}");
        }
    }

    #[test]
    fn scheduler_policies_run_sharded() {
        for sched in [
            SchedulerPolicy::OldestModelFirst,
            SchedulerPolicy::Fifo,
            SchedulerPolicy::RoundRobin,
            SchedulerPolicy::ChannelAware,
        ] {
            let cfg = ScaleSimConfig {
                scheduler: sched,
                ..small_cfg()
            };
            let r = run_sharded_sim(&cfg, 3).unwrap();
            assert_eq!(r.aggregations, 150, "{sched:?}");
        }
    }

    #[test]
    fn fading_channel_matches_reference_across_shards() {
        let cfg = ScaleSimConfig {
            channel: Some("markov:0.5,500".into()),
            scheduler: SchedulerPolicy::ChannelAware,
            ..small_cfg()
        };
        let (r_ref, w_ref) = run_scale_sim_full(&cfg).unwrap();
        assert!(r_ref.channel_lost > 0, "{r_ref:?}");
        assert!(r_ref.bytes_on_wire > 0, "{r_ref:?}");
        for shards in [1, 2, 4] {
            let (r, w) = run_sharded_sim_full(&cfg, shards).unwrap();
            assert_eq!(
                r.summary_json().to_string_compact(),
                r_ref.summary_json().to_string_compact(),
                "shards={shards}"
            );
            assert_eq!(w, w_ref, "final model, shards={shards}");
            assert_eq!(r.bytes_on_wire, r_ref.bytes_on_wire, "shards={shards}");
            assert_eq!(r.channel_lost, r_ref.channel_lost, "shards={shards}");
        }
    }
}
