//! The paper's coordination layer: client scheduling + model aggregation.
//!
//! The server side is a sans-IO state machine (`core::ServerCore`) with
//! two open policy seams (`policy::AggregationPolicy`,
//! `policy::SchedulingPolicy`); engines are thin drivers that feed it
//! events. Four algorithms share one harness (`runner::FlContext`):
//!
//! | Algorithm       | Section | Driver                | Aggregation policy |
//! |-----------------|---------|-----------------------|--------------------|
//! | `Sfl` (FedAvg)  | II-A    | [`sfl::run_sfl`]      | (synchronous mean) |
//! | `AflNaive`      | III-A   | [`afl::run_afl`]      | `NaiveAlpha`       |
//! | `AflBaseline`   | III-B   | [`afl_baseline`]      | `SolvedBeta`       |
//! | `Csmaafl`       | III-C   | [`afl::run_afl`]      | `StalenessEq11`    |
//!
//! Any AFL run can swap its aggregation rule via the config's
//! `aggregation` spelling (e.g. `--set aggregation=fedasync:0.5`) —
//! including the two related-work policies `FedAsyncPoly` and
//! `AdaptiveDistance` — and its *world model* via the `scenario`
//! spelling (`sim::scenario`: `static` | `dropout:p` | `churn:rate` |
//! `drift:period`). The TCP deployment leader (`net::leader`) drives
//! the same `ServerCore`, so the simulator and the deployment share one
//! aggregation code path.
//!
//! Two subsystems ship a sequential/sharded engine *pair* over one
//! semantics, bit-identical by contract (`rust/tests/sharded.rs`) and
//! differing only in wall-clock:
//!
//! | Path                        | Sequential spec     | Sharded pipeline        |
//! |-----------------------------|---------------------|-------------------------|
//! | `repro sim` (synthetic)     | [`scale`]           | [`shard`]               |
//! | `repro train` (real learner)| [`afl::run_afl`]    | [`learner_shard`]       |
//!
//! In each pair the sequential loop is the executable spec; the sharded
//! twin farms the expensive pure work (synthetic slot training /
//! [`crate::learner::Learner::train`]) to K workers while one
//! coordinator thread keeps every ordered decision in exact event
//! order. `repro train --shards N` picks the learner pair's engine via
//! [`effective_shards`].

pub mod afl;
pub mod afl_baseline;
pub mod beta_solver;
pub mod core;
pub mod learner_shard;
pub mod policy;
pub mod runner;
pub mod scale;
pub mod scheduler;
pub mod sfl;
pub mod shard;
pub mod staleness;

pub use self::core::{AggregationOutcome, ModelAggregator, NativeAggregator, ServerCore};
pub use afl::{adaptive_steps, run_afl, run_afl_full, run_afl_traced};
pub use afl_baseline::run_afl_baseline;
pub use beta_solver::{effective_coefficients, naive_effective_coefficients, solve_betas};
pub use learner_shard::{run_afl_sharded, run_afl_sharded_full, run_afl_sharded_traced};
pub use policy::{
    AdaptiveDistance, AggregationPolicy, FedAsyncPoly, NaiveAlpha, PolicyParams, SchedulingPolicy,
    SolvedBeta, StalenessEq11, UpdateObservation,
};
pub use runner::{FlContext, Recorder, RunStats};
pub use scale::{
    run_scale_sim, run_scale_sim_full, run_scale_sim_traced, CapacityClassCell, ScaleSimConfig,
    ScaleSimReport,
};
pub use scheduler::{SchedulerPolicy, UploadScheduler};
pub use shard::{run_sharded_sim, run_sharded_sim_full, run_sharded_sim_traced};
pub use staleness::{local_weight, StalenessTracker};

use anyhow::{Context, Result};

use crate::config::{Algorithm, RunConfig};
use crate::metrics::RunResult;
use crate::telemetry::Telemetry;

/// Resolve the aggregation policy (and its series label) for an AFL run:
/// the config's explicit `aggregation` spelling when set, else the
/// algorithm's paper default.
pub fn resolve_policy(cfg: &RunConfig) -> Result<(Box<dyn AggregationPolicy>, String)> {
    let params = PolicyParams {
        clients: cfg.clients,
        gamma: cfg.gamma,
    };
    match &cfg.aggregation {
        Some(spec) => {
            let policy = <dyn AggregationPolicy>::parse(spec, &params)
                .with_context(|| format!("aggregation policy {spec:?}"))?;
            let label = policy.label();
            Ok((policy, label))
        }
        None => match cfg.algorithm {
            Algorithm::AflNaive => Ok((
                Box::new(NaiveAlpha) as Box<dyn AggregationPolicy>,
                "afl-naive".to_string(),
            )),
            _ => Ok((
                Box::new(StalenessEq11::new(cfg.gamma)?) as Box<dyn AggregationPolicy>,
                format!("csmaafl g={}", cfg.gamma),
            )),
        },
    }
}

/// The learner-engine worker count a config asks for: the explicit
/// `shards` setting when present, else every available core (`auto`).
/// Bit-identity makes any answer safe; this only decides wall-clock.
pub fn effective_shards(cfg: &RunConfig) -> usize {
    match cfg.shards {
        Some(n) => n,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    }
}

/// Dispatch one run according to `ctx.cfg.algorithm`. The learner-driven
/// AFL algorithms route through the sharded engine when
/// [`effective_shards`] asks for more than one worker; the sequential
/// loop stays the single-worker production path (and the executable
/// spec the sharded engine is tested against).
pub fn run(ctx: &FlContext<'_>) -> Result<RunResult> {
    run_traced(ctx, &mut Telemetry::off())
}

/// As [`run`], recording ordered trace events and aggregate histograms
/// through `tel` for the algorithms whose engines are instrumented (the
/// learner-driven AFL pair). SFL and the baseline sweep have no
/// asynchronous decision points to trace; they run untraced.
pub fn run_traced(ctx: &FlContext<'_>, tel: &mut Telemetry) -> Result<RunResult> {
    match ctx.cfg.algorithm {
        Algorithm::Sfl => sfl::run_sfl(ctx),
        Algorithm::AflBaseline => run_afl_baseline(ctx),
        Algorithm::AflNaive | Algorithm::Csmaafl => {
            let (policy, label) = resolve_policy(ctx.cfg)?;
            let shards = effective_shards(ctx.cfg);
            if shards == 1 {
                run_afl_traced(ctx, policy, ctx.cfg.scheduler, label, tel)
                    .map(|(result, _)| result)
            } else {
                run_afl_sharded_traced(ctx, policy, ctx.cfg.scheduler, label, shards, tel)
                    .map(|(result, _)| result)
            }
        }
    }
}
