//! The paper's coordination layer: client scheduling + model aggregation.
//!
//! Four algorithms share one harness (`runner::FlContext`):
//!
//! | Algorithm       | Section | Engine                |
//! |-----------------|---------|-----------------------|
//! | `Sfl` (FedAvg)  | II-A    | [`sfl::run_sfl`]      |
//! | `AflNaive`      | III-A   | [`afl::run_afl`]      |
//! | `AflBaseline`   | III-B   | [`afl_baseline`]      |
//! | `Csmaafl`       | III-C   | [`afl::run_afl`]      |

pub mod afl;
pub mod afl_baseline;
pub mod beta_solver;
pub mod runner;
pub mod scheduler;
pub mod sfl;
pub mod staleness;

pub use afl::{adaptive_steps, run_afl, BetaPolicy};
pub use afl_baseline::run_afl_baseline;
pub use beta_solver::{effective_coefficients, naive_effective_coefficients, solve_betas};
pub use runner::{FlContext, Recorder};
pub use scheduler::{SchedulerPolicy, UploadScheduler};
pub use staleness::{local_weight, StalenessTracker};

use anyhow::Result;

use crate::config::Algorithm;
use crate::metrics::RunResult;

/// Dispatch one run according to `ctx.cfg.algorithm`.
pub fn run(ctx: &FlContext<'_>) -> Result<RunResult> {
    match ctx.cfg.algorithm {
        Algorithm::Sfl => sfl::run_sfl(ctx),
        Algorithm::AflNaive => run_afl(
            ctx,
            BetaPolicy::NaiveAlpha,
            ctx.cfg.scheduler,
            "afl-naive".into(),
        ),
        Algorithm::AflBaseline => run_afl_baseline(ctx),
        Algorithm::Csmaafl => run_afl(
            ctx,
            BetaPolicy::Staleness {
                gamma: ctx.cfg.gamma,
                rho: ctx.cfg.mu_rho,
            },
            ctx.cfg.scheduler,
            format!("csmaafl g={}", ctx.cfg.gamma),
        ),
    }
}
