//! Pure-Rust multinomial logistic regression learner.
//!
//! Same `Learner` contract as the PJRT CNN, no artifacts needed. Used by
//! the coordinator's unit/property tests and the scheduler benches, and as
//! a sanity baseline: on the synthetic datasets a linear model is weaker
//! than the CNN but still learns, so FL dynamics (convergence, staleness
//! effects) are visible at a fraction of the cost.

use anyhow::{ensure, Result};

use super::Learner;
use crate::data::Dataset;
use crate::model::{ParamSet, Tensor, TensorSpec};
use crate::util::rng::Rng;

const IMG: usize = 28 * 28;
const K: usize = 10;

/// Softmax regression: W (784x10) + b (10), SGD on NLL.
#[derive(Debug, Clone)]
pub struct LinearLearner {
    /// SGD learning rate.
    pub lr: f32,
    /// Mini-batch size per SGD step.
    pub batch: usize,
}

impl Default for LinearLearner {
    fn default() -> Self {
        LinearLearner { lr: 0.05, batch: 5 }
    }
}

impl LinearLearner {
    /// A learner with an explicit learning rate and batch size.
    pub fn new(lr: f32, batch: usize) -> Self {
        assert!(batch > 0);
        LinearLearner { lr, batch }
    }

    fn logits(p: &ParamSet, img: &[f32], out: &mut [f32]) {
        let w = &p.tensors[0].data; // row-major (784, 10)
        let b = &p.tensors[1].data;
        out.copy_from_slice(b);
        for (i, &xv) in img.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w[i * K..(i + 1) * K];
            for k in 0..K {
                out[k] += xv * row[k];
            }
        }
    }

    /// Softmax in place; returns log-sum-exp for loss computation.
    fn softmax(logits: &mut [f32]) -> f32 {
        let max = logits.iter().cloned().fold(f32::MIN, f32::max);
        let mut sum = 0.0f32;
        for v in logits.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in logits.iter_mut() {
            *v /= sum;
        }
        sum.ln() + max
    }
}

impl Learner for LinearLearner {
    fn specs(&self) -> Vec<TensorSpec> {
        vec![
            TensorSpec {
                name: "w".into(),
                shape: vec![IMG, K],
            },
            TensorSpec {
                name: "b".into(),
                shape: vec![K],
            },
        ]
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn init(&self, seed: u32) -> Result<ParamSet> {
        let mut r = Rng::new(seed as u64 ^ 0x11ea12);
        let mut w = vec![0.0f32; IMG * K];
        for v in &mut w {
            *v = 0.01 * r.normal();
        }
        Ok(ParamSet {
            tensors: vec![
                Tensor::from_data(self.specs()[0].clone(), w),
                Tensor::from_data(self.specs()[1].clone(), vec![0.0; K]),
            ],
        })
    }

    fn train(&self, p: &ParamSet, xs: &[f32], ys: &[i32], steps: usize) -> Result<(ParamSet, f32)> {
        ensure!(xs.len() == steps * self.batch * IMG, "xs size mismatch");
        ensure!(ys.len() == steps * self.batch, "ys size mismatch");
        let mut p = p.clone();
        let mut probs = [0.0f32; K];
        let mut loss_acc = 0.0f64;
        let inv_b = 1.0 / self.batch as f32;
        for s in 0..steps {
            // Accumulate gradient over the mini-batch, then apply.
            let mut gw = vec![0.0f32; IMG * K];
            let mut gb = [0.0f32; K];
            for b in 0..self.batch {
                let n = s * self.batch + b;
                let img = &xs[n * IMG..(n + 1) * IMG];
                let y = ys[n] as usize;
                Self::logits(&p, img, &mut probs);
                Self::softmax(&mut probs);
                // NLL = -ln p_y (probs hold the softmax now).
                loss_acc -= probs[y].max(1e-12).ln() as f64;
                // d(logit_k) = p_k - 1[k==y]
                let mut delta = probs;
                delta[y] -= 1.0;
                for (i, &xv) in img.iter().enumerate() {
                    if xv == 0.0 {
                        continue;
                    }
                    let row = &mut gw[i * K..(i + 1) * K];
                    for k in 0..K {
                        row[k] += xv * delta[k];
                    }
                }
                for k in 0..K {
                    gb[k] += delta[k];
                }
            }
            let w = &mut p.tensors[0].data;
            let lr = self.lr * inv_b;
            for (wv, gv) in w.iter_mut().zip(&gw) {
                *wv -= lr * gv;
            }
            let bt = &mut p.tensors[1].data;
            for k in 0..K {
                bt[k] -= lr * gb[k];
            }
        }
        let mean_loss = (loss_acc / (steps * self.batch) as f64) as f32;
        Ok((p, mean_loss))
    }

    fn evaluate(&self, p: &ParamSet, test: &Dataset) -> Result<(f64, f64)> {
        let mut probs = [0.0f32; K];
        let mut correct = 0usize;
        let mut loss = 0.0f64;
        for i in 0..test.len() {
            let img = test.image(i);
            Self::logits(p, img, &mut probs);
            Self::softmax(&mut probs);
            let y = test.y[i] as usize;
            let pred = probs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == y {
                correct += 1;
            }
            loss -= probs[y].max(1e-12).ln() as f64;
        }
        let n = test.len() as f64;
        Ok((correct as f64 / n, loss / n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, SynthKind};

    #[test]
    fn init_deterministic() {
        let l = LinearLearner::default();
        assert_eq!(l.init(3).unwrap(), l.init(3).unwrap());
        assert_ne!(l.init(3).unwrap(), l.init(4).unwrap());
    }

    #[test]
    fn learns_synthetic_mnist() {
        let l = LinearLearner::default();
        let (tr, te) = generate(SynthKind::Mnist, 300, 100, 5);
        let mut p = l.init(0).unwrap();
        let (acc0, _) = l.evaluate(&p, &te).unwrap();
        // 30 epochs of 60 steps over the whole training set.
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut cur = super::super::BatchCursor::new((0..tr.len()).collect());
        for _ in 0..10 {
            cur.fill(&tr, 60 * l.batch(), IMG, &mut xs, &mut ys);
            let (p2, loss) = l.train(&p, &xs, &ys, 60).unwrap();
            assert!(loss.is_finite());
            p = p2;
        }
        let (acc, _) = l.evaluate(&p, &te).unwrap();
        assert!(acc > acc0 + 0.3, "acc {acc0} -> {acc}");
        assert!(acc > 0.6, "final acc {acc}");
    }

    #[test]
    fn train_is_deterministic() {
        let l = LinearLearner::default();
        let (tr, _) = generate(SynthKind::Mnist, 50, 10, 6);
        let p = l.init(1).unwrap();
        let xs = tr.x[..10 * IMG].to_vec();
        let ys = tr.y[..10].to_vec();
        let (a, la) = l.train(&p, &xs, &ys, 2).unwrap();
        let (b, lb) = l.train(&p, &xs, &ys, 2).unwrap();
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn train_validates_sizes() {
        let l = LinearLearner::default();
        let p = l.init(0).unwrap();
        assert!(l.train(&p, &[0.0; 10], &[0; 5], 1).is_err());
    }

    #[test]
    fn loss_decreases_on_fixed_batch() {
        let l = LinearLearner::new(0.1, 5);
        let (tr, _) = generate(SynthKind::Mnist, 5, 5, 9);
        let xs = tr.x.clone();
        let ys = tr.y.clone();
        let mut p = l.init(2).unwrap();
        let (_, first) = l.train(&p, &xs, &ys, 1).unwrap();
        for _ in 0..50 {
            p = l.train(&p, &xs, &ys, 1).unwrap().0;
        }
        let (_, last) = l.train(&p, &xs, &ys, 1).unwrap();
        assert!(last < first * 0.5, "{first} -> {last}");
    }
}
