//! The production learner: AOT CNN artifacts executed through PJRT.
//!
//! Wraps [`Engine`], decomposing an arbitrary `steps` request into
//! scan-fused `train_chunk` dispatches plus single `train_step` calls for
//! the remainder (the chunk size is baked into the artifact at lowering).
//!
//! This type compiles in every build mode: without the `pjrt` cargo
//! feature, [`Engine`] is the uninhabited runtime stub, so a
//! `PjrtLearner` can never be constructed (its only constructor takes an
//! `Engine`) and callers fall back to [`super::LinearLearner`].

use anyhow::{ensure, Result};

use super::Learner;
use crate::data::Dataset;
use crate::model::{ParamSet, TensorSpec};
use crate::runtime::Engine;

/// [`Learner`] implementation backed by the PJRT [`Engine`].
pub struct PjrtLearner {
    engine: Engine,
}

impl PjrtLearner {
    /// Wrap a compiled engine.
    pub fn new(engine: Engine) -> Self {
        PjrtLearner { engine }
    }

    /// The underlying engine (for direct artifact dispatch, e.g. the
    /// PJRT aggregator ablation).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    fn img(&self) -> usize {
        self.engine.model().image_numel()
    }
}

impl Learner for PjrtLearner {
    fn specs(&self) -> Vec<TensorSpec> {
        self.engine.model().params.clone()
    }

    fn batch(&self) -> usize {
        self.engine.model().batch
    }

    fn init(&self, seed: u32) -> Result<ParamSet> {
        self.engine.init(seed)
    }

    fn train(&self, p: &ParamSet, xs: &[f32], ys: &[i32], steps: usize) -> Result<(ParamSet, f32)> {
        let m = self.engine.model();
        let (batch, chunk, img) = (m.batch, m.chunk_steps, self.img());
        ensure!(xs.len() == steps * batch * img, "xs size mismatch");
        ensure!(ys.len() == steps * batch, "ys size mismatch");
        let mut params = p.clone();
        let mut loss_acc = 0.0f64;
        let mut steps_done = 0usize;
        // Fused chunks first (one PJRT dispatch per `chunk` steps)…
        while steps - steps_done >= chunk {
            let xs_c = &xs[steps_done * batch * img..(steps_done + chunk) * batch * img];
            let ys_c = &ys[steps_done * batch..(steps_done + chunk) * batch];
            let (p2, loss) = self.engine.train_chunk(&params, xs_c, ys_c)?;
            params = p2;
            loss_acc += loss as f64 * chunk as f64;
            steps_done += chunk;
        }
        // …then single steps for the remainder.
        while steps_done < steps {
            let xs_s = &xs[steps_done * batch * img..(steps_done + 1) * batch * img];
            let ys_s = &ys[steps_done * batch..(steps_done + 1) * batch];
            let (p2, loss) = self.engine.train_step(&params, xs_s, ys_s)?;
            params = p2;
            loss_acc += loss as f64;
            steps_done += 1;
        }
        let mean = if steps > 0 {
            (loss_acc / steps as f64) as f32
        } else {
            0.0
        };
        Ok((params, mean))
    }

    fn evaluate(&self, p: &ParamSet, test: &Dataset) -> Result<(f64, f64)> {
        self.engine.evaluate_set(p, &test.x, &test.y)
    }
}
