//! The local-learning abstraction the coordinator drives.
//!
//! `Learner` hides *what* model is trained: the production implementation
//! (`PjrtLearner`) executes the AOT CNN artifacts through PJRT; the
//! pure-Rust `LinearLearner` (multinomial logistic regression) exercises
//! identical coordinator logic orders of magnitude faster, for unit /
//! property tests and scheduler benches. Both are deterministic.

mod linear;
mod pjrt;

pub use linear::LinearLearner;
pub use pjrt::PjrtLearner;

use anyhow::Result;

use crate::data::Dataset;
use crate::model::{ParamSet, TensorSpec};

/// A batch-oriented local trainer + evaluator.
///
/// `Sync` is a supertrait because the sharded learner engine
/// (`coordinator::learner_shard`) calls [`Learner::train`] concurrently
/// from K shard workers through one `&dyn Learner` while the
/// coordinator thread runs [`Learner::evaluate`] — every method already
/// takes `&self` and both implementations are stateless between calls.
pub trait Learner: Sync {
    /// Ordered parameter tensor specs (the manifest contract).
    fn specs(&self) -> Vec<TensorSpec>;

    /// Mini-batch size of one SGD step.
    fn batch(&self) -> usize;

    /// Deterministic parameter initialization.
    fn init(&self, seed: u32) -> Result<ParamSet>;

    /// Run `steps` SGD steps. `xs` holds `steps*batch` flattened images,
    /// `ys` the matching labels. Returns updated params + mean loss.
    fn train(&self, p: &ParamSet, xs: &[f32], ys: &[i32], steps: usize) -> Result<(ParamSet, f32)>;

    /// Full test-set evaluation: (accuracy, mean loss).
    fn evaluate(&self, p: &ParamSet, test: &Dataset) -> Result<(f64, f64)>;
}

/// Cyclic batch assembler: builds the (steps*batch) training slab for a
/// client shard, advancing a persistent cursor so successive local rounds
/// walk the shard like an epoch iterator.
#[derive(Debug, Clone)]
pub struct BatchCursor {
    /// The shard's sample indices, walked cyclically.
    pub indices: Vec<usize>,
    pos: usize,
}

impl BatchCursor {
    /// A cursor over a (non-empty) shard, starting at its first sample.
    pub fn new(indices: Vec<usize>) -> Self {
        assert!(!indices.is_empty(), "empty shard");
        BatchCursor { indices, pos: 0 }
    }

    /// Fill `xs`/`ys` with the next `count` samples (wrapping).
    pub fn fill(&mut self, ds: &Dataset, count: usize, img: usize, xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        xs.clear();
        ys.clear();
        xs.reserve(count * img);
        ys.reserve(count);
        for _ in 0..count {
            let idx = self.indices[self.pos];
            xs.extend_from_slice(&ds.x[idx * img..(idx + 1) * img]);
            ys.push(ds.y[idx]);
            self.pos = (self.pos + 1) % self.indices.len();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate, SynthKind};

    #[test]
    fn cursor_wraps_and_is_exhaustive() {
        let (ds, _) = generate(SynthKind::Mnist, 10, 10, 1);
        let mut cur = BatchCursor::new((0..10).collect());
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        cur.fill(&ds, 25, 784, &mut xs, &mut ys);
        assert_eq!(ys.len(), 25);
        assert_eq!(xs.len(), 25 * 784);
        // First 10 labels = the shard in order; then it wraps.
        assert_eq!(&ys[..10], &ds.y[..10]);
        assert_eq!(&ys[10..20], &ds.y[..10]);
    }

    #[test]
    #[should_panic]
    fn cursor_rejects_empty() {
        BatchCursor::new(vec![]);
    }
}
