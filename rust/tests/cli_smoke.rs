//! Smoke coverage for the hand-rolled `repro` argument parser: every
//! subcommand's usage/help/error path, plus the artifact-free analytic
//! subcommands end-to-end. The only federated runs here are the tiny
//! `repro grid` happy paths (2 clients, 1 slot) — heavier dynamics live
//! in `learning_dynamics.rs` — so the suite stays fast.

use std::path::PathBuf;
use std::process::{Command, Output};

/// Run the built `repro` binary with `args` in a scratch directory.
fn repro(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .current_dir(std::env::temp_dir())
        .output()
        .expect("spawning repro")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("csmaafl_cli_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ------------------------------------------------------------- usage

#[test]
fn no_arguments_prints_usage_and_succeeds() {
    let out = repro(&[]);
    assert!(out.status.success());
    let text = stdout(&out);
    assert!(text.contains("USAGE"), "{text}");
    assert!(text.contains("repro <COMMAND>"), "{text}");
}

#[test]
fn help_flag_prints_usage_for_every_command_position() {
    for args in [&["--help"][..], &["-h"][..], &["train", "--help"][..]] {
        let out = repro(args);
        assert!(out.status.success(), "{args:?}");
        assert!(stdout(&out).contains("COMMANDS"), "{args:?}");
    }
}

#[test]
fn help_subcommand_prints_usage() {
    let out = repro(&["help"]);
    assert!(out.status.success());
    assert!(stdout(&out).contains("COMMON OPTIONS"));
}

#[test]
fn usage_lists_every_dispatchable_command() {
    let usage = stdout(&repro(&[]));
    for cmd in [
        "train", "compare", "figures", "sweep", "grid", "analyze",
        "timeline", "inspect", "smoke", "sim", "trace", "bench", "serve", "join",
    ] {
        assert!(usage.contains(cmd), "usage must mention {cmd}");
    }
}

// ------------------------------------------------------------ errors

#[test]
fn unknown_command_fails_with_usage() {
    let out = repro(&["frobnicate"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("unknown command"), "{err}");
    assert!(err.contains("USAGE"), "{err}");
}

#[test]
fn option_missing_value_is_rejected() {
    let out = repro(&["train", "--config"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("expects a value"), "{}", stderr(&out));
}

#[test]
fn malformed_set_override_is_rejected() {
    let out = repro(&["train", "--set", "gamma"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("key=value"), "{}", stderr(&out));
}

#[test]
fn unknown_config_key_is_rejected() {
    let out = repro(&["train", "--set", "not_a_knob=1", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("not_a_knob"), "{}", stderr(&out));
}

#[test]
fn invalid_config_value_is_rejected() {
    let out = repro(&["train", "--set", "clients=banana", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("banana"), "{}", stderr(&out));
}

#[test]
fn sweep_invalid_value_is_an_error_not_a_panic() {
    // A bad --values entry used to hit `.expect("invalid sweep value")`;
    // it must surface as a named error through the Result chain.
    let out = repro(&[
        "sweep", "--param", "gamma", "--values", "banana", "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "max_slots=1",
    ]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("banana"), "{err}");
    assert!(err.contains("gamma"), "{err}");
    assert!(!err.contains("panicked"), "{err}");
}

#[test]
fn unknown_aggregation_policy_is_rejected() {
    let out = repro(&["train", "--set", "aggregation=bogus", "--learner", "linear"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("bogus"), "{err}");
}

#[test]
fn usage_lists_aggregation_policy_registry() {
    let usage = stdout(&repro(&[]));
    assert!(usage.contains("AGGREGATION POLICIES"), "{usage}");
    for name in ["naive", "solved", "staleness", "fedasync", "adaptive"] {
        assert!(usage.contains(name), "usage must mention {name}");
    }
}

#[test]
fn usage_lists_scenario_registry() {
    let usage = stdout(&repro(&[]));
    assert!(usage.contains("SCENARIOS"), "{usage}");
    for name in ["static", "dropout", "churn", "drift"] {
        assert!(usage.contains(name), "usage must mention {name}");
    }
}

#[test]
fn unknown_scenario_is_rejected() {
    let out = repro(&["train", "--set", "scenario=blizzard", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("blizzard"), "{}", stderr(&out));
}

#[test]
fn usage_lists_channel_model_registry() {
    let usage = stdout(&repro(&[]));
    for spelling in ["CHANNEL MODELS", "ideal", "markov"] {
        assert!(usage.contains(spelling), "usage must mention {spelling}");
    }
}

#[test]
fn unknown_or_misplaced_channel_is_rejected() {
    let out = repro(&["train", "--set", "channel=tropo", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("tropo"), "{}", stderr(&out));
    // The synchronous baselines assume an ideal channel; a fading model
    // on them is a config error, not a silently ignored knob.
    let out = repro(&[
        "train", "--set", "algorithm=fedavg", "--set", "channel=markov:0.5,500",
        "--learner", "linear",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("ideal channel"), "{}", stderr(&out));
}

#[test]
fn usage_lists_capacity_profile_registry() {
    let usage = stdout(&repro(&[]));
    assert!(usage.contains("CAPACITY PROFILES"), "{usage}");
    for name in ["full", "uniform:rate", "classes:r1xf1"] {
        assert!(usage.contains(name), "usage must mention {name}");
    }
}

#[test]
fn malformed_capacity_fails_before_any_data_generation() {
    // Validation runs in RunConfig::validate(), ahead of dataset synth
    // and training — the error must name the bad spelling.
    let out = repro(&["train", "--set", "capacity=bogus", "--learner", "linear"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("bogus"), "{err}");
    assert!(err.contains("unknown capacity profile"), "{err}");
    // Out-of-range rates are named too.
    let out = repro(&["train", "--set", "capacity=uniform:0", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("(0,1]"), "{}", stderr(&out));
    // Submodels need an engine that can train them: the sync baseline
    // trains full models, so a non-trivial profile is a config error.
    let out = repro(&[
        "train", "--set", "algorithm=sfl",
        "--set", "capacity=classes:1.0x0.5,0.5x0.5", "--learner", "linear",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("capacity profiles apply only"), "{}", stderr(&out));
}

#[test]
fn grid_with_capacity_mix_emits_per_class_run_fields() {
    let dir = scratch_dir("grid_capacity");
    let out = repro(&[
        "grid", "--learner", "linear", "--format", "json",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "local_steps=1",
        "--set", "max_slots=1",
        "--axis", "capacity=full;classes:1.0x0.5,0.5x0.5",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    // The heterogeneous cell carries per-class roll-ups; the trivial
    // cell must not even have the key.
    assert!(json.contains("\"classes\""), "{json}");
    assert!(json.contains("\"r0.5\""), "{json}");
    let record = csmaafl::util::json::parse(&json).unwrap();
    let jobs = match record.get("jobs").unwrap() {
        csmaafl::util::json::Json::Array(jobs) => jobs.clone(),
        other => panic!("jobs is not an array: {other:?}"),
    };
    assert_eq!(jobs.len(), 2);
    assert!(jobs[0].get("summary").unwrap().get("classes").is_none());
    assert!(jobs[1].get("summary").unwrap().get("classes").is_some());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_rejects_malformed_axis() {
    let out = repro(&["grid", "--axis", "gamma", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("key=v1,v2"), "{}", stderr(&out));
}

#[test]
fn grid_rejects_conflicting_axis_and_set() {
    let out = repro(&[
        "grid", "--set", "gamma=0.1", "--axis", "gamma=0.2,0.4", "--learner", "linear",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("conflicts"), "{}", stderr(&out));
}

#[test]
fn grid_rejects_unknown_format() {
    let out = repro(&[
        "grid", "--axis", "gamma=0.1,0.2", "--format", "xml", "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "max_slots=1",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("xml"), "{}", stderr(&out));
}

#[test]
fn jobs_flag_rejects_non_integers() {
    let out = repro(&[
        "sweep", "--jobs", "many", "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "max_slots=1",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--jobs"), "{}", stderr(&out));
}

#[test]
fn unknown_learner_is_rejected() {
    let out = repro(&["train", "--learner", "quantum"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown learner"), "{}", stderr(&out));
}

#[test]
fn missing_config_file_is_reported_with_path() {
    let out = repro(&["train", "--config", "definitely_missing_cfg.json"]);
    assert!(!out.status.success());
    assert!(
        stderr(&out).contains("definitely_missing_cfg.json"),
        "{}",
        stderr(&out)
    );
}

#[test]
fn inspect_rejects_unknown_target() {
    let out = repro(&["inspect", "nonsense"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("unknown inspect target"), "{}", stderr(&out));
}

#[test]
fn analyze_without_records_says_run_figures_first() {
    let dir = scratch_dir("analyze");
    let out = repro(&["analyze", "--results", dir.to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("repro figures"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn smoke_without_artifacts_mentions_make_artifacts() {
    let dir = scratch_dir("smoke");
    let out = repro(&["smoke", "--artifacts", dir.to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("make artifacts"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

// ----------------------------------------------- analytic happy paths

#[test]
fn inspect_naive_decay_emits_csv_table() {
    let out = repro(&["inspect", "naive-decay", "--clients", "8"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.starts_with("schedule_position,effective_coefficient"), "{text}");
    // Header + one row per schedule position.
    assert_eq!(text.lines().count(), 9, "{text}");
}

#[test]
fn inspect_betas_emits_solved_coefficients() {
    let out = repro(&["inspect", "betas", "--clients", "5"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("schedule_position,beta"), "{text}");
    assert_eq!(text.lines().count(), 6, "{text}");
    // β_1 = 0: the first aggregation of a sweep discards the old global.
    assert!(text.lines().nth(1).unwrap().starts_with("1,0.0"), "{text}");
}

#[test]
fn timeline_writes_fig2_csv() {
    let dir = scratch_dir("timeline");
    let out = repro(&[
        "timeline",
        "--clients",
        "20",
        "--local-steps",
        "16",
        "--out",
        dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let csv = std::fs::read_to_string(dir.join("fig2_timeline.csv")).unwrap();
    // The Sec. II-C analytic values for the default time model.
    assert!(csv.contains("sfl,homogeneous,round_time,2210"), "{csv}");
    assert!(csv.contains("afl,any,update_interval,150"), "{csv}");
    // The command also echoes the table to stdout.
    assert!(stdout(&out).contains("update_interval"), "{}", stdout(&out));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_runs_a_tiny_matrix_end_to_end() {
    let dir = scratch_dir("grid");
    let out = repro(&[
        "grid", "--learner", "linear", "--jobs", "2",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "local_steps=1",
        "--set", "max_slots=1",
        "--axis", "gamma=0.2,0.4",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("gamma=0.2"), "{text}");
    assert!(text.contains("wrote"), "{text}");
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    assert!(json.contains("\"gamma\""), "{json}");
    assert!(json.contains("gamma=0.4"), "{json}");
    assert!(!json.contains("wallclock"), "matrix must be deterministic");
    let csv = std::fs::read_to_string(dir.join("grid.csv")).unwrap();
    assert!(csv.starts_with("series,slot"), "{csv}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_semicolon_axis_separator_allows_comma_parameterized_values() {
    let dir = scratch_dir("grid_semi");
    let out = repro(&[
        "grid", "--learner", "linear", "--jobs", "2",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "local_steps=1",
        "--set", "max_slots=1",
        "--axis", "scenario=static;churn:0.3,2",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    assert!(json.contains("churn:0.3,2"), "{json}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_treats_repeated_set_keys_as_axes() {
    let dir = scratch_dir("grid_sets");
    let out = repro(&[
        "grid", "--learner", "linear", "--format", "json",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "local_steps=1",
        "--set", "max_slots=1",
        "--set", "gamma=0.2", "--set", "gamma=0.4",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("\"spec\": \"gamma=0.2\""), "{text}");
    assert!(text.contains("\"spec\": \"gamma=0.4\""), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------------ sim/bench

#[test]
fn sim_rejects_bad_flags() {
    let out = repro(&["sim", "--clients", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("clients"), "{}", stderr(&out));
    let out = repro(&["sim", "--format", "xml"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("xml"), "{}", stderr(&out));
    let out = repro(&["sim", "--scheduler", "lottery"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("lottery"), "{}", stderr(&out));
    let out = repro(&["sim", "--heterogeneity", "warp9"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("warp9"), "{}", stderr(&out));
    let out = repro(&["sim", "--clients", "10", "--aggregation", "bogus"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("bogus"), "{}", stderr(&out));
}

#[test]
fn sim_runs_a_tiny_simulation_to_json() {
    let out = repro(&[
        "sim", "--clients", "50", "--iterations", "100", "--params", "8",
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("\"aggregations\": 100"), "{text}");
    assert!(text.contains("\"clients\": 50"), "{text}");
    assert!(text.contains("\"events_per_sec\""), "{text}");
    assert!(text.contains("\"arena_slots\""), "{text}");
}

#[test]
fn sim_prints_a_table_by_default() {
    let out = repro(&["sim", "--clients", "20", "--iterations", "10", "--params", "4"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("scale sim: 20 clients"), "{text}");
    assert!(text.contains("aggregations"), "{text}");
}

#[test]
fn sim_rejects_zero_or_malformed_shards() {
    let out = repro(&["sim", "--clients", "10", "--shards", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--shards"), "{}", stderr(&out));
    let out = repro(&["sim", "--clients", "10", "--shards", "many"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--shards"), "{}", stderr(&out));
}

#[test]
fn sim_shards_only_change_wall_clock_fields() {
    let run = |shards: &str| {
        let out = repro(&[
            "sim", "--clients", "60", "--iterations", "120", "--params", "8",
            "--shards", shards, "--format", "json",
        ]);
        assert!(out.status.success(), "{}", stderr(&out));
        csmaafl::util::json::parse(&stdout(&out)).unwrap()
    };
    let strip = |j: &csmaafl::util::json::Json| {
        let mut o = j.as_object().unwrap().clone();
        for k in ["shards", "wall_secs", "events_per_sec", "aggs_per_sec"] {
            o.remove(k);
        }
        o
    };
    let a = run("1");
    let b = run("3");
    assert_eq!(a.get("shards").unwrap().as_i64(), Some(1));
    assert_eq!(b.get("shards").unwrap().as_i64(), Some(3));
    assert_eq!(strip(&a), strip(&b), "non-wall-clock fields must be bit-identical");
}

#[test]
fn sim_default_shards_is_available_parallelism() {
    // Clients far above any plausible core count, so the partition
    // clamp cannot mask the default.
    let out = repro(&[
        "sim", "--clients", "4096", "--iterations", "64", "--params", "4",
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let j = csmaafl::util::json::parse(&stdout(&out)).unwrap();
    let expect = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1) as i64;
    assert_eq!(j.get("shards").unwrap().as_i64(), Some(expect));
}

#[test]
fn sim_scenario_override_changes_lost_uploads() {
    let run = |extra: &[&str]| {
        let mut args = vec![
            "sim", "--clients", "5000", "--iterations", "5000", "--params", "8",
            "--format", "json",
        ];
        args.extend_from_slice(extra);
        let out = repro(&args);
        assert!(out.status.success(), "{}", stderr(&out));
        let j = csmaafl::util::json::parse(&stdout(&out)).unwrap();
        j.get("lost_uploads").unwrap().as_i64().unwrap()
    };
    assert_eq!(run(&[]), 0, "static world loses nothing");
    assert!(
        run(&["--set", "scenario=dropout:0.1"]) > 0,
        "dropout must surface in lost_uploads"
    );
}

#[test]
fn sim_rejects_unknown_set_keys_and_scenarios() {
    let out = repro(&["sim", "--clients", "10", "--set", "gamma=0.3"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("scenario"), "{}", stderr(&out));
    let out = repro(&["sim", "--clients", "10", "--set", "scenario=blizzard"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("blizzard"), "{}", stderr(&out));
    let out = repro(&["sim", "--clients", "10", "--train-passes", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("train_passes"), "{}", stderr(&out));
}

#[test]
fn sim_capacity_flag_surfaces_per_class_cells_in_json() {
    let out = repro(&[
        "sim", "--clients", "200", "--iterations", "300", "--params", "8",
        "--capacity", "classes:1.0x0.5,0.5x0.3,0.25x0.2",
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    // The echoed spelling is the canonical spec() form (1.0 prints as 1).
    assert!(
        text.contains("\"capacity\": \"classes:1x0.5,0.5x0.3,0.25x0.2\""),
        "{text}"
    );
    for label in ["\"r1\"", "\"r0.5\"", "\"r0.25\""] {
        assert!(text.contains(label), "{text}");
    }
    // --set spells the same knob; the trivial profile stays silent —
    // no capacity/classes keys at all, so the record is byte-identical
    // to a pre-submodel run.
    let out = repro(&[
        "sim", "--clients", "50", "--iterations", "60", "--params", "4",
        "--set", "capacity=uniform:1.0", "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(!text.contains("\"capacity\""), "{text}");
    assert!(!text.contains("\"classes\""), "{text}");
}

#[test]
fn sim_rejects_malformed_capacity() {
    for bad in [
        "capacity=bogus",
        "capacity=uniform:2.0",
        "capacity=classes:1.0x0.5,2.0x0.5",
    ] {
        let out = repro(&["sim", "--clients", "10", "--set", bad]);
        assert!(!out.status.success(), "{bad} must fail");
        assert!(stderr(&out).contains("capacity"), "{bad}: {}", stderr(&out));
    }
    let out = repro(&["sim", "--clients", "10", "--capacity", "bogus"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("bogus"), "{}", stderr(&out));
}

#[test]
fn sim_channel_flag_surfaces_wire_metrics_in_json() {
    let out = repro(&[
        "sim", "--clients", "100", "--iterations", "300", "--params", "8",
        "--channel", "markov:0.5,500", "--scheduler", "channel-aware",
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("\"channel\": \"markov:0.5,500\""), "{text}");
    assert!(text.contains("\"bytes_on_wire\""), "{text}");
    assert!(text.contains("\"scheduler\": \"channel-aware\""), "{text}");
    // --set spells the same knob; the trivial spelling reports itself
    // as ideal with the meter still running (full records always carry
    // channel provenance — only the *summary* keeps quiet).
    let out = repro(&[
        "sim", "--clients", "50", "--iterations", "60", "--params", "4",
        "--set", "channel=ideal", "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("\"channel\": \"ideal\""), "{text}");
}

#[test]
fn sim_rejects_malformed_channel() {
    for bad in ["channel=tropo", "channel=markov:1.5", "channel=markov:0.5,0"] {
        let out = repro(&["sim", "--clients", "10", "--set", bad]);
        assert!(!out.status.success(), "{bad} must fail");
        assert!(stderr(&out).contains("channel"), "{bad}: {}", stderr(&out));
    }
    let out = repro(&["sim", "--clients", "10", "--channel", "tropo"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("tropo"), "{}", stderr(&out));
}

#[test]
fn grid_sim_sweeps_shards_with_identical_summaries() {
    let dir = scratch_dir("grid_sim");
    let out = repro(&[
        "grid", "--sim", "--format", "json",
        "--set", "clients=200", "--set", "iterations=150", "--set", "params=8",
        "--axis", "shards=1,2",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    let record = csmaafl::util::json::parse(&json).unwrap();
    let jobs = match record.get("jobs").unwrap() {
        csmaafl::util::json::Json::Array(jobs) => jobs.clone(),
        other => panic!("jobs is not an array: {other:?}"),
    };
    assert_eq!(jobs.len(), 2);
    assert_eq!(jobs[0].get("spec").unwrap().as_str(), Some("shards=1"));
    assert_eq!(jobs[1].get("spec").unwrap().as_str(), Some("shards=2"));
    // A shards axis sweeps hardware parallelism only: the deterministic
    // summaries of every cell must be byte-identical.
    assert_eq!(
        jobs[0].get("summary").unwrap().to_string_compact(),
        jobs[1].get("summary").unwrap().to_string_compact()
    );
    assert!(!json.contains("wall_secs"), "matrix must be deterministic");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_sim_channel_axis_differentiates_summaries() {
    let dir = scratch_dir("grid_channel");
    let out = repro(&[
        "grid", "--sim", "--format", "json",
        "--set", "clients=100", "--set", "iterations=200", "--set", "params=8",
        "--axis", "channel=ideal;markov:0.5,500",
        "--out", dir.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("grid.json")).unwrap();
    let record = csmaafl::util::json::parse(&json).unwrap();
    let jobs = match record.get("jobs").unwrap() {
        csmaafl::util::json::Json::Array(jobs) => jobs.clone(),
        other => panic!("jobs is not an array: {other:?}"),
    };
    assert_eq!(jobs.len(), 2);
    assert_eq!(jobs[0].get("spec").unwrap().as_str(), Some("channel=ideal"));
    assert_eq!(
        jobs[1].get("spec").unwrap().as_str(),
        Some("channel=markov:0.5,500")
    );
    // The ideal cell's summary stays silent (byte-identical to a
    // pre-channel record); the fading cell surfaces the wire meter and
    // genuinely different dynamics.
    let ideal = jobs[0].get("summary").unwrap().to_string_compact();
    let faded = jobs[1].get("summary").unwrap().to_string_compact();
    assert!(!ideal.contains("bytes_on_wire"), "{ideal}");
    assert!(faded.contains("bytes_on_wire"), "{faded}");
    assert_ne!(ideal, faded, "fading must differentiate the series");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn grid_sim_validates_cells_before_running_any() {
    let out = repro(&[
        "grid", "--sim",
        "--set", "clients=100000000",
        "--axis", "scheduler=oldest;lottery",
    ]);
    assert!(!out.status.success());
    // The bad cell fails fast — long before the absurd base config
    // could ever have been simulated.
    assert!(stderr(&out).contains("lottery"), "{}", stderr(&out));
    // Registry spellings stored unparsed by set_field (aggregation,
    // scenario) are still validated per cell up front.
    let out = repro(&[
        "grid", "--sim",
        "--set", "clients=100000000",
        "--axis", "aggregation=staleness:0.3;bogus",
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("bogus"), "{}", stderr(&out));
    let out = repro(&["grid", "--sim", "--set", "clients=20"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--axis"), "{}", stderr(&out));
}

#[test]
fn bench_rejects_zero_shards() {
    let out = repro(&["bench", "--quick", "--suite", "aggregation", "--shards", "0"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--shards"), "{}", stderr(&out));
}

#[test]
fn bench_rejects_bad_flags() {
    let out = repro(&["bench", "--format", "xml"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("xml"), "{}", stderr(&out));
    let out = repro(&["bench", "--quick", "--suite", "bogus"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("bogus"), "{}", stderr(&out));
    let out = repro(&["bench", "--factor", "abc"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("--factor"), "{}", stderr(&out));
}

#[test]
fn bench_channel_suite_emits_fading_and_delta_cases() {
    let dir = scratch_dir("bench_channel");
    let out = repro(&[
        "bench", "--quick", "--suite", "channel", "--out", dir.to_str().unwrap(),
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    for case in [
        "gain_walk_10000", "delta_encode_5370", "delta_apply_5370",
        "delta_encode_431080", "delta_apply_431080", "sim_channel_aware_2000",
    ] {
        assert!(text.contains(case), "missing {case}: {text}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn bench_check_reports_missing_baseline_path() {
    let out = repro(&[
        "bench", "--quick", "--suite", "aggregation",
        "--check", "definitely_missing_baseline.json",
    ]);
    assert!(!out.status.success());
    assert!(
        stderr(&out).contains("definitely_missing_baseline.json"),
        "{}",
        stderr(&out)
    );
}

#[test]
fn bench_writes_schema_valid_record_and_checks_against_baseline() {
    let dir = scratch_dir("bench");
    let out_flag = dir.to_str().unwrap();
    let out = repro(&[
        "bench", "--quick", "--suite", "aggregation", "--out", out_flag,
        "--format", "json",
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = stdout(&out);
    assert!(text.contains("\"schema\": \"csmaafl-bench-v1\""), "{text}");
    assert!(text.contains("lerp_5370"), "{text}");
    assert!(text.contains("\"ns_per_iter\""), "{text}");
    // The record landed as BENCH_<date>.json in --out.
    let records: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        .collect();
    assert_eq!(records.len(), 1, "{records:?}");

    // --check exercises the comparison logic, not the timings: a huge
    // baseline passes, an impossibly small one fails with "regressed".
    let case = r#"{"iters": 1, "ns_per_iter": NS, "clients": 0}"#;
    let rec = |ns: &str| {
        format!(
            r#"{{"schema": "csmaafl-bench-v1", "suites": {{"aggregation": {{"lerp_5370": {}}}}}}}"#,
            case.replace("NS", ns)
        )
    };
    std::fs::write(dir.join("generous.json"), rec("1e15")).unwrap();
    let out = repro(&[
        "bench", "--quick", "--suite", "aggregation", "--out", out_flag,
        "--check", dir.join("generous.json").to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    // Status lines live on stderr so --format json stdout stays pure.
    assert!(stderr(&out).contains("bench check"), "{}", stderr(&out));

    std::fs::write(dir.join("impossible.json"), rec("0.0001")).unwrap();
    let out = repro(&[
        "bench", "--quick", "--suite", "aggregation", "--out", out_flag,
        "--check", dir.join("impossible.json").to_str().unwrap(),
    ]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("regressed"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------------------------------------------- trace

#[test]
fn trace_subcommand_replays_a_recorded_sim() {
    let dir = scratch_dir("trace");
    let path = dir.join("run.jsonl");
    let out = repro(&[
        "sim", "--clients", "50", "--iterations", "200", "--params", "8",
        "--set", "scenario=dropout:0.1",
        "--trace", path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "{}", stderr(&out));
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(!text.is_empty(), "trace file must not be empty");
    assert!(text.lines().all(|l| l.starts_with("{\"ev\":\"")), "{text}");

    // The reader renders the aggregate report...
    let out = repro(&["trace", path.to_str().unwrap()]);
    assert!(out.status.success(), "{}", stderr(&out));
    let table = stdout(&out);
    assert!(table.contains("staleness"), "{table}");
    assert!(table.contains("jain"), "{table}");
    assert!(table.contains("uploads"), "{table}");
    // ...and --check validates without rendering.
    let out = repro(&["trace", path.to_str().unwrap(), "--check"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert!(stdout(&out).contains("trace ok"), "{}", stdout(&out));

    // A malformed line is rejected with its line number.
    std::fs::write(dir.join("bad.jsonl"), "{\"ev\":\"warp\"}\n").unwrap();
    let out = repro(&["trace", dir.join("bad.jsonl").to_str().unwrap()]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("line 1"), "{}", stderr(&out));
    // A missing file names its path; a missing path is a usage error.
    let out = repro(&["trace", "definitely_missing_trace.jsonl"]);
    assert!(!out.status.success());
    assert!(
        stderr(&out).contains("definitely_missing_trace.jsonl"),
        "{}",
        stderr(&out)
    );
    let out = repro(&["trace"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("usage"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------------------------ train --shards

#[test]
fn train_rejects_zero_or_malformed_shards_before_data_generation() {
    // `--shards` is validated in apply_train_shards, ahead of
    // Session::new (dataset synthesis) and the output directory — a bad
    // value must leave the scratch directory untouched.
    let dir = scratch_dir("train_shards_bad");
    for bad in ["0", "many", "-2"] {
        let out = repro(&[
            "train", "--shards", bad, "--out", dir.to_str().unwrap(),
            "--learner", "linear",
            "--set", "clients=2", "--set", "samples_per_client=4",
            "--set", "test_samples=10", "--set", "local_steps=1",
            "--set", "max_slots=1",
        ]);
        assert!(!out.status.success(), "--shards {bad} must fail");
        assert!(stderr(&out).contains("--shards"), "{bad}: {}", stderr(&out));
        assert_eq!(
            std::fs::read_dir(&dir).unwrap().count(),
            0,
            "--shards {bad} must fail before anything is written"
        );
    }
    // The config spelling is validated the same way.
    let out = repro(&["train", "--set", "shards=0", "--learner", "linear"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("shards"), "{}", stderr(&out));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_surfaces_the_shard_count_in_the_run_json() {
    let dir = scratch_dir("train_shards_json");
    let base_args = [
        "--out", dir.to_str().unwrap(), "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "local_steps=1",
        "--set", "max_slots=1",
    ];
    // Explicit --shards lands verbatim in the full record.
    let mut args = vec!["train", "--shards", "2", "--label", "explicit"];
    args.extend_from_slice(&base_args);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("explicit.json")).unwrap();
    let j = csmaafl::util::json::parse(&json).unwrap();
    assert_eq!(j.get("shards").unwrap().as_i64(), Some(2));

    // The default (`auto` = all cores, clamped to the client count) is
    // surfaced too, never silent.
    let mut args = vec!["train", "--label", "auto"];
    args.extend_from_slice(&base_args);
    let out = repro(&args);
    assert!(out.status.success(), "{}", stderr(&out));
    let json = std::fs::read_to_string(dir.join("auto.json")).unwrap();
    let j = csmaafl::util::json::parse(&json).unwrap();
    let shards = j.get("shards").unwrap().as_i64().unwrap();
    assert!((1..=2).contains(&shards), "auto clamps to [1, clients]: {shards}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn jobs_times_shards_oversubscription_is_rejected_with_both_flags_named() {
    // An absurd product can never fit any machine; the error must name
    // both knobs so the fix is obvious, and fire before data generation.
    let dir = scratch_dir("oversub");
    let out = repro(&[
        "compare", "--jobs", "2", "--shards", "1000000",
        "--out", dir.to_str().unwrap(),
        "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10", "--set", "max_slots=1",
    ]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("--jobs"), "{err}");
    assert!(err.contains("--shards"), "{err}");
    assert!(err.contains("oversubscribes"), "{err}");
    assert_eq!(std::fs::read_dir(&dir).unwrap().count(), 0, "{err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn usage_mentions_train_shards_flag() {
    let usage = stdout(&repro(&[]));
    assert!(usage.contains("--shards"), "{usage}");
}

#[test]
fn verbosity_flags_are_accepted() {
    // -q / -v must parse (they mutate global logger state, not config).
    let out = repro(&["-q", "inspect", "betas", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
    let out = repro(&["-v", "inspect", "naive-decay", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
}

#[test]
fn log_level_flag_is_accepted_and_validated() {
    let out = repro(&["--log-level", "debug", "inspect", "betas", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
    // A bad spelling is rejected with the flag and the value named.
    let out = repro(&["--log-level", "chatty", "inspect", "betas", "--clients", "3"]);
    assert!(!out.status.success());
    let err = stderr(&out);
    assert!(err.contains("--log-level"), "{err}");
    assert!(err.contains("chatty"), "{err}");
}

#[test]
fn repro_log_env_is_a_validated_fallback() {
    let with_env = |val: &str, args: &[&str]| {
        Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(args)
            .env("REPRO_LOG", val)
            .current_dir(std::env::temp_dir())
            .output()
            .expect("spawning repro")
    };
    // A valid spelling is honoured silently.
    let out = with_env("warn", &["inspect", "betas", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
    // A bad spelling is an error that names its source...
    let out = with_env("chatty", &["inspect", "betas", "--clients", "3"]);
    assert!(!out.status.success());
    assert!(stderr(&out).contains("REPRO_LOG"), "{}", stderr(&out));
    // ...unless an explicit -q/-v already chose the verbosity, in which
    // case the fallback (bad value included) is ignored entirely.
    let out = with_env("chatty", &["-q", "inspect", "betas", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
    // --log-level beats the env even when both are valid.
    let out = with_env("trace", &["--log-level", "error", "inspect", "betas", "--clients", "3"]);
    assert!(out.status.success(), "{}", stderr(&out));
}

#[test]
fn repeated_options_last_one_wins() {
    let out = repro(&["inspect", "betas", "--clients", "3", "--clients", "4"]);
    assert!(out.status.success(), "{}", stderr(&out));
    assert_eq!(stdout(&out).lines().count(), 5, "{}", stdout(&out));
}

// -------------------------------------------------------- serve / join

/// A tiny synthetic dataset so serve/join invocations stay fast.
const TINY_DATA: &[&str] = &[
    "--learner", "linear",
    "--set", "clients=2",
    "--set", "samples_per_client=4",
    "--set", "test_samples=10",
];

fn serve_err(extra: &[&str]) -> String {
    let mut args = vec!["serve"];
    args.extend_from_slice(extra);
    args.extend_from_slice(TINY_DATA);
    let out = repro(&args);
    assert!(!out.status.success(), "serve {extra:?} must fail");
    stderr(&out)
}

#[test]
fn serve_rejects_bad_net_flags() {
    for (extra, needle) in [
        (&["--net-shards", "0"][..], "--net-shards"),
        (&["--net-shards", "many"][..], "--net-shards"),
        (&["--net-queue", "0"][..], "--net-queue"),
        (&["--net-queue", "deep"][..], "--net-queue"),
        (&["--net-timeout-ms", "soon"][..], "--net-timeout-ms"),
        (&["--net-rejoin-ms", "later"][..], "--net-rejoin-ms"),
        (&["--format", "xml"][..], "xml"),
    ] {
        let err = serve_err(extra);
        assert!(err.contains(needle), "serve {extra:?}: {err}");
    }
}

#[test]
fn join_rejects_bad_fault_flags() {
    for (extra, needle) in [
        (&["--faults", "explode=0.1"][..], "explode"),
        (&["--faults", "drop=1.5"][..], "outside"),
        (&["--faults", "drop"][..], "key=value"),
        (&["--faults", "churn=0.1x0"][..], "churn rounds"),
        (&["--faults", "drop=0.1", "--fault-seed", "abc"][..], "--fault-seed"),
        (&["--worker-id", "5", "--workers", "4"][..], "worker-id"),
    ] {
        let mut args = vec!["join"];
        args.extend_from_slice(extra);
        args.extend_from_slice(TINY_DATA);
        let out = repro(&args);
        assert!(!out.status.success(), "join {extra:?} must fail");
        assert!(stderr(&out).contains(needle), "join {extra:?}: {}", stderr(&out));
    }
}

#[test]
fn serve_and_join_reject_channel_models_before_data_generation() {
    // Deployment runs over real links: a simulated fading channel in
    // the config must be rejected up front, like every other net knob —
    // long before Session::new generates any data.
    let err = serve_err(&["--set", "channel=markov:0.5,500"]);
    assert!(err.contains("real links"), "{err}");
    let mut args = vec!["join", "--set", "channel=markov:0.5,500"];
    args.extend_from_slice(TINY_DATA);
    let out = repro(&args);
    assert!(!out.status.success(), "join with a channel model must fail");
    assert!(stderr(&out).contains("real links"), "{}", stderr(&out));
}

#[test]
fn usage_mentions_net_deployment_flags() {
    let usage = stdout(&repro(&[]));
    for flag in [
        "--net-shards", "--net-timeout-ms", "--net-queue", "--net-rejoin-ms", "--lockstep",
        "--faults", "--fault-seed", "--reconnect-ms", "--connect-attempts", "--delta",
    ] {
        assert!(usage.contains(flag), "usage must mention {flag}");
    }
}

/// One real (tiny) serve+join federation: the run JSON surfaces every
/// net knob at its effective value — defaults included, the way `sim`
/// surfaces `shards`.
#[test]
fn serve_run_json_surfaces_net_knob_defaults() {
    use std::process::Stdio;
    let bind = "127.0.0.1:47931";
    let serve = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(["serve", "--bind", bind, "--clients", "1", "--iterations", "2"])
        .args(["--format", "json"])
        .args(TINY_DATA)
        .current_dir(std::env::temp_dir())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawning repro serve");
    let join = repro(&[
        "join", "--connect", bind, "--workers", "1", "--worker-id", "0",
        "--local-steps", "1", "--connect-attempts", "300",
        "--learner", "linear",
        "--set", "clients=2", "--set", "samples_per_client=4",
        "--set", "test_samples=10",
    ]);
    assert!(join.status.success(), "{}", stderr(&join));
    let out = serve.wait_with_output().expect("waiting for serve");
    assert!(out.status.success(), "{}", stderr(&out));
    let j = csmaafl::util::json::parse(&stdout(&out)).unwrap();
    assert_eq!(j.get("schema").unwrap().as_str(), Some("csmaafl-serve-v1"));
    let cfg = j.get("config").unwrap();
    let expect_shards = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1) as i64;
    assert_eq!(cfg.get("net_shards").unwrap().as_i64(), Some(expect_shards));
    assert_eq!(cfg.get("net_timeout_ms").unwrap().as_i64(), Some(5000));
    assert_eq!(cfg.get("net_queue").unwrap().as_i64(), Some(1024));
    assert_eq!(cfg.get("net_rejoin_ms").unwrap().as_i64(), Some(30000));
    assert_eq!(cfg.get("lockstep").unwrap().as_bool(), Some(false));
    let summary = j.get("summary").unwrap();
    assert_eq!(summary.get("aggregations").unwrap().as_i64(), Some(2));
    let digest = summary.get("model_digest").unwrap().as_str().unwrap();
    assert_eq!(digest.len(), 16, "digest is a 16-hex-digit string: {digest}");
    assert!(digest.chars().all(|c| c.is_ascii_hexdigit()), "{digest}");
}
