//! Integration: the paper's qualitative learning claims, on the fast
//! linear learner (the CNN path is covered by `pjrt_integration.rs`).

use csmaafl::config::{Algorithm, RunConfig};
use csmaafl::data::Partition;
use csmaafl::session::{LearnerKind, Session};
use csmaafl::sim::HeterogeneityProfile;

fn base_cfg() -> RunConfig {
    RunConfig {
        clients: 12,
        samples_per_client: 50,
        test_samples: 300,
        local_steps: 20,
        max_slots: 20.0,
        ..RunConfig::default()
    }
}

/// Both FedAvg and CSMAAFL must actually learn the synthetic task.
#[test]
fn both_algorithms_learn() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    for alg in [Algorithm::Sfl, Algorithm::Csmaafl] {
        let run = session.run_with(|c| c.algorithm = alg).unwrap();
        let first = run.points.first().unwrap().accuracy;
        let final_ = run.final_accuracy();
        assert!(
            final_ > first + 0.3 && final_ > 0.5,
            "{alg:?}: {first:.3} -> {final_:.3}"
        );
    }
}

/// The headline claim: CSMAAFL accelerates the EARLY stage — accuracy in
/// the first few relative slots beats FedAvg's, while the final levels
/// are comparable.
#[test]
fn csmaafl_accelerates_early_stage() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    let fedavg = session.run_with(|c| c.algorithm = Algorithm::Sfl).unwrap();
    let csma = session
        .run_with(|c| c.algorithm = Algorithm::Csmaafl)
        .unwrap();
    // Early advantage: mean accuracy over slots 1..5.
    let early = |r: &csmaafl::RunResult| {
        r.points
            .iter()
            .filter(|p| p.slot >= 1.0 && p.slot <= 5.0)
            .map(|p| p.accuracy)
            .sum::<f64>()
            / 5.0
    };
    assert!(
        early(&csma) > early(&fedavg) + 0.05,
        "early csma {:.3} vs fedavg {:.3}",
        early(&csma),
        early(&fedavg)
    );
    // Comparable end point.
    assert!(
        csma.final_accuracy() > fedavg.final_accuracy() - 0.12,
        "final csma {:.3} vs fedavg {:.3}",
        csma.final_accuracy(),
        fedavg.final_accuracy()
    );
}

/// Non-IID is harder than IID for both algorithms (classic FL behaviour
/// the paper's scenarios 2/4 rest on).
#[test]
fn noniid_is_harder() {
    let mut cfg = base_cfg();
    cfg.max_slots = 10.0;
    let iid = Session::new(cfg.clone(), LearnerKind::Linear, "artifacts").unwrap();
    cfg.partition = Partition::TwoClass;
    let non = Session::new(cfg, LearnerKind::Linear, "artifacts").unwrap();
    let acc_iid = iid
        .run_with(|c| c.algorithm = Algorithm::Csmaafl)
        .unwrap()
        .final_accuracy();
    let acc_non = non
        .run_with(|c| c.algorithm = Algorithm::Csmaafl)
        .unwrap()
        .final_accuracy();
    assert!(
        acc_non < acc_iid + 0.02,
        "non-IID {acc_non:.3} should not beat IID {acc_iid:.3}"
    );
}

/// γ sensitivity (Sec. IV discussion): γ scales down every client
/// contribution, so an over-large γ freezes the global model near its
/// initialization while a tuned γ learns. (The paper's opposite failure
/// mode — γ=0.1 collapsing to random guessing — is a non-convex CNN
/// effect; it is exercised by the figure harness on the PJRT path.)
#[test]
fn gamma_sensitivity_ordering() {
    let mut cfg = base_cfg();
    cfg.partition = Partition::TwoClass; // γ effects are starkest non-IID
    let session = Session::new(cfg, LearnerKind::Linear, "artifacts").unwrap();
    let acc = |gamma: f64| {
        session
            .run_with(|c| {
                c.algorithm = Algorithm::Csmaafl;
                c.gamma = gamma;
            })
            .unwrap()
            .final_accuracy()
    };
    let tuned = acc(0.4);
    let frozen = acc(200.0); // contributions ~1/(200·j): model barely moves
    assert!(
        tuned > frozen + 0.2,
        "tuned gamma {tuned:.3} must beat frozen gamma {frozen:.3}"
    );
    assert!(frozen < 0.45, "over-large gamma should stay near init: {frozen:.3}");
}

/// Naive AFL (Sec. III-A) underperforms CSMAAFL: the diminishing
/// coefficients waste the early updates.
#[test]
fn naive_afl_underperforms_csmaafl() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    let naive = session
        .run_with(|c| c.algorithm = Algorithm::AflNaive)
        .unwrap();
    let csma = session
        .run_with(|c| c.algorithm = Algorithm::Csmaafl)
        .unwrap();
    // Compare the early phase, where naive's tiny (1-β)=α throttles
    // progress while CSMAAFL takes full updates.
    let at5 = |r: &csmaafl::RunResult| {
        r.points
            .iter()
            .find(|p| p.slot >= 5.0)
            .map(|p| p.accuracy)
            .unwrap_or(0.0)
    };
    assert!(
        at5(&csma) > at5(&naive),
        "csma@5 {:.3} vs naive@5 {:.3}",
        at5(&csma),
        at5(&naive)
    );
}

/// Fairness under extreme heterogeneity: adaptive local iterations keep
/// Jain's index high.
#[test]
fn adaptive_iters_improve_fairness() {
    let mut cfg = base_cfg();
    cfg.max_slots = 12.0;
    cfg.heterogeneity = HeterogeneityProfile::Extreme {
        fast_frac: 0.25,
        slow_frac: 0.25,
        mid_factor: 2.0,
        slow_factor: 10.0,
    };
    let session = Session::new(cfg, LearnerKind::Linear, "artifacts").unwrap();
    let on = session.run_with(|c| c.adaptive_iters = true).unwrap();
    let off = session.run_with(|c| c.adaptive_iters = false).unwrap();
    assert!(
        on.fairness >= off.fairness - 1e-9,
        "fairness on {:.3} vs off {:.3}",
        on.fairness,
        off.fairness
    );
    // Slowest clients upload materially more often with the policy on.
    let slow_uploads_on: u64 = on.uploads_per_client.iter().rev().take(3).sum();
    let slow_uploads_off: u64 = off.uploads_per_client.iter().rev().take(3).sum();
    assert!(
        slow_uploads_on > slow_uploads_off,
        "straggler uploads: on {slow_uploads_on} vs off {slow_uploads_off}"
    );
}

/// Failure injection: with a lossy uplink the server keeps making
/// progress — fewer aggregations, but the model still learns and the run
/// completes cleanly.
#[test]
fn survives_lossy_uplink() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    let reliable = session.run_with(|c| c.upload_loss = 0.0).unwrap();
    let lossy = session.run_with(|c| c.upload_loss = 0.3).unwrap();
    assert!(lossy.aggregations > 0);
    assert!(
        lossy.aggregations < reliable.aggregations,
        "losses must reduce delivered aggregations: {} vs {}",
        lossy.aggregations,
        reliable.aggregations
    );
    assert!(
        lossy.final_accuracy() > 0.5,
        "still learns under 30% loss: {:.3}",
        lossy.final_accuracy()
    );
    assert!(lossy.points.iter().all(|p| p.accuracy.is_finite()));
    // The drop count is now a first-class result field, not just a log
    // line: reliable runs report 0, lossy runs report every loss.
    assert_eq!(reliable.lost_uploads, 0);
    assert!(lossy.lost_uploads > 0, "30% loss must drop some uploads");
}

/// Client-sampling FedAvg ([2]): sampling K<M shortens rounds but still
/// learns; full participation remains the accuracy reference.
#[test]
fn sampled_fedavg_learns_with_shorter_rounds() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    let full = session.run_with(|c| c.algorithm = Algorithm::Sfl).unwrap();
    let sampled = session
        .run_with(|c| {
            c.algorithm = Algorithm::Sfl;
            c.sfl_sample_fraction = 0.25;
        })
        .unwrap();
    // Same virtual horizon, but sampled rounds are shorter (K·τ^u term),
    // so more rounds fit.
    assert!(
        sampled.aggregations > full.aggregations,
        "sampled {} vs full {}",
        sampled.aggregations,
        full.aggregations
    );
    assert!(sampled.final_accuracy() > 0.5, "{}", sampled.final_accuracy());
}

/// Determinism: identical configs give bit-identical curves.
#[test]
fn runs_are_reproducible() {
    let session = Session::new(base_cfg(), LearnerKind::Linear, "artifacts").unwrap();
    let a = session.run().unwrap();
    let b = session.run().unwrap();
    assert_eq!(a.aggregations, b.aggregations);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.accuracy, pb.accuracy);
        assert_eq!(pa.loss, pb.loss);
    }
}
