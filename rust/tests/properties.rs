//! Property-style test sweeps over coordinator invariants (the
//! dependency-minimal build has no proptest; these are seeded
//! random-input sweeps with the same intent — every case runs hundreds
//! of random instances).

use csmaafl::coordinator::scheduler::{SchedulerPolicy, UploadScheduler};
use csmaafl::coordinator::staleness::{local_weight, StalenessTracker};
use csmaafl::coordinator::{
    run_scale_sim, NativeAggregator, ScaleSimConfig, ServerCore, StalenessEq11,
};
use csmaafl::model::{
    axpy_flat, axpy_flat_scalar, finalize_overlap_mean, lerp_flat, lerp_flat_par,
    lerp_flat_scalar, ParamArena, ParamLayout, ParamSet, SubmodelMap, Tensor, TensorSpec,
    KERNEL_CHUNK,
};
use csmaafl::sim::EventQueue;
use csmaafl::util::json::{self, Json};
use csmaafl::util::rng::Rng;

// ---------------------------------------------------------------- sched

/// No starvation: under arbitrary request patterns, every filed request
/// is eventually granted once the request stream stops.
#[test]
fn scheduler_no_starvation() {
    for seed in 0..100u64 {
        let mut r = Rng::new(seed);
        let m = 2 + r.below(20) as usize;
        for policy in [SchedulerPolicy::OldestModelFirst, SchedulerPolicy::Fifo] {
            let mut s = UploadScheduler::new(policy, m);
            let mut outstanding = vec![false; m];
            let mut filed = 0u64;
            let mut granted = 0u64;
            for t in 0..500u64 {
                let c = r.below(m as u64) as usize;
                if !outstanding[c] {
                    s.request(c, t);
                    outstanding[c] = true;
                    filed += 1;
                }
                if r.below(3) == 0 {
                    if let Some(w) = s.grant() {
                        outstanding[w] = false;
                        granted += 1;
                    }
                }
            }
            while let Some(w) = s.grant() {
                outstanding[w] = false;
                granted += 1;
            }
            assert_eq!(filed, granted, "seed {seed} policy {policy:?}");
            assert!(outstanding.iter().all(|o| !o));
        }
    }
}

/// Grant conservation: slots_granted equals the sum of per-client grants,
/// and Jain fairness stays in (0, 1].
#[test]
fn scheduler_accounting_invariants() {
    for seed in 0..100u64 {
        let mut r = Rng::new(seed * 7 + 1);
        let m = 1 + r.below(30) as usize;
        let mut s = UploadScheduler::new(SchedulerPolicy::OldestModelFirst, m);
        let mut outstanding = vec![false; m];
        for t in 0..300u64 {
            let c = r.below(m as u64) as usize;
            if !outstanding[c] {
                s.request(c, t);
                outstanding[c] = true;
            }
            if r.below(2) == 0 {
                if let Some(w) = s.grant() {
                    outstanding[w] = false;
                }
            }
        }
        let total: u64 = s.grants().iter().sum();
        assert_eq!(total, s.slots_granted());
        let j = s.jain_fairness();
        assert!(j > 0.0 && j <= 1.0 + 1e-12, "jain {j}");
    }
}

/// The O(log n) heap / O(1) cursor fast paths pick exactly the winners
/// the O(n) reference scan (the same policy as a trait object) picks,
/// under arbitrary request/grant interleavings.
#[test]
fn scheduler_fast_paths_match_reference_scan() {
    for seed in 0..60u64 {
        let mut r = Rng::new(seed * 31 + 3);
        let m = 2 + r.below(40) as usize;
        for policy in [
            SchedulerPolicy::OldestModelFirst,
            SchedulerPolicy::Fifo,
            SchedulerPolicy::RoundRobin,
        ] {
            let mut fast = UploadScheduler::new(policy, m);
            let mut scan = UploadScheduler::with_policy(policy, policy.build(), m);
            let mut outstanding = vec![false; m];
            for t in 0..400u64 {
                let c = r.below(m as u64) as usize;
                if !outstanding[c] {
                    fast.request(c, t);
                    scan.request(c, t);
                    outstanding[c] = true;
                }
                if r.below(3) == 0 {
                    let a = fast.grant();
                    let b = scan.grant();
                    assert_eq!(a, b, "seed {seed} policy {policy:?} t {t}");
                    if let Some(w) = a {
                        outstanding[w] = false;
                    }
                }
            }
            loop {
                let a = fast.grant();
                assert_eq!(a, scan.grant(), "seed {seed} policy {policy:?} drain");
                match a {
                    Some(w) => outstanding[w] = false,
                    None => break,
                }
            }
            assert_eq!(fast.grants(), scan.grants(), "seed {seed} {policy:?}");
            assert_eq!(fast.slots_granted(), scan.slots_granted());
            assert_eq!(fast.pending_len(), scan.pending_len());
        }
    }
}

/// Round-robin serves clients in strict cyclic order.
#[test]
fn round_robin_cyclic_order() {
    for seed in 0..50u64 {
        let mut r = Rng::new(seed + 1000);
        let m = 2 + r.below(10) as usize;
        let mut s = UploadScheduler::new(SchedulerPolicy::RoundRobin, m);
        for c in 0..m {
            s.request(c, r.below(100));
        }
        let mut order = Vec::new();
        while let Some(w) = s.grant() {
            order.push(w);
        }
        assert_eq!(order, (0..m).collect::<Vec<_>>(), "seed {seed}");
    }
}

// ------------------------------------------------------------- staleness

/// eq. (11) weight is monotone: non-increasing in j, s, γ; non-decreasing
/// in μ. Checked over random parameter draws.
#[test]
fn staleness_weight_monotonicity() {
    let mut r = Rng::new(77);
    for _ in 0..500 {
        let mu = 0.5 + 50.0 * r.f64();
        let gamma = 0.05 + r.f64();
        let j = 1 + r.below(5000);
        let s = 1 + r.below(200);
        let w = local_weight(mu, gamma, j, s);
        assert!((0.0..=1.0).contains(&w));
        assert!(local_weight(mu, gamma, j + 1 + r.below(100), s) <= w + 1e-12);
        assert!(local_weight(mu, gamma, j, s + 1 + r.below(100)) <= w + 1e-12);
        assert!(local_weight(mu, gamma * (1.0 + r.f64()), j, s) <= w + 1e-12);
        assert!(local_weight(mu * (1.0 + r.f64()), gamma, j, s) + 1e-12 >= w);
    }
}

/// The μ tracker stays within the observed range (after seeding).
#[test]
fn staleness_tracker_bounded_by_observations() {
    for seed in 0..50u64 {
        let mut r = Rng::new(seed * 3 + 5);
        let rho = 0.05 + 0.9 * r.f64();
        let mut t = StalenessTracker::new(rho);
        let mut lo = f64::MAX;
        let mut hi: f64 = 1.0; // observe() floors staleness at 1
        for _ in 0..200 {
            let s = r.below(100);
            lo = lo.min((s as f64).max(1.0));
            hi = hi.max(s as f64);
            t.observe(s);
            assert!(
                t.mu() >= lo - 1e-9 && t.mu() <= hi + 1e-9,
                "mu {} outside [{lo}, {hi}]",
                t.mu()
            );
        }
    }
}

// ------------------------------------------------------------ aggregation

fn random_pset(r: &mut Rng, tensors: usize, max_len: usize) -> ParamSet {
    ParamSet {
        tensors: (0..tensors)
            .map(|i| {
                let n = 1 + r.below(max_len as u64) as usize;
                Tensor::from_data(
                    TensorSpec {
                        name: format!("t{i}"),
                        shape: vec![n],
                    },
                    (0..n).map(|_| r.normal()).collect(),
                )
            })
            .collect(),
    }
}

/// lerp is a convex combination: every element stays inside the
/// elementwise interval, endpoints are exact.
#[test]
fn lerp_convexity_property() {
    let mut r = Rng::new(13);
    for _ in 0..200 {
        let g = random_pset(&mut r, 3, 50);
        let l = {
            // Same shapes, fresh values.
            let mut l = g.clone();
            for t in &mut l.tensors {
                for v in &mut t.data {
                    *v = r.normal();
                }
            }
            l
        };
        let beta = r.f32();
        let mut out = g.clone();
        out.lerp_inplace(&l, beta);
        for ((to, tg), tl) in out.tensors.iter().zip(&g.tensors).zip(&l.tensors) {
            for ((o, gg), ll) in to.data.iter().zip(&tg.data).zip(&tl.data) {
                let (lo, hi) = (gg.min(*ll), gg.max(*ll));
                assert!(*o >= lo - 1e-5 && *o <= hi + 1e-5);
            }
        }
        let mut id = g.clone();
        id.lerp_inplace(&l, 1.0);
        assert_eq!(id, g);
        let mut rep = g.clone();
        rep.lerp_inplace(&l, 0.0);
        assert_eq!(rep, l);
    }
}

/// A sequential solved-β sweep equals the weighted sum for random scalars
/// — the algebra behind Sec. III-B, fuzzed at the ParamSet level.
#[test]
fn sweep_equals_weighted_sum_paramsets() {
    let mut r = Rng::new(29);
    for _ in 0..100 {
        let m = 2 + r.below(12) as usize;
        let raw: Vec<f64> = (0..m).map(|_| 0.05 + r.f64()).collect();
        let s: f64 = raw.iter().sum();
        let alpha: Vec<f64> = raw.into_iter().map(|v| v / s).collect();
        let betas = csmaafl::coordinator::solve_betas(&alpha).unwrap();
        let locals: Vec<ParamSet> = (0..m).map(|_| random_pset(&mut r, 1, 8)).collect();
        // All must share one shape for aggregation; rebuild with shape of 0.
        let spec = locals[0].specs();
        let locals: Vec<ParamSet> = (0..m)
            .map(|_| {
                let mut p = ParamSet::zeros(&spec);
                for t in &mut p.tensors {
                    for v in &mut t.data {
                        *v = r.normal();
                    }
                }
                p
            })
            .collect();
        let mut fedavg = ParamSet::zeros(&spec);
        for (a, l) in alpha.iter().zip(&locals) {
            fedavg.axpy_inplace(l, *a as f32);
        }
        let mut w = random_pset(&mut r, 1, 8);
        w = {
            let mut p = ParamSet::zeros(&spec);
            for t in &mut p.tensors {
                for v in &mut t.data {
                    *v = r.normal() * 10.0;
                }
            }
            p
        };
        for (t, l) in locals.iter().enumerate() {
            w.lerp_inplace(l, betas[t] as f32);
        }
        let diff = w.max_abs_diff(&fedavg);
        assert!(diff < 1e-4, "diff {diff}");
    }
}

/// The tentpole equivalence: in-place aggregation — both the tensor
/// path (`on_update` + native lerp) and the arena/flat path
/// (`on_update_flat` over recycled slots) — is bit-for-bit identical to
/// the clone-based allocate-and-replace reference across random
/// staleness patterns and policy weights.
#[test]
fn inplace_aggregation_equals_clone_based_aggregation_bitwise() {
    for seed in 0..30u64 {
        let mut r = Rng::new(seed * 13 + 7);
        let tensors = 1 + r.below(4) as usize;
        let g0 = random_pset(&mut r, tensors, 40);
        let specs = g0.specs();
        let numel = g0.numel();
        let gamma = 0.1 + r.f64();

        let mut core_tensor = ServerCore::new(
            g0.clone(),
            8,
            Box::new(StalenessEq11::new(gamma).unwrap()),
            0.1,
        );
        let mut core_flat = ServerCore::new(
            g0.clone(),
            8,
            Box::new(StalenessEq11::new(gamma).unwrap()),
            0.1,
        );
        // Clone-based reference: a fresh parameter set is allocated per
        // update and swapped in (the pre-arena arithmetic, spelled out).
        let mut w_ref = g0.clone();
        let mut tracker = StalenessTracker::new(0.1);
        let mut j = 0u64;
        let mut arena = ParamArena::new(ParamLayout::of(&g0));
        let mut flat = vec![0.0f32; numel];

        for _ in 0..40 {
            let mut local = g0.clone();
            for t in &mut local.tensors {
                for v in &mut t.data {
                    *v = r.normal();
                }
            }
            local.copy_to_flat(&mut flat);
            let start = j.saturating_sub(r.below(6));
            let staleness = j - start;

            let lw = local_weight(tracker.mu(), gamma, j + 1, staleness);
            tracker.observe(staleness);
            let beta = (1.0 - lw) as f32;
            let mut fresh = ParamSet::zeros(&specs);
            for ((ft, wt), lt) in fresh
                .tensors
                .iter_mut()
                .zip(&w_ref.tensors)
                .zip(&local.tensors)
            {
                for ((o, x), y) in ft.data.iter_mut().zip(&wt.data).zip(&lt.data) {
                    *o = beta * *x + (1.0 - beta) * *y;
                }
            }
            w_ref = fresh;
            j += 1;

            let client = (j % 8) as usize;
            core_tensor
                .on_update(client, start, &local, &NativeAggregator)
                .unwrap();
            let slot = arena.alloc();
            arena.get_mut(slot).copy_from_slice(&flat);
            core_flat.on_update_flat(client, start, arena.get(slot)).unwrap();
            arena.free(slot);
        }
        assert_eq!(
            core_tensor.global().max_abs_diff(&w_ref),
            0.0,
            "seed {seed}: tensor path != clone reference"
        );
        assert_eq!(
            core_flat.global().max_abs_diff(&w_ref),
            0.0,
            "seed {seed}: arena path != clone reference"
        );
        assert_eq!(core_tensor.iteration(), j);
        assert_eq!(core_flat.iteration(), j);
        assert_eq!(arena.live(), 0, "every slot recycled");
        assert_eq!(arena.slots(), 1, "steady state reuses one slot");
    }
}

// -------------------------------------------------------------- kernels
//
// Differential harness for the flat-kernel variants in `model::params`.
// The retained straight-line loops (`lerp_flat_scalar`, `axpy_flat_scalar`)
// are the executable reference; every other variant — the chunked
// autovectorization-friendly dispatchers, the feature-gated SSE2 path
// (this same file compiled under `--features simd` exercises it, since
// the dispatcher IS the SSE2 path there), and the scoped-thread parallel
// lerp — must match it bit for bit. Lengths sweep the chunking edge
// cases (0, 1, chunk−1, chunk, chunk+1, large-and-odd), and every case
// also runs on offset subslices so alignment is fuzzed, not assumed.

/// Kernel-edge lengths: empty, single, around the chunk boundary, a few
/// chunks plus a remainder, and large-and-odd.
fn kernel_lengths() -> Vec<usize> {
    vec![
        0,
        1,
        KERNEL_CHUNK - 1,
        KERNEL_CHUNK,
        KERNEL_CHUNK + 1,
        3 * KERNEL_CHUNK + 5,
        255,
        777,
        4097,
    ]
}

fn random_flat(r: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| r.normal()).collect()
}

/// The chunked/SIMD lerp dispatcher equals the scalar reference bit for
/// bit at every edge length, beta, and subslice offset.
#[test]
fn lerp_flat_matches_scalar_reference_bitwise() {
    let mut r = Rng::new(401);
    for n in kernel_lengths() {
        for beta in [0.0f32, 0.31, 0.9, 1.0, r.f32()] {
            for off in [0usize, 1, 3] {
                let off = off.min(n);
                let g0 = random_flat(&mut r, n);
                let l = random_flat(&mut r, n);
                let mut want = g0.clone();
                lerp_flat_scalar(&mut want[off..], &l[off..], beta);
                let mut got = g0.clone();
                lerp_flat(&mut got[off..], &l[off..], beta);
                assert!(
                    got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "n {n} beta {beta} off {off}"
                );
            }
        }
    }
}

/// The chunked/SIMD axpy dispatcher equals the scalar reference bit for
/// bit at every edge length, weight, and subslice offset.
#[test]
fn axpy_flat_matches_scalar_reference_bitwise() {
    let mut r = Rng::new(409);
    for n in kernel_lengths() {
        for w in [0.0f32, 0.25, 1.0, -0.7, r.f32()] {
            for off in [0usize, 1, 3] {
                let off = off.min(n);
                let a0 = random_flat(&mut r, n);
                let b = random_flat(&mut r, n);
                let mut want = a0.clone();
                axpy_flat_scalar(&mut want[off..], &b[off..], w);
                let mut got = a0.clone();
                axpy_flat(&mut got[off..], &b[off..], w);
                assert!(
                    got.iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "n {n} w {w} off {off}"
                );
            }
        }
    }
}

/// The scoped-thread parallel lerp equals the scalar reference bit for
/// bit at any thread count (including counts exceeding the length) —
/// eq. (3) is elementwise, so the split cannot change a single rounding.
#[test]
fn parallel_lerp_matches_scalar_reference_bitwise() {
    let mut r = Rng::new(419);
    for n in kernel_lengths() {
        for threads in [1usize, 2, 3, 4, 7] {
            let beta = r.f32();
            let g0 = random_flat(&mut r, n);
            let l = random_flat(&mut r, n);
            let mut want = g0.clone();
            lerp_flat_scalar(&mut want, &l, beta);
            let mut got = g0.clone();
            lerp_flat_par(&mut got, &l, beta, threads);
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "n {n} threads {threads}"
            );
        }
    }
}

/// `merge_lerp_set` (which routes through the dispatcher per covered
/// slice) equals a hand-rolled per-element scalar loop bit for bit, and
/// leaves uncovered elements untouched — at fuzzed layouts and rates.
#[test]
fn merge_lerp_set_matches_scalar_reference_bitwise() {
    for seed in 0..60u64 {
        let mut r = Rng::new(seed * 19 + 421);
        let layout = random_layout(&mut r);
        let rate = 0.05 + 0.95 * r.f64();
        let map = SubmodelMap::new(&layout, rate);
        let mut g = ParamSet::zeros(layout.specs());
        for t in &mut g.tensors {
            for v in &mut t.data {
                *v = r.normal();
            }
        }
        let sub: Vec<f32> = (0..map.numel()).map(|_| r.normal()).collect();
        let beta = r.f32();

        let mut want = g.clone();
        let mut off = 0usize;
        for (t, s) in want.tensors.iter_mut().zip(map.slices()) {
            for e in 0..s.keep {
                let x = t.data[e];
                let y = sub[off + e];
                t.data[e] = beta * x + (1.0 - beta) * y;
            }
            off += s.keep;
        }

        let mut got = g.clone();
        map.merge_lerp_set(&mut got, &sub, beta);
        for ((tg, tw), s) in got.tensors.iter().zip(&want.tensors).zip(map.slices()) {
            for e in 0..s.full_len {
                assert_eq!(
                    tg.data[e].to_bits(),
                    tw.data[e].to_bits(),
                    "seed {seed} elem {e} (keep {})",
                    s.keep
                );
            }
        }
    }
}

// ------------------------------------------------------------- submodel

fn random_layout(r: &mut Rng) -> ParamLayout {
    let tensors = 1 + r.below(5) as usize;
    ParamLayout::new(
        (0..tensors)
            .map(|i| TensorSpec {
                name: format!("t{i}"),
                shape: vec![1 + r.below(60) as usize],
            })
            .collect(),
    )
}

/// Rate 1.0 is the identity: extract then merge reproduces the full
/// buffer bit-for-bit over random layouts and values.
#[test]
fn submodel_rate_one_extract_merge_is_identity_bitwise() {
    for seed in 0..60u64 {
        let mut r = Rng::new(seed * 11 + 2);
        let layout = random_layout(&mut r);
        let map = SubmodelMap::new(&layout, 1.0);
        assert!(map.is_full());
        assert_eq!(map.numel(), layout.numel());
        let full: Vec<f32> = (0..layout.numel()).map(|_| r.normal()).collect();
        let mut sub = vec![0.0f32; map.numel()];
        map.extract_flat(&full, &mut sub);
        let mut back = vec![0.0f32; full.len()];
        map.merge_flat(&mut back, &sub);
        assert!(
            back.iter().zip(&full).all(|(a, b)| a.to_bits() == b.to_bits()),
            "seed {seed}"
        );
    }
}

/// Slice maps are in-bounds, in layout order and mutually disjoint, and
/// keep counts stay in `[1, full_len]` — at any fuzzed rate.
#[test]
fn submodel_slices_in_bounds_sorted_disjoint() {
    for seed in 0..100u64 {
        let mut r = Rng::new(seed * 17 + 9);
        let layout = random_layout(&mut r);
        let rate = 0.05 + 0.95 * r.f64();
        let map = SubmodelMap::new(&layout, rate);
        let mut prev_end = 0usize;
        let mut covered = 0usize;
        for s in map.slices() {
            assert!(s.keep >= 1 && s.keep <= s.full_len, "seed {seed}");
            assert!(s.full_start >= prev_end, "seed {seed}: overlap/unsorted");
            assert!(s.full_start + s.full_len <= map.full_numel(), "seed {seed}");
            prev_end = s.full_start + s.full_len;
            covered += s.keep;
        }
        assert_eq!(prev_end, map.full_numel(), "layout fully tiled");
        assert_eq!(covered, map.numel());
        assert!(map.numel() <= map.full_numel());
    }
}

/// Overlap-count aggregation over K random rates equals the scalar
/// scatter/sum/divide reference loop bit-for-bit (same addition order,
/// same division).
#[test]
fn submodel_overlap_aggregation_matches_scalar_reference_bitwise() {
    for seed in 0..40u64 {
        let mut r = Rng::new(seed * 23 + 1);
        let layout = random_layout(&mut r);
        let n = layout.numel();
        let k = 1 + r.below(6) as usize;
        let maps: Vec<SubmodelMap> = (0..k)
            .map(|_| SubmodelMap::new(&layout, 0.05 + 0.95 * r.f64()))
            .collect();
        let subs: Vec<Vec<f32>> = maps
            .iter()
            .map(|m| (0..m.numel()).map(|_| r.normal()).collect())
            .collect();

        let mut acc = vec![0.0f32; n];
        let mut counts = vec![0u32; n];
        for (m, s) in maps.iter().zip(&subs) {
            m.accumulate_overlap(&mut acc, &mut counts, s);
        }
        finalize_overlap_mean(&mut acc, &counts);

        let mut ref_acc = vec![0.0f32; n];
        let mut ref_cnt = vec![0u32; n];
        for (m, s) in maps.iter().zip(&subs) {
            let mut off = 0usize;
            for sl in m.slices() {
                for e in 0..sl.keep {
                    ref_acc[sl.full_start + e] += s[off + e];
                    ref_cnt[sl.full_start + e] += 1;
                }
                off += sl.keep;
            }
        }
        for i in 0..n {
            if ref_cnt[i] > 0 {
                ref_acc[i] /= ref_cnt[i] as f32;
            }
        }
        assert_eq!(counts, ref_cnt, "seed {seed}");
        for i in 0..n {
            assert_eq!(
                acc[i].to_bits(),
                ref_acc[i].to_bits(),
                "seed {seed} elem {i}"
            );
        }
    }
}

// --------------------------------------------------------------- channel

/// The fading process is a pure function of (seed, client, slot): any
/// query order — monotone per-client sweeps, or the raw random
/// interleaving across clients and times — returns the same gain and
/// the same loss decision. This is the invariant that lets every
/// engine (and every shard count) query the channel when convenient
/// without perturbing determinism.
#[test]
fn fading_channel_pure_in_seed_client_and_slot() {
    use csmaafl::sim::channel;
    for seed in 0..50u64 {
        let mut r = Rng::new(seed * 31 + 5);
        let spec = format!(
            "markov:{},{}",
            [0.2, 0.5, 1.0][r.below(3) as usize],
            [50u64, 500, 1000][r.below(3) as usize]
        );
        let model = channel::parse(&spec).unwrap();
        let root = Rng::new(r.next_u64());
        let clients = 2 + r.below(12) as usize;
        let mut a = model.bind(clients, &root);
        let mut b = model.bind(clients, &root);
        let queries: Vec<(usize, u64)> = (0..200)
            .map(|_| (r.below(clients as u64) as usize, r.below(20_000)))
            .collect();
        // Reference pass: sorted (client-major, time-ascending) on `a`.
        let mut sorted = queries.clone();
        sorted.sort_unstable();
        let mut expect = std::collections::HashMap::new();
        for &(c, t) in &sorted {
            expect.insert((c, t), (a.gain(c, t), a.upload_lost(c, t)));
        }
        // Adversarial pass: the raw random interleaving on `b`.
        for &(c, t) in &queries {
            let got = (b.gain(c, t), b.upload_lost(c, t));
            assert_eq!(
                got,
                expect[&(c, t)],
                "{spec}: query order changed the process at ({c},{t})"
            );
        }
    }
}

/// Channel-scaled upload durations stay inside the gain ladder's
/// envelope (gain ∈ [0.25, 2.0] means τ/2 … 4τ, floored at one tick),
/// and the ideal channel returns τ *exactly* — degenerate τ = 0
/// included, which is what keeps `channel=ideal` timelines untouched.
#[test]
fn scaled_tau_respects_the_gain_ladder_envelope() {
    use csmaafl::sim::channel;
    let model = channel::parse("markov:0.5,100").unwrap();
    let mut s = model.bind(8, &Rng::new(9));
    let mut r = Rng::new(10);
    for _ in 0..500 {
        let c = r.below(8) as usize;
        let t = r.below(50_000);
        let tau = r.below(10_000);
        let scaled = s.scaled_tau(c, t, tau);
        assert!(scaled >= 1, "never below one tick");
        assert!(
            scaled <= (tau as f64 * 4.0).round() as u64 + 1,
            "{scaled} ticks from tau={tau}: past the deepest fade"
        );
    }
    let mut ideal = channel::parse("ideal").unwrap().bind(8, &Rng::new(9));
    for tau in [0u64, 1, 7, 10_000] {
        assert_eq!(ideal.scaled_tau(3, 12, tau), tau, "ideal must be exact");
    }
}

// ---------------------------------------------------------------- scale

/// 100k-client scale smoke for the arena + heap-scheduler hot path.
/// `#[ignore]`d in the dev loop; CI's perf-smoke job runs it via
/// `cargo test --release -- --ignored`.
#[test]
#[ignore = "scale smoke: run in CI perf-smoke (cargo test --release -- --ignored)"]
fn scale_smoke_100k_clients() {
    let cfg = ScaleSimConfig {
        clients: 100_000,
        iterations: 100_000,
        params: 32,
        ..ScaleSimConfig::default()
    };
    let r = run_scale_sim(&cfg).unwrap();
    assert_eq!(r.aggregations, 100_000);
    assert!(r.events >= r.aggregations);
    assert!(r.final_norm.is_finite());
    assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
    assert!(r.mean_staleness >= 0.0);
    assert!(r.arena_slots <= 100_000, "{}", r.arena_slots);
}

// ---------------------------------------------------------------- events

/// Event queue pops monotonically in time under random schedules.
#[test]
fn event_queue_monotone_under_fuzz() {
    for seed in 0..50u64 {
        let mut r = Rng::new(seed + 500);
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut last = 0u64;
        for i in 0..200u64 {
            // Schedule 0-3 future events, pop 0-2.
            for _ in 0..r.below(4) {
                q.schedule_in(r.below(1000), i);
            }
            for _ in 0..r.below(3) {
                if let Some((t, _)) = q.pop() {
                    assert!(t >= last, "time went backwards");
                    last = t;
                }
            }
        }
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }
}

// ------------------------------------------------------------------ json

/// JSON roundtrip fuzz: random documents survive serialize → parse.
#[test]
fn json_roundtrip_fuzz() {
    fn random_json(r: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { r.below(4) } else { r.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(r.below(2) == 0),
            2 => Json::Int(r.next_u64() as i64 / 1000),
            3 => {
                let s: String = (0..r.below(12))
                    .map(|_| {
                        let c = r.below(96) as u8 + 32;
                        c as char
                    })
                    .collect();
                Json::Str(s)
            }
            4 => Json::Array(
                (0..r.below(5))
                    .map(|_| random_json(r, depth - 1))
                    .collect(),
            ),
            _ => {
                let mut o = Json::object();
                for i in 0..r.below(5) {
                    o.set(&format!("k{i}"), random_json(r, depth - 1));
                }
                o
            }
        }
    }
    for seed in 0..300u64 {
        let mut r = Rng::new(seed);
        let doc = random_json(&mut r, 3);
        let compact = json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(doc, compact, "seed {seed}");
        let pretty = json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(doc, pretty, "seed {seed}");
    }
}

/// Config set_field never panics on arbitrary inputs — it returns errors.
#[test]
fn config_set_field_total() {
    let keys = [
        "algorithm", "clients", "gamma", "dataset", "partition", "tau_up",
        "scheduler", "aggregator", "garbage_key", "max_slots", "capacity",
    ];
    let vals = [
        "", "0", "-1", "abc", "1e9", "fedavg", "noniid", "fifo", "π",
        "classes:1.0x0.5,0.5x0.5", "uniform:nan",
    ];
    let mut cfg = csmaafl::config::RunConfig::default();
    for k in keys {
        for v in vals {
            let _ = cfg.set_field(k, v); // must not panic
        }
    }
}
